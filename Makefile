PY := PYTHONPATH=src python

.PHONY: tier1 test bench-eval bench

# CI gate: the full suite, then the eval-engine parity tests explicitly
# (they are the acceptance bar for the streaming fused-rank engine).
tier1:
	$(PY) -m pytest -x -q
	$(PY) -m pytest -q tests/test_eval_engine.py -k "parity"

test:
	$(PY) -m pytest -q

# old-path vs fused-rank engine µs/query at E ∈ {10k, 100k}; appends CSV rows
bench-eval:
	PYTHONPATH=src:. python benchmarks/bench_eval_engine.py --csv benchmarks/eval_engine.csv

bench:
	PYTHONPATH=src:. python benchmarks/run.py
