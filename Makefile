PY := PYTHONPATH=src python

.PHONY: tier1 test check-hygiene lint bench-eval bench-train bench-tick \
	bench-serve bench bench-json bench-smoke chaos-smoke attack-smoke \
	async-smoke serve-chaos-smoke

# CI gate: repo hygiene + lint, the full suite, the engine parity tests
# explicitly (they are the acceptance bars for the streaming fused-rank eval
# engine, the device-resident training engine, and the batched federation
# tick engine), then every bench suite at smoke extents so bench code paths
# can't rot, the fault soak, the Byzantine-storm gate, the streamed-
# scheduling gate, and the serving-resilience gate.
tier1: check-hygiene lint
	$(PY) -m pytest -x -q
	$(PY) -m pytest -q tests/test_eval_engine.py -k "parity"
	$(PY) -m pytest -q tests/test_train_engine.py -k "parity or retrace"
	$(PY) -m pytest -q tests/test_tick_engine.py -k "parity or reused"
	$(MAKE) bench-smoke
	$(MAKE) chaos-smoke
	$(MAKE) attack-smoke
	$(MAKE) async-smoke
	$(MAKE) serve-chaos-smoke

# ruff when available, pyflakes as second choice, stdlib-ast fallback
# otherwise (this container ships neither) — unused/duplicate imports fail
lint:
	python tools/lint.py

# every registered bench suite at tiny extents (N=2 owners, E ≤ 1k,
# single-digit epochs): exercises the bench code paths — including the
# sharded tick rows (2 forced host devices) and the in-bench parity asserts
# — as a tier-1 gate. Smoke numbers are not measurements; run.py refuses to
# write BENCH_*.json from a smoke run.
bench-smoke:
	XLA_FLAGS="--xla_force_host_platform_device_count=2" PYTHONPATH=src:. python benchmarks/run.py --smoke

# seeded fault soak over a 4-owner ring (crashes + stragglers + corrupted
# embeddings for the first ticks, then a clean tail): asserts no tick
# aborts, quarantines release, zero BUSY/QUARANTINED leak at quiescence,
# and the federation still converges. 4 forced host devices so the sharded
# tick path (group-failure fallback included) runs under fault injection.
chaos-smoke:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" PYTHONPATH=src:. python benchmarks/chaos_smoke.py

# seeded Byzantine poisoning storm over a 4-owner ring, run clean /
# undefended (both tick engines, bit-parity asserted) / defended (median
# robust aggregation, then + cosine screen): asserts the storm fires, no
# tick aborts, undefended quality measurably degrades, the defended runs
# recover to the adversary-free baseline, and the screen/reputation/
# quarantine machinery engages.
attack-smoke:
	PYTHONPATH=src:. python benchmarks/attack_smoke.py

# serving-resilience gate: seeded replica chaos (pinned crash streak on one
# replica, pinned straggler, random crash tail, expired deadlines, an
# over-quota submit) under live federation hot-swaps — asserts zero lost
# requests (served + shed + failed == submitted), breaker open → probe →
# re-admit, hedge beats the straggler, and post-flip results bit-equal a
# per-call ranker. 4 forced host devices so replica routing is real.
serve-chaos-smoke:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" PYTHONPATH=src:. python benchmarks/serve_chaos_smoke.py

# streamed-scheduling gate: 8-owner ring with tick_sync="stream" under a
# pinned straggler + random crashes — asserts the mesh keeps finishing work
# (simulated time) while the straggler blocks, nobody starves, and the run
# drains deferred/quarantined work at quiescence. 8 forced host devices so
# dependency levels dispatch against a real multi-device mesh.
async-smoke:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" PYTHONPATH=src:. python benchmarks/async_smoke.py

# fail if generated artifacts (bytecode, pytest caches) are ever tracked
# again — PR 3 accidentally shipped 12 __pycache__/*.pyc files
check-hygiene:
	@bad=$$(git ls-files | grep -E '(\.pyc$$|\.pyo$$|__pycache__|\.pytest_cache)' || true); \
	if [ -n "$$bad" ]; then \
		echo "tracked generated files:"; echo "$$bad"; exit 1; \
	fi

test:
	$(PY) -m pytest -q

# old-path vs fused-rank engine µs/query at E ∈ {10k, 100k}; appends CSV rows
bench-eval:
	PYTHONPATH=src:. python benchmarks/bench_eval_engine.py --csv benchmarks/eval_engine.csv

# seed dense path vs device-resident training engine µs/step at E ∈ {10k, 100k}
bench-train:
	PYTHONPATH=src:. python benchmarks/bench_train_engine.py --csv benchmarks/train_engine.csv

# serial reference tick vs batched (single-device) vs sharded tick engine at
# 8 owners, E=10k each; 8 simulated host devices so the sharded row measures
# real multi-device placement on CPU CI
bench-tick:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" PYTHONPATH=src:. python benchmarks/bench_federation_tick.py --csv benchmarks/federation_tick.csv

# serving tier under load at E=10⁶: per-call vs continuously batched,
# closed + open (Poisson) loops with p50/p99/QPS, and hot-swap under live
# federation ticks; 4 simulated host devices so replica routing is real
bench-serve:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" PYTHONPATH=src:. python benchmarks/bench_serving.py --csv benchmarks/serving.csv

bench:
	PYTHONPATH=src:. python benchmarks/run.py

# same, plus machine-readable BENCH_<suite>.json artifacts at the repo root
# (the committed perf trajectory). Forces 8 host devices — the sharded tick
# baseline must measure real multi-device placement, and every artifact
# records the actual environment in its _env.device_count row (plus
# tick_engine.sharded_devices.*) so a baseline regenerated under a
# different device count diffs loudly. (The previous single-device default
# silently produced a sharded row with sharded_devices=1.)
bench-json:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" PYTHONPATH=src:. python benchmarks/run.py --json
