PY := PYTHONPATH=src python

.PHONY: tier1 test bench-eval bench-train bench

# CI gate: the full suite, then the engine parity tests explicitly (they are
# the acceptance bars for the streaming fused-rank eval engine and the
# device-resident training engine).
tier1:
	$(PY) -m pytest -x -q
	$(PY) -m pytest -q tests/test_eval_engine.py -k "parity"
	$(PY) -m pytest -q tests/test_train_engine.py -k "parity or retrace"

test:
	$(PY) -m pytest -q

# old-path vs fused-rank engine µs/query at E ∈ {10k, 100k}; appends CSV rows
bench-eval:
	PYTHONPATH=src:. python benchmarks/bench_eval_engine.py --csv benchmarks/eval_engine.csv

# seed dense path vs device-resident training engine µs/step at E ∈ {10k, 100k}
bench-train:
	PYTHONPATH=src:. python benchmarks/bench_train_engine.py --csv benchmarks/train_engine.csv

bench:
	PYTHONPATH=src:. python benchmarks/run.py
