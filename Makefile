PY := PYTHONPATH=src python

.PHONY: tier1 test bench-eval bench-train bench-tick bench bench-json

# CI gate: the full suite, then the engine parity tests explicitly (they are
# the acceptance bars for the streaming fused-rank eval engine, the
# device-resident training engine, and the batched federation tick engine).
tier1:
	$(PY) -m pytest -x -q
	$(PY) -m pytest -q tests/test_eval_engine.py -k "parity"
	$(PY) -m pytest -q tests/test_train_engine.py -k "parity or retrace"
	$(PY) -m pytest -q tests/test_tick_engine.py -k "parity or reused"

test:
	$(PY) -m pytest -q

# old-path vs fused-rank engine µs/query at E ∈ {10k, 100k}; appends CSV rows
bench-eval:
	PYTHONPATH=src:. python benchmarks/bench_eval_engine.py --csv benchmarks/eval_engine.csv

# seed dense path vs device-resident training engine µs/step at E ∈ {10k, 100k}
bench-train:
	PYTHONPATH=src:. python benchmarks/bench_train_engine.py --csv benchmarks/train_engine.csv

# serial reference tick vs batched tick engine at 8 owners, E=10k each
bench-tick:
	PYTHONPATH=src:. python benchmarks/bench_federation_tick.py --csv benchmarks/federation_tick.csv

bench:
	PYTHONPATH=src:. python benchmarks/run.py

# same, plus machine-readable BENCH_<suite>.json artifacts in benchmarks/
bench-json:
	PYTHONPATH=src:. python benchmarks/run.py --json
