"""Tier-1 lint gate (``make lint``) with zero hard dependencies.

Prefers a real linter when one is on the box (``ruff``, then ``pyflakes``);
otherwise falls back to a stdlib-``ast`` pass that catches the two defects
that actually rot in this repo — module-level imports that nothing uses,
and the same name imported twice — without inventing style opinions.

The fallback is deliberately conservative: a name counts as used if it
appears as ANY identifier anywhere in the module (including inside quoted
annotations and docstrings), so it can underreport but not false-positive
on ``from __future__ import annotations``-style string types. ``# noqa``
on the import line suppresses, same as the real linters.
"""
from __future__ import annotations

import ast
import os
import shutil
import subprocess
import sys

TARGETS = ("src", "benchmarks", "tests", "tools")


def _py_files(root: str):
    for target in TARGETS:
        base = os.path.join(root, target)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if n.endswith(".py"):
                    yield os.path.join(dirpath, n)


def _bound_names(node):
    """Names an import statement binds at module scope."""
    out = []
    for a in node.names:
        if a.name == "*":
            continue
        bound = a.asname or a.name.split(".")[0]
        out.append(bound)
    return out


def _check_file(path: str):
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [(e.lineno or 0, f"syntax error: {e.msg}")]
    lines = src.splitlines()

    imports = []  # (lineno, bound name)
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            if isinstance(node, ast.ImportFrom) and node.module == "__future__":
                continue
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if "# noqa" in line:
                continue
            for name in _bound_names(node):
                imports.append((node.lineno, name))

    # every identifier anywhere in the module (walk covers annotations,
    # decorators, nested scopes); string constants are scanned too so a
    # name referenced only inside a quoted annotation stays "used"
    used = set()
    strings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            used.add(node.attr)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            strings.append(node.value)
    blob = "\n".join(strings)

    problems = []
    seen = {}
    for lineno, name in imports:
        if name in seen:
            problems.append(
                (lineno, f"duplicate import of {name!r} (first at line "
                         f"{seen[name]})")
            )
            continue
        seen[name] = lineno
        if name not in used and name not in blob:
            problems.append((lineno, f"unused import {name!r}"))
    return problems


def _fallback(root: str) -> int:
    failures = 0
    for path in _py_files(root):
        if os.path.basename(path) == "__init__.py":
            continue  # re-export surface: "unused" imports are the point
        for lineno, msg in _check_file(path):
            rel = os.path.relpath(path, root)
            print(f"{rel}:{lineno}: {msg}")
            failures += 1
    if failures:
        print(f"lint: {failures} problem(s)", file=sys.stderr)
        return 1
    print("lint: clean (stdlib ast fallback)")
    return 0


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if shutil.which("ruff"):
        return subprocess.call(
            ["ruff", "check", *(t for t in TARGETS
                                if os.path.isdir(os.path.join(root, t)))],
            cwd=root,
        )
    try:
        import pyflakes  # noqa
    except ImportError:
        return _fallback(root)
    files = list(_py_files(root))
    return subprocess.call(
        [sys.executable, "-m", "pyflakes", *files], cwd=root
    )


if __name__ == "__main__":
    sys.exit(main())
