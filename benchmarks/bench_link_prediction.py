"""Tab. 4 — link prediction (filtered Hit@1/3/10, Mean Rank):
Independent-TransE vs FKGE (and the Pallas scoring kernel parity check)."""
from __future__ import annotations

import time


from benchmarks.common import emit, pick, small_universe
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.eval import link_prediction
from repro.kge.trainer import KGETrainer


def main() -> None:
    kgs = small_universe(seed=0, n=pick(3, 2))

    # the streaming fused-rank engine made full-split eval affordable — no
    # more max_test=150 subsampling (seed-path wall-clock limit)
    max_test = pick(2000, 16)

    for name, kg in kgs.items():
        tr = KGETrainer(kg, "transe", dim=pick(32, 16), seed=0, margin=2.0)
        tr.train_epochs(pick(270, 2))
        t0 = time.perf_counter()
        lp = link_prediction(tr.params, tr.model, kg, max_test=max_test)
        dt = (time.perf_counter() - t0) * 1e6
        emit(
            f"tab4.independent.{name}", dt,
            f"hit@10={lp['hit@10']:.3f};hit@3={lp['hit@3']:.3f};"
            f"hit@1={lp['hit@1']:.3f};mr={lp['mean_rank']:.0f}",
        )

    fed = FederationScheduler(
        kgs, dim=pick(32, 16), ppat_cfg=PPATConfig(steps=pick(120, 6), seed=0),
        local_epochs=pick(150, 2), update_epochs=pick(40, 2), seed=0,
    )
    fed.initial_training()
    fed.run(max_ticks=pick(3, 1))
    for name, kg in kgs.items():
        t0 = time.perf_counter()
        lp = link_prediction(fed.trainers[name].params, fed.trainers[name].model,
                             kg, max_test=max_test)
        dt = (time.perf_counter() - t0) * 1e6
        emit(
            f"tab4.fkge.{name}", dt,
            f"hit@10={lp['hit@10']:.3f};hit@3={lp['hit@3']:.3f};"
            f"hit@1={lp['hit@1']:.3f};mr={lp['mean_rank']:.0f}",
        )


if __name__ == "__main__":
    main()
