"""Tier-1 serving-resilience gate: seeded replica chaos under live hot-swaps.

Drives a ``KGEServingTier`` attached to a live 2-owner federation through a
deterministic fault storm — a pinned crash streak on one replica (the
circuit breaker MUST open), a pinned straggler (the hedge MUST fire), a
random crash tail, deadline-expired requests (MUST shed), and an
over-quota submit burst (MUST reject) — with federation ticks hot-swapping
the serving tables mid-storm, then asserts the resilience contract at
drain:

  * zero LOST requests: every submitted request resolves to exactly one of
    served / shed / failed (``served + shed + failed == submitted`` — the
    tier itself re-asserts this at every drain point);
  * the storm actually fired (crash + straggle both observed), so the gate
    cannot silently pass by the fault layer rotting into a no-op;
  * failure isolation worked: batches were retried (not failed wholesale)
    and the goodput floor holds despite the storm;
  * the breaker opened on the crashing replica, and — on the clean tail —
    its timed probe re-admitted it (``breaker_close``), leaving every
    replica healthy after cooldown;
  * hedged dispatch beat the pinned straggler (``hedged >= 1``);
  * hot-swap under fire: at least one federation flip reached serving, and
    post-flip results are bit-equal to a per-call ranker on the owner's
    current params.

Runs in a handful of seconds on CPU CI (``make serve-chaos-smoke``, wired
into ``make tier1``) and under ``benchmarks/run.py`` (the ``serve_chaos``
suite) so the bench-smoke gate exercises it too. It is a pass/fail gate,
not a measurement: it emits no rows, so it never lands in ``BENCH_*.json``
artifacts.
"""
from __future__ import annotations

import argparse
import itertools
import sys
import time

import numpy as np

from repro.core.faults import ServeFault, ServeFaultPlan
from repro.serving import KGECandidateRanker, KGEServingTier, TierOverloadError

#: pinned streak: every launch routed to replica slot 1 in the first eight
#: launch seqs crashes — consecutive failures there are guaranteed, so the
#: breaker deterministically opens; slot 0 absorbs the retries
_CRASH_STREAK = {(s, 1): ServeFault("crash") for s in range(8)}
#: pinned straggler at launch seq 10, whichever replica takes it: 30
#: simulated seconds of suppressed readiness — only a hedge can win
_STRAGGLE = {(10, s): ServeFault("straggle", delay=30.0) for s in range(8)}


def _fault_plan() -> ServeFaultPlan:
    return ServeFaultPlan(
        crash=0.2, seed=11, until=40,
        table={**_CRASH_STREAK, **_STRAGGLE},
    )


def gate(*, max_ticks: int = 1) -> dict:
    """Run the scenario; raises RuntimeError on any contract violation.
    Returns the tier's stats dict (for the CLI summary)."""
    import jax

    from benchmarks.common import small_universe
    from repro.core.federation import FederationScheduler
    from repro.core.ppat import PPATConfig

    uni = small_universe(seed=7, n=2)
    ctr = itertools.count()
    sched = FederationScheduler(
        uni, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
        local_epochs=2, update_epochs=2, seed=0,
        score_fn=lambda name: float(next(ctr)),  # monotone ⇒ accepts pinned
    )
    sched.initial_training()
    devs = jax.devices()
    # at least two replica slots even on a 1-device host (same physical
    # device twice): retry/hedge/breaker semantics need a second slot
    ring = [devs[i % len(devs)] for i in range(max(2, min(4, len(devs))))]
    tier = KGEServingTier.for_owner(
        sched, "Alpha", block_e=256, max_batch=8, home_slot=0,
        replicas=len(ring), devices=ring,
        serve_faults=_fault_plan(), retry_limit=2,
        breaker_fails=2, probe_after=4, hedge_after=0.05,
    )
    e = tier.model.num_entities
    rng = np.random.default_rng(3)
    qs = np.stack(
        [rng.integers(0, e, 160), rng.integers(0, 4, 160),
         rng.integers(0, e, 160)], axis=1,
    ).astype(np.int64)

    reqs = []

    def burst(lo, hi, rows=4, **kw):
        for i in range(lo, hi, rows):
            reqs.append(tier.submit_rank(
                qs[i:i + rows, 0], qs[i:i + rows, 1], qs[i:i + rows, 2], **kw
            ))
            tier.step()

    t0 = time.perf_counter()
    # phase 1 — into the pinned crash streak + straggler + random storm
    burst(0, 80)
    # a few requests with an already-expired deadline: MUST shed, not fail
    burst(80, 88, deadline=0.0)
    tier.run_until_drained()
    # phase 2 — federation ticks flip the serving tables mid-storm
    v0 = tier.version
    sched.run(max_ticks=max_ticks)
    flips = tier.version - v0
    # phase 3 — clean cooldown traffic (past `until`): probes re-admit
    post = tier.submit_rank(qs[:4, 0], qs[:4, 1], qs[:4, 2])
    reqs.append(post)
    tier.step()
    burst(88, 160)
    tier.run_until_drained()
    wall = time.perf_counter() - t0

    # admission reject: one over-quota submit must raise, and must not
    # enter the accounting
    rejected = False
    tier.max_queue = 0
    try:
        tier.submit_rank(qs[:1, 0], qs[:1, 1], qs[:1, 2])
    except TierOverloadError:
        rejected = True
    tier.max_queue = None

    s = tier.stats
    goodput = s["served"] / max(s["submitted"], 1)
    tr = sched.trainers["Alpha"]
    known = np.concatenate(
        [uni["Alpha"].train, uni["Alpha"].valid, uni["Alpha"].test]
    )
    ranker = KGECandidateRanker(dict(tr.params), tr.model, known, block_e=256)
    want = ranker.rank_tails(qs[:4, 0], qs[:4, 1], qs[:4, 2])

    checks = [
        (s["served"] + s["shed"] + s["failed"] == s["submitted"],
         f"requests lost: {s}"),
        (all(r.done for r in reqs), "undrained request leaked"),
        (tier.fault_counts.get("crash", 0) >= 2
         and tier.fault_counts.get("straggle", 0) >= 1,
         f"storm too quiet: {tier.fault_counts}"),
        (s["retried"] >= 1, "no batch ever retried — isolation untested"),
        (s["breaker_open"] >= 1,
         f"breaker never opened under the pinned crash streak: {s}"),
        (s["breaker_close"] >= 1,
         f"probe never re-admitted the broken replica: {s}"),
        (all(rp.healthy for rp in tier.replicas),
         f"replica left unhealthy after clean cooldown: {tier.health()}"),
        (s["hedged"] >= 1, f"hedge never fired on the pinned straggler: {s}"),
        (s["shed"] >= 1, f"expired requests did not shed: {s}"),
        (all(r.state == "shed" for r in reqs if r.deadline == 0.0),
         "a deadline-0 request did not shed"),
        (rejected and s["rejected"] == 1,
         "over-quota submit was not rejected"),
        (goodput >= 0.7, f"goodput floor broken: {goodput:.2f} < 0.7"),
        (flips >= 1, "federation ran but no version flip reached serving"),
        (post.state == "served" and np.array_equal(post.result, want),
         "post-flip result not bit-equal to per-call ranker"),
        (s["publish_errors"] == 0, f"hot-swap publish failed: {s}"),
    ]
    failures = [msg for ok, msg in checks if not ok]
    print(
        f"serve-chaos-smoke: replicas={len(ring)} wall={wall:.1f}s "
        f"submitted={s['submitted']} served={s['served']} shed={s['shed']} "
        f"failed={s['failed']} retried={s['retried']} hedged={s['hedged']} "
        f"breaker={s['breaker_open']}/{s['breaker_close']} flips={flips} "
        f"goodput={goodput:.2f} faults={dict(tier.fault_counts)}"
    )
    if failures:
        raise RuntimeError("; ".join(failures))
    return s


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-ticks", type=int, default=1)
    args = ap.parse_args(argv)
    try:
        gate(max_ticks=args.max_ticks)
    except RuntimeError as ex:
        print(f"serve-chaos-smoke FAIL: {ex}", file=sys.stderr)
        return 1
    print("serve-chaos-smoke: PASS — zero lost requests, breaker cycled, "
          "hedge won, shed/reject enforced, hot-swap served bit-equal")
    return 0


if __name__ == "__main__":
    sys.exit(main())
