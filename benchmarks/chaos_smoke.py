"""Tier-1 chaos gate: seeded fault soak over a 4-owner federation.

Drives a small all-ring federation through a deterministic fault storm —
crashes, stragglers past the tick deadline, and corrupted exchanged
embeddings for the first ticks (``until=3``), then a clean tail — and
asserts the fault-tolerance contract at quiescence:

  * no tick aborts: every fault is isolated to its entry and surfaced as a
    ``FederationEvent(fault=...)`` audit record;
  * the storm actually fired (multiple fault kinds observed), so the gate
    cannot silently pass by the injector rotting into a no-op;
  * the federation heals: deferred retries drain, quarantines release, and
    no owner is left ``BUSY`` or ``QUARANTINED`` at quiescence;
  * it still converges: the backtrack invariant holds (best scores never
    regress below the post-local-training baseline) and at least one PPAT
    exchange was accepted despite the chaos.

Runs in a handful of seconds on CPU CI (``make chaos-smoke``, wired into
``make tier1``). This is a pass/fail gate, not a measurement — it is
deliberately NOT registered in ``benchmarks/run.py``'s suite list, so it
never lands in ``BENCH_*.json`` artifacts.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.federation import FederationScheduler, NodeState
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe

FAULT_SPEC = "crash=0.3,straggle=0.2,corrupt=0.2,seed=5,until=3,delay=1e6"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--owners", type=int, default=4)
    ap.add_argument("--max-ticks", type=int, default=24)
    ap.add_argument("--tick-impl", default=None,
                    choices=[None, "batched", "reference"])
    args = ap.parse_args(argv)

    n = args.owners
    stats = [(f"O{i}", 6, 40000, 120000) for i in range(n)]
    aligns = [(f"O{i}", f"O{(i + 1) % n}", 12000) for i in range(n)]
    uni = synthesize_universe(
        seed=3, scale=1 / 1000, kg_stats=stats, alignments=aligns
    )
    fed = FederationScheduler(
        uni, dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
        tick_faults=FAULT_SPEC, tick_deadline=1e5,
        retry_budget=2, backoff_ticks=1, quarantine_ticks=2,
    )
    inits = fed.initial_training()
    t0 = time.perf_counter()
    fed.run(max_ticks=args.max_ticks, tick_impl=args.tick_impl)
    wall = time.perf_counter() - t0

    faults = [e.fault for e in fed.events if e.fault]
    kinds = sorted(set(faults))
    checks = [
        (len(kinds) >= 2,
         f"storm too quiet — need >= 2 fault kinds, saw {kinds}"),
        (all(s in (NodeState.READY, NodeState.SLEEP)
             for s in fed.state.values()),
         "leaked transient state at quiescence: "
         + str({m: s.value for m, s in fed.state.items()})),
        (not fed._deferred,
         f"deferred retries stranded: {fed._deferred}"),
        (not fed._quarantine_until,
         f"quarantine never released: {fed._quarantine_until}"),
        (fed._tick < args.max_ticks,
         f"did not quiesce before the tick cap ({fed._tick})"),
        (all(fed.best_score[m] >= inits[m] for m in uni),
         "backtrack invariant violated: best score regressed"),
        (any(e.accepted and e.kind == "ppat" for e in fed.events),
         "no PPAT exchange accepted — federation made no progress"),
        # streaming-scheduler stamps stay coherent in barrier mode: every
        # event at level 0, per-owner clocks advancing, and the view-
        # version vector moving with accepted exchanges
        (all(e.level == 0 and e.owner_clock > 0 for e in fed.events),
         "barrier-mode events carry bad level/owner_clock stamps"),
        (max(e.view_version for e in fed.events) > 0,
         "view versions never advanced despite accepted exchanges"),
    ]
    failures = [msg for ok, msg in checks if not ok]
    print(
        f"chaos-smoke: N={n} ticks={fed._tick} wall={wall:.1f}s "
        f"faults={len(faults)} kinds={kinds} "
        f"accepted={sum(1 for e in fed.events if e.accepted)}"
    )
    for msg in failures:
        print(f"chaos-smoke FAIL: {msg}", file=sys.stderr)
    if failures:
        return 1
    print("chaos-smoke: PASS — faults isolated, federation healed and "
          "converged")
    return 0


if __name__ == "__main__":
    sys.exit(main())
