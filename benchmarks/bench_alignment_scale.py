"""Fig. 11 / Tab. 6 — effect of the aligned-entity sampling ratio
(20/40/60/80/100%) on federation gains."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, pick, small_universe
from repro.core.alignment import AlignmentRegistry
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig


def main() -> None:
    base = small_universe(seed=0, n=pick(3, 2))
    rng = np.random.default_rng(0)
    full_reg = AlignmentRegistry.from_kgs(base)
    names = list(base)
    pairs = [(a, b) for i, a in enumerate(names) for b in names[i + 1 :]
             if full_reg.entities(a, b) is not None]

    for ratio in (0.2, 0.4, 0.6, 0.8, 1.0):
        # subsample EVERY pair's aligned set at the same ratio (Fig. 11 setup)
        reg = AlignmentRegistry()
        k = 0
        for a, b in pairs:
            ia, ib = full_reg.entities(a, b)
            kk = max(2, int(len(ia) * ratio))
            sel = rng.choice(len(ia), kk, replace=False)
            reg.add_entities(a, b, ia[sel], ib[sel])
            k += kk

        t0 = time.perf_counter()
        # score_split="test" (Alg. 1 verbatim) so time-0 and final scores are
        # on the SAME split/negatives — gains are then comparable.
        fed = FederationScheduler(
            base, dim=pick(32, 16), registry=reg,
            ppat_cfg=PPATConfig(steps=pick(120, 6), seed=0),
            local_epochs=pick(150, 2), update_epochs=pick(40, 2), seed=0,
            score_split="test",
        )
        init = fed.initial_training()
        final = fed.run(max_ticks=pick(2, 1))
        dt = (time.perf_counter() - t0) * 1e6
        gains = [final[n] - init[n] for n in names]
        emit(
            f"tab6.ratio_{int(ratio*100)}", dt,
            f"aligned={k};mean_gain={np.mean(gains)*100:+.2f}pp",
        )


if __name__ == "__main__":
    main()
