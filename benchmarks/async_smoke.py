"""Tier-1 async gate: streamed scheduling under a straggler + crash storm.

Drives an 8-owner ring federation with ``tick_sync="stream"`` through a
combined storm — one pinned slow owner (a simulated per-entry delay far
beyond anything the mesh should wait for) plus seeded random crashes for
the first ticks, then a clean tail — and asserts the asynchronous-
scheduling contract at quiescence:

  * **no stall**: owners outside the straggler's alignment neighborhood
    finish (in simulated time) without ever inheriting the straggler's
    delay — under the lockstep barrier every owner would, which is the
    difference this gate pins;
  * **no starvation**: every owner — the straggler included — hosts work
    and advances its per-owner logical clock despite the storm;
  * **streaming actually streamed**: dependency levels past level 0 were
    cut and executed, and accepted events carry advancing view versions;
  * **quiescence drains**: deferred retries and quarantines empty, no
    owner is left ``BUSY``/``QUARANTINED``, and the run quiesces before
    the tick cap;
  * it still converges: the backtrack invariant holds and PPAT exchanges
    were accepted through the chaos.

Runs in a handful of seconds on CPU CI (``make async-smoke``, wired into
``make tier1``; the Makefile forces 8 host devices so the streamed levels
dispatch against a real multi-device mesh). Pass/fail gate, not a
measurement — deliberately NOT registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.faults import Fault, FaultPlan
from repro.core.federation import FederationScheduler, NodeState
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe

#: the slow owner's simulated per-entry delay — absurdly large on purpose:
#: any fast owner whose simulated finish stays below this provably never
#: waited on the straggler's chain
DELAY = 1e6


def storm_plan(host: str, *, storm_ticks: int = 3) -> FaultPlan:
    """Pinned straggles on ``host`` (every entry it hosts, first
    ``storm_ticks`` ticks) layered over seeded random crashes elsewhere.
    The pinned table wins for the slow owner's entries; every other draw
    falls through to the crash rate."""
    table = {
        (t, host): Fault("straggle", delay=DELAY)
        for t in range(1, storm_ticks + 1)
    }
    return FaultPlan(crash=0.25, seed=7, until=storm_ticks, table=table)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--owners", type=int, default=8)
    ap.add_argument("--max-ticks", type=int, default=24)
    ap.add_argument("--staleness-bound", type=int, default=1)
    args = ap.parse_args(argv)

    n = args.owners
    slow = "O0"
    stats = [(f"O{i}", 6, 40000, 120000) for i in range(n)]
    aligns = [(f"O{i}", f"O{(i + 1) % n}", 12000) for i in range(n)]
    uni = synthesize_universe(
        seed=3, scale=1 / 1000, kg_stats=stats, alignments=aligns
    )
    fed = FederationScheduler(
        uni, dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
        tick_faults=storm_plan(slow),
        retry_budget=2, backoff_ticks=1, quarantine_ticks=2,
    )
    inits = fed.initial_training()
    t0 = time.perf_counter()
    fed.run(
        max_ticks=args.max_ticks, tick_sync="stream",
        staleness_bound=args.staleness_bound,
    )
    wall = time.perf_counter() - t0

    faults = [e.fault for e in fed.events if e.fault]
    kinds = sorted(set(faults))
    sims = fed.sim_times()
    hosts = {e.host for e in fed.events if e.host in uni}
    # entries that FINISHED (simulated) before the straggler's first slow
    # entry could have: under the lockstep barrier this set is empty — the
    # very first tick synchronizes every owner behind the 1e6 s straggle
    early = [
        e for e in fed.events
        if 0.0 < e.sim_finish < DELAY and e.fault is None
    ]
    checks = [
        ("crash" in kinds,
         f"storm too quiet — crashes never fired, saw {kinds}"),
        (sims.get(slow, 0.0) >= DELAY,
         f"the pinned straggle never landed: sim({slow})="
         f"{sims.get(slow, 0.0):.3g}s"),
        # no stall: the mesh kept finishing work while the straggler
        # blocked — a barrier run would leave `early` EMPTY
        (len(early) > n,
         f"mesh stalled behind the straggler: only {len(early)} entries "
         f"finished before its chain"),
        # no starvation: the mesh serviced everyone, slow owner included
        (hosts == set(uni),
         f"owners never serviced: {sorted(set(uni) - hosts)}"),
        (all(fed._owner_clock.get(o, 0) > 0 for o in uni),
         f"stuck per-owner clocks: {fed._owner_clock}"),
        # streaming actually streamed: levels past 0 were cut, and view
        # versions advanced on the events that consumed them
        (any(e.level > 0 for e in fed.events),
         "no dependency level past 0 — the plan never actually streamed"),
        (max((e.view_version for e in fed.events), default=0) > 0,
         "view versions never advanced on any event"),
        (all(s in (NodeState.READY, NodeState.SLEEP)
             for s in fed.state.values()),
         "leaked transient state at quiescence: "
         + str({m: s.value for m, s in fed.state.items()})),
        (not fed._deferred,
         f"deferred retries stranded: {fed._deferred}"),
        (not fed._quarantine_until,
         f"quarantine never released: {fed._quarantine_until}"),
        (fed._tick < args.max_ticks,
         f"did not quiesce before the tick cap ({fed._tick})"),
        (all(fed.best_score[m] >= inits[m] for m in uni),
         "backtrack invariant violated: best score regressed"),
        (any(e.accepted and e.kind == "ppat" for e in fed.events),
         "no PPAT exchange accepted — federation made no progress"),
    ]
    failures = [msg for ok, msg in checks if not ok]
    print(
        f"async-smoke: N={n} passes={fed._tick} wall={wall:.1f}s "
        f"faults={len(faults)} kinds={kinds} "
        f"stale={sum(1 for e in fed.events if e.fault == 'stale')} "
        f"levels={max((e.level for e in fed.events), default=0) + 1} "
        f"accepted={sum(1 for e in fed.events if e.accepted)} "
        f"early={len(early)} slow_sim={sims.get(slow, 0.0):.3g}s"
    )
    for msg in failures:
        print(f"async-smoke FAIL: {msg}", file=sys.stderr)
    if failures:
        return 1
    print("async-smoke: PASS — streamed past the straggler, no starvation, "
          "drained at quiescence")
    return 0


if __name__ == "__main__":
    sys.exit(main())
