"""Render EXPERIMENTS.md §Roofline tables from dryrun_results.json."""
from __future__ import annotations

import json
import os
import sys

HERE = os.path.dirname(__file__)


def fmt(x):
    return f"{x:.2e}"


def main(path=None):
    path = path or os.path.join(HERE, "dryrun_results.json")
    with open(path) as f:
        results = json.load(f)
    rows_1pod = [r for r in results if r["status"] == "ok" and not r.get("multi_pod")]
    rows_2pod = [r for r in results if r["status"] == "ok" and r.get("multi_pod")]
    skips = {(r["arch"], r["shape"]) for r in results if r["status"] == "skipped"}

    print("### Single-pod (16×16 = 256 chips) — full baseline table\n")
    print("| arch | shape | kind | compute_s | memory_s | collective_s | bottleneck | useful FLOPs | peak GiB/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows_1pod, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {fmt(rf['compute_s'])} "
            f"| {fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | **{rf['bottleneck']}** "
            f"| {rf['useful_flops_ratio']*100:.0f}% "
            f"| {r['memory']['peak_bytes_per_device']/2**30:.2f} | {r['compile_s']:.0f} |"
        )
    print("\nSkipped (documented in DESIGN.md §4):",
          ", ".join(f"{a}×{s}" for a, s in sorted(skips)))

    print("\n### Two-pod (2×16×16 = 512 chips) — pod-axis sharding proof\n")
    print("| arch | shape | compute_s | memory_s | collective_s | bottleneck | peak GiB/dev |")
    print("|---|---|---|---|---|---|---|")
    for r in sorted(rows_2pod, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        print(
            f"| {r['arch']} | {r['shape']} | {fmt(rf['compute_s'])} | {fmt(rf['memory_s'])} "
            f"| {fmt(rf['collective_s'])} | **{rf['bottleneck']}** "
            f"| {r['memory']['peak_bytes_per_device']/2**30:.2f} |"
        )

    # candidates for the perf pass
    def frac(r):
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        return rf["compute_s"] / dom if dom else 0.0

    worst = sorted(rows_1pod, key=frac)[:5]
    coll = sorted(rows_1pod, key=lambda r: -r["roofline"]["collective_s"])[:5]
    print("\n### Hillclimb candidates")
    print("worst compute fraction:", [(r["arch"], r["shape"], f"{frac(r)*100:.1f}%") for r in worst])
    print("most collective-bound:", [(r["arch"], r["shape"], fmt(r["roofline"]["collective_s"])) for r in coll])


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
