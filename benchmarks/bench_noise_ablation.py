"""Tab. 5 — triple classification accuracy under different PATE noise scales
λ ∈ {no-noise, 0.05, 1, 2, 5} for one KG pair (paper: Dbpedia↔Geonames)."""
from __future__ import annotations

import time

from benchmarks.common import emit, pick, small_universe
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.eval import triple_classification_accuracy


def main() -> None:
    # λ per Eqs. 9–10 (PATE's γ): noise = Lap(1/λ); 0 = the paper's "No noise"
    for lam_name, lam in [("none", 0.0), ("0.05", 0.05), ("1", 1.0), ("2", 2.0), ("5", 5.0)]:
        kgs = small_universe(seed=0, n=2)
        t0 = time.perf_counter()
        fed = FederationScheduler(
            kgs, dim=pick(32, 16),
            ppat_cfg=PPATConfig(steps=pick(120, 6), lam=lam, seed=0),
            local_epochs=pick(150, 2), update_epochs=pick(40, 2), seed=0,
        )
        fed.initial_training()
        fed.run(max_ticks=pick(2, 1))
        dt = (time.perf_counter() - t0) * 1e6
        accs = {
            n: triple_classification_accuracy(
                fed.trainers[n].params, fed.trainers[n].model, kgs[n]
            )
            for n in kgs
        }
        pair = ";".join(f"{n}={a:.3f}" for n, a in accs.items())
        eps = max(fed.epsilons) if (fed.epsilons and lam > 0) else float("inf")
        emit(f"tab5.lambda_{lam_name}", dt, f"{pair};eps={eps:.2f}")


if __name__ == "__main__":
    main()
