"""Shared benchmark utilities: CSV emission + scaled-universe builders."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

#: every ``emit`` call also lands here, so harnesses (``run.py --json``) can
#: dump machine-readable ``BENCH_*.json`` files per suite
RECORDED: Dict[str, float] = {}


def smoke() -> bool:
    """True when ``REPRO_BENCH_SMOKE`` asks for tiny-extent runs: every
    registered suite shrinks its default extents (N=2 owners, E ≤ 1k,
    single-digit epochs/steps) so the whole registry executes in CI time as
    a tier-1 gate — the bench CODE PATHS (parity asserts included) are
    exercised every run instead of rotting between full bench sessions.
    Smoke numbers are meaningless as measurements and must never be written
    into the committed ``BENCH_*.json`` baselines (``run.py`` refuses)."""
    return os.environ.get("REPRO_BENCH_SMOKE", "").strip().lower() in (
        "1", "true", "yes", "on"
    )


def pick(full, tiny):
    """``tiny`` under ``REPRO_BENCH_SMOKE``, else ``full`` — the one-liner
    suites use to shrink their default extents."""
    return tiny if smoke() else full


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    RECORDED[name] = float(us_per_call)
    print(f"{name},{us_per_call:.1f},{derived}")


def drain_recorded() -> Dict[str, float]:
    """Return and clear the rows emitted since the last drain."""
    out = dict(RECORDED)
    RECORDED.clear()
    return out


def write_bench_json(suite: str, rows: Dict[str, float], out_dir: str) -> str:
    """Write ``BENCH_<suite>.json`` mapping row name → µs/call.

    Every artifact also records its measurement environment as ``_env.*``
    rows (numeric, like everything else in the schema): a baseline
    regenerated under a different device count diffs loudly instead of
    silently mixing environments — the committed federation-tick baseline
    was once recorded in a 1-device process while claiming a sharded
    speedup, which this field makes impossible to miss."""
    import jax

    rows = dict(rows)
    rows["_env.device_count"] = float(len(jax.devices()))
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def timed(fn, *args, repeats: int = 1, **kw):
    # perf_counter, not time.time(): benchmark durations must be monotonic
    # and immune to NTP/clock adjustments
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # µs


def small_universe(seed: int = 0, n: int = 3):
    """A 3-KG universe big enough to show federation gains, small enough
    for CI-speed benchmarks."""
    from repro.kge.data import synthesize_universe

    stats = [
        ("Alpha", 14, 110000, 380000),
        ("Beta", 10, 90000, 300000),
        ("Gamma", 8, 70000, 230000),
    ][:n]
    names = {s[0] for s in stats}
    aligns = [
        a for a in [("Alpha", "Beta", 36000), ("Beta", "Gamma", 26000),
                    ("Alpha", "Gamma", 22000)]
        if a[0] in names and a[1] in names
    ]
    return synthesize_universe(seed=seed, scale=1 / 400,
                               kg_stats=stats, alignments=aligns)
