"""Shared benchmark utilities: CSV emission + scaled-universe builders."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness contract: ``name,us_per_call,derived`` CSV rows."""
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeats
    return out, dt * 1e6  # µs


def small_universe(seed: int = 0, n: int = 3):
    """A 3-KG universe big enough to show federation gains, small enough
    for CI-speed benchmarks."""
    from repro.kge.data import synthesize_universe

    stats = [
        ("Alpha", 14, 110000, 380000),
        ("Beta", 10, 90000, 300000),
        ("Gamma", 8, 70000, 230000),
    ][:n]
    names = {s[0] for s in stats}
    aligns = [
        a for a in [("Alpha", "Beta", 36000), ("Beta", "Gamma", 26000),
                    ("Alpha", "Gamma", 22000)]
        if a[0] in names and a[1] in names
    ]
    return synthesize_universe(seed=seed, scale=1 / 400,
                               kg_stats=stats, alignments=aligns)
