"""Streaming fused-rank eval engine vs the seed (B, E)-materializing path.

Emits ``eval_engine.{old|new}.E{N}`` rows with µs/query (one query = one test
triple, ranked tail- AND head-side) at E ∈ {10k, 100k}, plus a speedup row.
The acceptance bar is ≥ 5× at E = 100k on the CI backend. ``--csv <path>``
additionally records the rows to a CSV file.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import numpy as np

from benchmarks.common import emit, pick
from repro.kge.eval import link_prediction
from repro.kge.models import KGEModel, init_kge


@dataclass
class _FakeKG:
    """Minimal KG shim: random triples over a large entity table (the eval
    path only reads splits + num_entities)."""

    num_entities: int
    num_relations: int
    train: np.ndarray
    valid: np.ndarray
    test: np.ndarray


def _make(e: int, *, n_queries: int, dim: int, seed: int = 0) -> tuple:
    rng = np.random.default_rng(seed)

    def tri(n):
        return np.stack(
            [rng.integers(0, e, n), rng.integers(0, 8, n), rng.integers(0, e, n)],
            axis=1,
        ).astype(np.int64)

    kg = _FakeKG(e, 8, tri(4 * n_queries), tri(n_queries), tri(n_queries))
    m = KGEModel("transe", num_entities=e, num_relations=8, dim=dim)
    params = init_kge(jax.random.PRNGKey(seed), m)
    return params, m, kg


def _time_path(fn, *, repeats: int = 1) -> tuple:
    fn()  # warm-up: compile + trace outside the timed region
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    return out, (time.perf_counter() - t0) / repeats


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None, help="also append rows to this file")
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--queries", type=int, default=pick(24, 6))
    ap.add_argument("--sizes", type=int, nargs="*",
                    default=pick([10_000, 100_000], [768]))
    args = ap.parse_args(argv)

    rows = []
    for e in args.sizes:
        # the old path ships (B, E) to host and broadcasts (B, E, d) on
        # device — keep its batch small enough to fit CI memory
        batch = 16 if e >= 100_000 else 32
        params, m, kg = _make(e, n_queries=args.queries, dim=args.dim)
        kw = dict(filtered=True, max_test=args.queries, batch=batch)

        old, dt_old = _time_path(
            lambda: link_prediction(params, m, kg, engine="reference", **kw)
        )
        new, dt_new = _time_path(
            lambda: link_prediction(params, m, kg, engine="fused", **kw)
        )
        assert old == new, (old, new)  # parity recorded by the same run

        us_old = dt_old * 1e6 / args.queries
        us_new = dt_new * 1e6 / args.queries
        speedup = us_old / us_new
        rows.append((f"eval_engine.old.E{e}", us_old, f"mr={old['mean_rank']:.0f}"))
        rows.append((f"eval_engine.new.E{e}", us_new, f"mr={new['mean_rank']:.0f}"))
        # value = the ratio itself (dimensionless) so the committed JSON
        # baselines track the speedup machine-checkably, not a latency
        rows.append(
            (f"eval_engine.speedup.E{e}", speedup, f"speedup={speedup:.1f}x")
        )

    for name, us, derived in rows:
        emit(name, us, derived)
    if args.csv:
        with open(args.csv, "a") as f:
            for name, us, derived in rows:
                f.write(f"{name},{us:.1f},{derived}\n")


if __name__ == "__main__":
    main()
