"""§4.1.2 — the paper's ε̂ = 2.73 privacy-bound arithmetic, plus our honest
per-query moments accounting for one PPAT run at the paper's settings."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, pick
from repro.core.privacy import MomentsAccountant


def main() -> None:
    # --- the paper's arithmetic: per-handshake α ≤ 0.29, l = 9, δ = 1e-5 ---
    alpha, l, delta = 0.29, 9, 1e-5
    n_handshakes = 45
    eps = (alpha * n_handshakes + np.log(1 / delta)) / l
    emit("privacy.paper_bound", 0.0,
         f"eps={eps:.2f};expected=2.73;l={l};alpha_per_handshake={alpha}")

    # --- honest per-query accounting at λ=0.05, 4 teachers ----------------
    t0 = time.perf_counter()
    acc = MomentsAccountant(lam=0.05, delta=1e-5)
    rng = np.random.default_rng(0)
    queries = 0
    for _ in range(pick(50, 5)):  # PATE batches of 32 queries
        n1 = rng.integers(0, 5, 32)
        acc.update(4 - n1, n1)
        queries += 32
    dt = (time.perf_counter() - t0) * 1e6
    emit("privacy.per_query_accounting", dt,
         f"queries={queries};eps={acc.epsilon():.2f};best_l={acc.best_moment()}")

    # --- ε monotone in queries (DP sanity) --------------------------------
    acc2 = MomentsAccountant(lam=0.05, delta=1e-5)
    acc2.update(4, 0)
    e1 = acc2.epsilon()
    for _ in range(pick(100, 10)):
        acc2.update(4, 0)
    emit("privacy.monotonicity", 0.0,
         f"eps_1q={e1:.3f};eps_101q={acc2.epsilon():.3f};monotone={acc2.epsilon()>=e1}")


if __name__ == "__main__":
    main()
