"""Serving tier under load: continuous batching vs per-call, with hot-swap.

Closed-loop (fixed concurrency) and open-loop (Poisson arrivals) generators
drive single-query rank requests through ``KGEServingTier`` at E ≥ 10⁶ and
report p50/p99 latency and queries/sec, against a per-call
``KGECandidateRanker`` baseline (the pre-tier serving surface). A second
scenario attaches the tier to a live 2-owner federation and serves the same
traffic WHILE ticks land — every accepted update hot-swaps the serving
tables, and the run asserts zero failed requests across the version flips.

In-bench invariants (smoke included): batched results bit-equal the
per-call ranker, zero failures everywhere, ≥ 1 version flip in the
federation scenario; the ≥ 3× batched-vs-per-call throughput bar is
asserted on full (non-smoke) runs.

A third scenario measures the resilience layer: the faults-off vs
armed-inert overhead (the chaos layer must cost ≈0 when idle — results
asserted bit-equal), goodput and shed fraction under a seeded replica
crash storm with deadlines, and p99 with/without hedged dispatch under a
straggler storm (full runs assert hedging beats no-hedging — the straggle
delay is simulated, so the comparison is compute-independent).

Rows: ``serving.percall.E{N}`` / ``serving.closed.E{N}`` (µs/query),
``serving.closed.{p50,p99}_ms.E{N}`` / ``.qps.E{N}``, the same for
``serving.open.*`` (λ = 70% of measured closed-loop capacity),
``serving.speedup.E{N}`` (dimensionless),
``serving.{noticks,with_ticks}.E{N}`` for the federation scenario, and the
resilience rows ``serving.fault_{off,armed,overhead}.E{N}``,
``serving.storm.{goodput,shed_frac}.E{N}``, and
``serving.storm.p99_ms.{nohedge,hedge}.E{N}``.
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import List

import numpy as np

from benchmarks.common import emit, pick, smoke
from repro.serving import KGECandidateRanker, KGEServingTier, QueryRequest


def _tri(rng, n, e, r):
    return np.stack(
        [rng.integers(0, e, n), rng.integers(0, r, n), rng.integers(0, e, n)],
        axis=1,
    ).astype(np.int64)


def _lat_ms(reqs: List[QueryRequest], q: float) -> float:
    return float(np.percentile([r.latency for r in reqs], q) * 1e3)


def _pump(tier) -> None:
    if tier.queue:
        tier.step()
    elif tier.inflight:
        tier._reap(block=True)


def closed_loop(tier, queries: np.ndarray, *, concurrency: int):
    """Fixed-pressure generator: keep ``concurrency`` single-query requests
    outstanding until the list drains. Returns (requests, wall seconds)."""
    reqs: List[QueryRequest] = []
    live: List[QueryRequest] = []
    i, n = 0, len(queries)
    t0 = time.perf_counter()
    while i < n or live:
        live = [q for q in live if not q.done]
        while i < n and len(live) < concurrency:
            q = queries[i]
            req = tier.submit_rank(q[:1], q[1:2], q[2:3])
            reqs.append(req)
            live.append(req)
            i += 1
        if tier.queue or tier.inflight:
            _pump(tier)
    return reqs, time.perf_counter() - t0


def open_loop(tier, queries: np.ndarray, *, rate_qps: float, seed: int = 0):
    """Poisson-arrival generator at ``rate_qps``: latency is measured from
    each request's ARRIVAL time, so queueing delay under bursts counts."""
    rng = np.random.default_rng(seed)
    n = len(queries)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n))
    reqs: List[QueryRequest] = []
    i = 0
    t0 = time.perf_counter()
    while len(reqs) < n or tier.queue or tier.inflight:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            q = queries[i]
            req = tier.submit_rank(q[:1], q[1:2], q[2:3])
            req.submitted_at = t0 + arrivals[i]
            reqs.append(req)
            i += 1
        if tier.queue or tier.inflight:
            _pump(tier)
        elif i < n:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.002))
    return reqs, time.perf_counter() - t0


def _bench_traffic(rows, *, entities, dim, n_closed, n_percall, block_e,
                   max_batch, seed=0):
    import jax

    from repro.kge.models import KGEModel, init_kge

    rng = np.random.default_rng(seed)
    n_rel = 8
    known = _tri(rng, 5000, entities, n_rel)
    model = KGEModel("transe", num_entities=entities, num_relations=n_rel,
                     dim=dim)
    params = init_kge(jax.random.PRNGKey(seed), model)
    ranker = KGECandidateRanker(params, model, known, block_e=block_e)
    tier = KGEServingTier(params, model, known, block_e=block_e,
                          max_batch=max_batch)
    queries = _tri(rng, n_closed, entities, n_rel)

    # ---- per-call baseline (the pre-tier serving surface) ----------------
    per = queries[:n_percall]
    ranker.rank_tails(per[:1, 0], per[:1, 1], per[:1, 2])  # warm/compile
    t0 = time.perf_counter()
    percall_ranks = [
        ranker.rank_tails(q[:1], q[1:2], q[2:3]) for q in per
    ]
    us_percall = (time.perf_counter() - t0) / n_percall * 1e6

    # ---- closed loop ----------------------------------------------------
    warm, _ = closed_loop(tier, queries[: max_batch], concurrency=max_batch)
    creqs, wall = closed_loop(tier, queries, concurrency=2 * max_batch)
    assert tier.stats["failed"] == 0, tier.stats
    us_closed = wall / n_closed * 1e6
    qps = n_closed / wall
    # in-bench parity: batched results bit-equal the per-call ranker
    # (queries[j] went through both paths for j < n_percall)
    for j in range(n_percall):
        np.testing.assert_array_equal(creqs[j].result, percall_ranks[j])
    e = entities
    rows.append((f"serving.percall.E{e}", us_percall, "B=1 ranker calls"))
    rows.append((f"serving.closed.E{e}", us_closed,
                 f"qps={qps:.1f},batches={tier.stats['batches']}"))
    rows.append((f"serving.closed.p50_ms.E{e}", _lat_ms(creqs, 50), "latency"))
    rows.append((f"serving.closed.p99_ms.E{e}", _lat_ms(creqs, 99), "latency"))
    rows.append((f"serving.closed.qps.E{e}", qps, "queries/sec"))

    # ---- open loop at 70% of measured capacity --------------------------
    oreqs, owall = open_loop(tier, queries, rate_qps=0.7 * qps, seed=seed + 1)
    assert tier.stats["failed"] == 0, tier.stats
    oqps = len(oreqs) / owall
    rows.append((f"serving.open.p50_ms.E{e}", _lat_ms(oreqs, 50),
                 f"poisson λ={0.7 * qps:.1f}/s"))
    rows.append((f"serving.open.p99_ms.E{e}", _lat_ms(oreqs, 99), "latency"))
    rows.append((f"serving.open.qps.E{e}", oqps, "queries/sec"))

    speedup = us_percall / us_closed
    rows.append((f"serving.speedup.E{e}", speedup,
                 f"batched vs percall {speedup:.1f}x"))
    if not smoke():
        assert speedup >= 3.0, (
            f"batched serving {speedup:.2f}x < 3x per-call baseline"
        )


def _bench_with_ticks(rows, *, dim, steps, epochs, max_ticks, n_queries,
                      max_batch, seed=0):
    """Serve closed-loop traffic while a federation ticks in a background
    thread — every accepted update hot-swaps the tier's tables mid-load."""
    import itertools

    from benchmarks.common import small_universe
    from repro.core.federation import FederationScheduler
    from repro.core.ppat import PPATConfig

    uni = small_universe(seed=seed, n=2)
    ctr = itertools.count()
    # monotone score ⇒ deterministic accepts ⇒ the flip count is pinned by
    # the tick plan, not by tiny-universe training luck
    sched = FederationScheduler(
        uni, dim=dim, ppat_cfg=PPATConfig(steps=steps, seed=0),
        local_epochs=epochs, update_epochs=max(2, epochs // 2), seed=0,
        score_fn=lambda name: float(next(ctr)),
    )
    sched.initial_training()
    tier = KGEServingTier.for_owner(sched, "Alpha", max_batch=max_batch,
                                    block_e=512)
    e = sched.trainers["Alpha"].model.num_entities
    rng = np.random.default_rng(seed + 2)
    queries = _tri(rng, n_queries, uni["Alpha"].num_entities, 4)

    # baseline: the same traffic with no concurrent federation
    warm, _ = closed_loop(tier, queries[:max_batch], concurrency=max_batch)
    nreqs, nwall = closed_loop(tier, queries, concurrency=2 * max_batch)
    assert tier.stats["failed"] == 0
    rows.append((f"serving.noticks.E{e}", nwall / n_queries * 1e6,
                 f"p99={_lat_ms(nreqs, 99):.1f}ms"))

    v_before = tier.version
    th = threading.Thread(target=lambda: sched.run(max_ticks=max_ticks))
    th.start()
    reqs: List[QueryRequest] = []
    # bounded traffic spread across the federation's lifetime: a free-running
    # loop would issue ~100k requests on fast hosts and blow the smoke budget
    # gap-throttled passes for the thread's WHOLE lifetime: the first tick
    # spends seconds in jit compile before any flip, so a fixed pass budget
    # would drain before version 1 ever lands; the backstop only guards
    # against a hung federation
    gap_s = pick(0.1, 0.02)
    passes, serve_s = 0, 0.0
    while th.is_alive() and passes < 2000:
        batch, w = closed_loop(tier, queries, concurrency=2 * max_batch)
        reqs.extend(batch)
        serve_s += w
        passes += 1
        time.sleep(gap_s)
    th.join()
    wall = serve_s
    tier.run_until_drained()
    flips = tier.version - v_before
    assert tier.stats["failed"] == 0, tier.stats
    assert tier.stats["publish_errors"] == 0, tier.stats
    assert flips >= 1, "federation ran but no version flip reached serving"
    versions = {r.version for r in reqs}
    rows.append((
        f"serving.with_ticks.E{e}", wall / max(len(reqs), 1) * 1e6,
        f"flips={flips},versions_served={len(versions)},"
        f"p99={_lat_ms(reqs, 99):.1f}ms,served={len(reqs)}",
    ))


def _bench_resilience(rows, *, entities, dim, n_queries, block_e, max_batch,
                      seed=0):
    """The chaos layer measured: armed-inert overhead (pinned ≈0), goodput
    and shed fraction under a seeded crash storm, hedging vs not under a
    straggler storm."""
    import jax

    from repro.core.faults import ServeFaultPlan
    from repro.kge.models import KGEModel, init_kge

    rng = np.random.default_rng(seed)
    n_rel = 8
    known = _tri(rng, 5000, entities, n_rel)
    model = KGEModel("transe", num_entities=entities, num_relations=n_rel,
                     dim=dim)
    params = init_kge(jax.random.PRNGKey(seed), model)
    queries = _tri(rng, n_queries, entities, n_rel)
    devs = jax.devices()
    ring = [devs[i % len(devs)] for i in range(2)]  # ≥2 slots: retry/hedge
    e = entities

    # pre-trace every bucket the closed loop can produce on BOTH replicas:
    # a cold replica paying jit compile mid-measurement would drown the
    # overhead and hedging comparisons in compile noise
    warm = [("rank", b) for b in (8, 16, max_batch)]

    def make(**kw):
        return KGEServingTier(params, model, known, block_e=block_e,
                              max_batch=max_batch, replicas=2, devices=ring,
                              warm_buckets=warm, **kw)

    # ---- faults-off vs armed-inert: the idle chaos layer costs ≈0 -------
    off = make()
    closed_loop(off, queries[:max_batch], concurrency=max_batch)  # warm
    oreqs, owall = closed_loop(off, queries, concurrency=2 * max_batch)
    armed = make(serve_faults="screen")
    closed_loop(armed, queries[:max_batch], concurrency=max_batch)
    areqs, awall = closed_loop(armed, queries, concurrency=2 * max_batch)
    for a, b in zip(oreqs, areqs):  # armed but inert ⇒ bit-identical
        np.testing.assert_array_equal(a.result, b.result)
    us_off = owall / n_queries * 1e6
    us_armed = awall / n_queries * 1e6
    overhead = us_armed / us_off - 1.0
    rows.append((f"serving.fault_off.E{e}", us_off, "chaos layer off"))
    rows.append((f"serving.fault_armed.E{e}", us_armed,
                 "armed, zero injection (output screens on)"))
    rows.append((f"serving.fault_overhead.E{e}", overhead,
                 f"armed/off - 1 = {overhead:+.3f} (≈0)"))
    if not smoke():
        assert abs(overhead) < 0.5, (
            f"armed-inert chaos layer overhead {overhead:+.2f} not ≈0"
        )

    # ---- goodput + shed fraction under a seeded crash storm -------------
    storm = make(
        serve_faults=ServeFaultPlan(crash=0.25, straggle=0.1, seed=7,
                                    delay=0.002),
        retry_limit=2, breaker_fails=3, probe_after=8,
    )
    closed_loop(storm, queries[:max_batch], concurrency=max_batch)
    base = storm.stats["submitted"]
    # burst-submit with a deadline ≈30% of the measured serial drain time
    # (+2 pre-expired sentinels): head-of-line requests serve, tail sheds
    deadline = max(0.002, 0.3 * n_queries * us_off * 1e-6)
    for q in queries:
        storm.submit_rank(q[:1], q[1:2], q[2:3], deadline=deadline)
    for q in queries[:2]:
        storm.submit_rank(q[:1], q[1:2], q[2:3], deadline=0.0)
    storm.run_until_drained()  # asserts served + shed + failed == submitted
    s = storm.stats
    n_storm = s["submitted"] - base
    goodput = s["served"] / s["submitted"]
    shed_frac = s["shed"] / n_storm
    rows.append((f"serving.storm.goodput.E{e}", goodput,
                 f"served/submitted under crash storm "
                 f"(retried={s['retried']},failed={s['failed']})"))
    rows.append((f"serving.storm.shed_frac.E{e}", shed_frac,
                 f"deadline={deadline * 1e3:.1f}ms burst, shed={s['shed']}"))
    assert 0.0 <= shed_frac < 1.0 and s["shed"] >= 2, s
    if not smoke():
        assert goodput >= 0.5, f"storm goodput collapsed: {goodput:.2f}"

    # ---- p99 with vs without hedging: one chronically slow replica ------
    # replica slot 1 straggles EVERY batch it takes (pinned, simulated
    # delay ≫ compute AND hedge_after ≫ per-batch compute — hedging below
    # normal batch latency just duplicates healthy work): without hedging,
    # its batches eat the full delay; with hedging they re-dispatch to the
    # fast replica after hedge_after. Deterministic, so full runs assert
    # the win.
    from repro.core.faults import ServeFault

    delay = pick(1.0, 0.03)
    plan = ServeFaultPlan(
        table={(s, 1): ServeFault("straggle", delay=delay)
               for s in range(4096)}
    )
    p99 = {}
    for label, hedge in (("nohedge", None), ("hedge", pick(0.25, 0.01))):
        t = make(serve_faults=plan, hedge_after=hedge)
        closed_loop(t, queries[:max_batch], concurrency=max_batch)
        reqs, _ = closed_loop(t, queries, concurrency=2 * max_batch)
        t.run_until_drained()
        assert t.stats["failed"] == 0, t.stats
        p99[label] = _lat_ms(reqs, 99)
        extra = (f"hedged={t.stats['hedged']}" if hedge is not None
                 else f"straggles={t.fault_counts.get('straggle', 0)}")
        rows.append((f"serving.storm.p99_ms.{label}.E{e}", p99[label],
                     f"slow replica delay={delay * 1e3:.0f}ms, {extra}"))
    if not smoke():
        assert p99["hedge"] < p99["nohedge"], (
            f"hedging did not cut straggler p99: {p99}"
        )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None, help="also append rows to this file")
    ap.add_argument("--entities", type=int, default=pick(1_000_000, 768))
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--queries", type=int, default=pick(256, 12))
    ap.add_argument("--percall", type=int, default=pick(16, 4))
    ap.add_argument("--block-e", type=int, default=pick(8192, 256))
    ap.add_argument("--max-batch", type=int, default=pick(64, 8))
    args = ap.parse_args(argv)

    rows: list = []
    _bench_traffic(
        rows, entities=args.entities, dim=args.dim, n_closed=args.queries,
        n_percall=args.percall, block_e=args.block_e,
        max_batch=args.max_batch,
    )
    _bench_with_ticks(
        rows, dim=pick(24, 16), steps=pick(30, 6), epochs=pick(10, 2),
        max_ticks=pick(3, 1), n_queries=pick(128, 10),
        max_batch=pick(32, 8),
    )
    _bench_resilience(
        rows, entities=pick(100_000, 768), dim=args.dim,
        n_queries=pick(128, 12), block_e=pick(8192, 256),
        max_batch=pick(32, 8),
    )

    for name, us, derived in rows:
        emit(name, us, derived)
    if args.csv:
        with open(args.csv, "a") as f:
            for name, us, derived in rows:
                f.write(f"{name},{us:.1f},{derived}\n")


if __name__ == "__main__":
    main()
