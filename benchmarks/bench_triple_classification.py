"""Fig. 4/5 — triple classification: independent baseline vs FKGE,
single base model (TransE) and mixed translation-family models."""
from __future__ import annotations

import time

from benchmarks.common import emit, pick, small_universe
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.eval import triple_classification_accuracy
from repro.kge.trainer import KGETrainer


def run(*, mixed_models: bool = False, ticks: int = None) -> None:
    ticks = pick(3, 1) if ticks is None else ticks
    local, update = pick(150, 2), pick(40, 2)
    tag = "fig5_multi" if mixed_models else "fig4_transe"
    kgs = small_universe(seed=0, n=pick(3, 2))
    fams = (
        {n: f for n, f in zip(kgs, ["transr", "transd", "transe"])}
        if mixed_models
        else {n: "transe" for n in kgs}
    )

    # --- independent baseline (same budget: local training only) ---------
    base_acc = {}
    for i, (name, kg) in enumerate(kgs.items()):
        tr = KGETrainer(kg, fams[name], dim=pick(32, 16), seed=i, margin=2.0)
        tr.train_epochs(local + ticks * update)  # same epoch budget as federated
        base_acc[name] = triple_classification_accuracy(tr.params, tr.model, kg)

    # --- FKGE (paper protocol: Alg. 1 backtracks on test) ------------------
    t0 = time.perf_counter()
    fed = FederationScheduler(
        kgs, families=fams, dim=pick(32, 16),
        ppat_cfg=PPATConfig(steps=pick(120, 6), seed=0),
        local_epochs=local, update_epochs=update, seed=0, score_split="test",
    )
    init = fed.initial_training()  # "time 0" of Fig. 4/5
    final = fed.run(max_ticks=ticks)
    dt = (time.perf_counter() - t0) * 1e6

    for name in kgs:
        fkge = triple_classification_accuracy(
            fed.trainers[name].params, fed.trainers[name].model, kgs[name]
        )
        gain_self = (final[name] - init[name]) * 100  # the paper's Fig. 4 metric
        gain_vs_base = (fkge - base_acc[name]) * 100  # equal-budget independent
        emit(
            f"{tag}.{name}", dt / len(kgs),
            f"time0={init[name]:.3f};fkge={final[name]:.3f};gain={gain_self:+.2f}pp;"
            f"indep_baseline={base_acc[name]:.3f};vs_baseline={gain_vs_base:+.2f}pp",
        )


def main() -> None:
    run(mixed_models=False)
    run(mixed_models=True)


if __name__ == "__main__":
    main()
