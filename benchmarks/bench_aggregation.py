"""Tab. 7 — FKGE (with virtual entities G(N(X))) vs FKGE-simple (without)."""
from __future__ import annotations

import time

from benchmarks.common import emit, pick, small_universe
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.eval import triple_classification_accuracy


def main() -> None:
    for label, use_virtual in (("fkge_simple", False), ("fkge", True)):
        kgs = small_universe(seed=0, n=pick(3, 2))
        t0 = time.perf_counter()
        fed = FederationScheduler(
            kgs, dim=pick(32, 16), ppat_cfg=PPATConfig(steps=pick(120, 6), seed=0),
            use_virtual=use_virtual, local_epochs=pick(150, 2),
            update_epochs=pick(40, 2), seed=0,
        )
        fed.initial_training()
        fed.run(max_ticks=pick(3, 1))
        dt = (time.perf_counter() - t0) * 1e6
        accs = {
            n: triple_classification_accuracy(
                fed.trainers[n].params, fed.trainers[n].model, kgs[n]
            )
            for n in kgs
        }
        emit(
            f"tab7.{label}", dt,
            ";".join(f"{n}={a:.3f}" for n, a in accs.items()),
        )


if __name__ == "__main__":
    main()
