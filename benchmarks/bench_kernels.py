"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp oracle parity +
wall-time of the jnp path (the interpret path times Python, not TPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pick, timed


def main() -> None:
    key = jax.random.PRNGKey(0)

    # flash attention
    from repro.kernels.flash_attention import attention_ref, flash_attention

    s, bq = pick((2, 4, 256, 64), (1, 2, 128, 32)), pick(128, 64)
    q = jax.random.normal(key, s)
    k = jax.random.normal(jax.random.PRNGKey(1), (s[0], s[1] // 2, s[2], s[3]))
    v = jax.random.normal(jax.random.PRNGKey(2), (s[0], s[1] // 2, s[2], s[3]))
    out, _ = timed(lambda: np.asarray(flash_attention(q, k, v, block_q=bq, block_k=bq)))
    ref, dt = timed(lambda: np.asarray(attention_ref(q, k, v)), repeats=3)
    err = float(np.abs(out - ref).max())
    emit("kernel.flash_attention", dt,
         f"max_err={err:.2e};shape={'x'.join(map(str, s))}")

    # triple score
    from repro.kernels.triple_score import pairwise_scores, pairwise_scores_ref

    qq = jax.random.normal(key, (pick(64, 16), 100))
    ent = jax.random.normal(jax.random.PRNGKey(3), (pick(2048, 256), 100))
    out, _ = timed(lambda: np.asarray(pairwise_scores(qq, ent)))
    ref, dt = timed(lambda: np.asarray(pairwise_scores_ref(qq, ent)), repeats=3)
    emit("kernel.triple_score", dt,
         f"max_err={float(np.abs(out-ref).max()):.2e};"
         f"shape={qq.shape[0]}x{ent.shape[0]}x100")

    # csls
    from repro.kernels.csls import csls_matrix, csls_matrix_ref

    a = jax.random.normal(key, (pick(256, 64), 64))
    b = jax.random.normal(jax.random.PRNGKey(4), (pick(256, 64), 64))
    out, _ = timed(lambda: np.asarray(csls_matrix(a, b)))
    ref, dt = timed(lambda: np.asarray(csls_matrix_ref(a, b)), repeats=3)
    emit("kernel.csls", dt,
         f"max_err={float(np.abs(out-ref).max()):.2e};"
         f"shape={a.shape[0]}x{b.shape[0]}x64")

    # ssd
    from repro.kernels.ssd_scan import ssd_chunk_kernel_apply
    from repro.models.ssm import ssd

    t = pick(256, 128)
    x = jax.random.normal(key, (2, t, 4, 32))
    dtt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5), (2, t, 4)))
    aa = -jnp.exp(jax.random.normal(jax.random.PRNGKey(6), (4,)) * 0.2)
    bm = jax.random.normal(jax.random.PRNGKey(7), (2, t, 1, 32)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(8), (2, t, 1, 32)) * 0.3
    (yk, sk), _ = timed(lambda: jax.tree.map(np.asarray, ssd_chunk_kernel_apply(x, dtt, aa, bm, cm, chunk=64)))
    (yr, sr), dt = timed(lambda: jax.tree.map(np.asarray, ssd(x, dtt, aa, bm, cm, 64)), repeats=3)
    emit("kernel.ssd_scan", dt,
         f"max_err={float(np.abs(yk-yr).max()):.2e};shape=2x{t}x4x32")


if __name__ == "__main__":
    main()
