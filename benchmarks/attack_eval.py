"""Measured leakage vs accounted ε — the attack harness (ISSUE 7 tentpole).

The accountant says "λ=0.05 costs ε=…"; this suite checks that the number
means something by *attacking* the protocol's release surfaces and plotting
attack success next to ε as the DP noise level sweeps:

  * ``attacks.mi_vote.lam_*`` — membership inference against the PATE vote
    channel, the only surface through which a client learns about the
    host's private Y. A small aligned set (K rows) makes the teachers
    overfit their real pool; the attacker queries the *noisy* vote labels
    (``repro.core.ppat.noisy_vote_labels``) on candidate rows and averages
    over rounds. Because noise enters only this label channel, attack AUC
    is monotone in the noise level by construction — asserted below: more
    noise (smaller λ) ⇒ lower AUC, alongside the shrinking accounted ε.
  * ``attacks.recon.lam_*`` — embedding reconstruction (procrustes) of the
    host's private rows from the released synthesized rows, plus the
    client-geometry cosine (how much of X survives in G(X) — high, since
    W starts at identity and is kept near-orthogonal; reported, not a DP
    violation: X is the *sender's* data).
  * ``attacks.mi_triples.raw_y`` — triple-level membership inference
    against the raw (never released) host table: the upper-bound row that
    calibrates what the TransE-offset attack could extract if the host
    table itself leaked.

The λ=0 (no noise) configuration trains a *different* protocol run — clean
labels change the teacher/generator trajectory and deterministic {0,1}
votes quantize under tie-averaged ranks — so the monotonicity assertion is
anchored at the noisiest-vs-least-noisy λ>0 pair, and λ=0 is reported as
its own row.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, pick
from repro.core.alignment import AlignmentRegistry
from repro.core.attacks import advantage, auc, membership_inference, reconstruction_attack
from repro.core.ppat import PPATConfig, noisy_vote_labels, train_ppat
from repro.kge.data import synthesize_universe
from repro.kge.trainer import KGETrainer


def main() -> None:
    stats = [("Alpha", 14, 110000, 380000), ("Beta", 10, 90000, 300000)]
    kgs = synthesize_universe(
        seed=0, scale=1 / 200, kg_stats=stats,
        alignments=[("Alpha", "Beta", 60000)],
    )
    reg = AlignmentRegistry.from_kgs(kgs)
    idx_c, idx_h = reg.entities("Alpha", "Beta")
    ctr = KGETrainer(kgs["Alpha"], "transe", dim=16, seed=0)
    htr = KGETrainer(kgs["Beta"], "transe", dim=16, seed=1)
    # NOT scaled down in smoke: the vote-channel membership signal rides on
    # teachers overfitting a *structured* KGE table — at near-init tables
    # the member/nonmember vote gap survives even drowning noise (measured:
    # epochs=4 leaves AUC≈0.62 at λ=0.01) and the monotonicity assert below
    # loses its teeth. Smoke trims the λ sweep instead.
    epochs = 30
    ctr.train_epochs(epochs)
    htr.train_epochs(epochs)

    # --- upper-bound row: triple-level MI against the RAW host table -----
    ys_full = np.asarray(htr.get_entity_embeddings(idx_h))
    al_full = set(int(i) for i in idx_h)
    kg = kgs["Beta"]

    def _aligned_triples(tri):
        m = np.fromiter(
            ((int(h) in al_full and int(t) in al_full) for h, _, t in tri),
            bool, len(tri),
        )
        return tri[m]

    t0 = time.perf_counter()
    mem = _aligned_triples(kg.train)
    non = _aligned_triples(np.concatenate([kg.valid, kg.test]))
    perm = np.random.default_rng(0).permutation(len(mem))
    bg, scored = mem[perm[: len(mem) // 2]], mem[perm[len(mem) // 2 :]]
    raw_rel = {int(e): ys_full[i] for i, e in enumerate(idx_h)}
    mi_raw = membership_inference(raw_rel, scored, non, bg)
    emit(
        "attacks.mi_triples.raw_y", (time.perf_counter() - t0) * 1e6,
        f"auc={mi_raw['auc']:.4f};adv={mi_raw['advantage']:.4f};"
        f"n={mi_raw['n_member']}+{mi_raw['n_nonmember']}",
    )

    # --- vote-channel MI + reconstruction, swept over the DP noise λ -----
    # tiny aligned pool so the 4 teachers overfit their real rows; the
    # membership signal is the member-vs-nonmember vote-rate gap
    K = 32
    x = ctr.get_entity_embeddings(idx_c[:K])
    y = htr.get_entity_embeddings(idx_h[:K])
    ys = np.asarray(y)
    members = set(int(i) for i in idx_h[:K])
    others = np.array(
        [i for i in range(htr.model.num_entities) if i not in members]
    )[:200]
    y_non = htr.get_entity_embeddings(others)

    steps = 300   # teacher overfit needs the full steps even in smoke
    rounds = 32   # enough averaging that the λ=1 signal clears the noise
    # noise = Lap(1/λ): λ=1.0 least noise … 0.01 drowns the channel; 0.0
    # disables DP entirely (reported, excluded from the monotonicity chain)
    lams = pick(
        [("0", 0.0), ("1", 1.0), ("0.3", 0.3), ("0.1", 0.1), ("0.01", 0.01)],
        [("1", 1.0), ("0.01", 0.01)],
    )
    curve = []  # (lam, auc) for λ>0, sweep order = decreasing λ
    for lam_name, lam in lams:
        t0 = time.perf_counter()
        cfg = PPATConfig(steps=steps, lam=lam, seed=0)
        cl, ho, hist = train_ppat(x, y, cfg, key=jax.random.PRNGKey(0))
        pos = noisy_vote_labels(
            ho.params, y, lam, jax.random.PRNGKey(7), rounds=rounds
        )
        neg = noisy_vote_labels(
            ho.params, y_non, lam, jax.random.PRNGKey(7), rounds=rounds
        )
        a = auc(pos, neg)
        eps = hist["epsilon"] if lam > 0 else float("inf")
        dt = (time.perf_counter() - t0) * 1e6
        emit(
            f"attacks.mi_vote.lam_{lam_name}", dt,
            f"auc={a:.4f};adv={advantage(a):.4f};eps={eps:.2f}",
        )
        synth = np.asarray(cl.generate(x))
        rec_y = reconstruction_attack(synth, ys)
        rec_x = reconstruction_attack(synth, np.asarray(x))
        emit(
            f"attacks.recon.lam_{lam_name}", dt,
            f"cos_y={rec_y['cosine']:.4f};mse_y={rec_y['mse']:.4f};"
            f"cos_x={rec_x['cosine']:.4f}",
        )
        if lam > 0:
            curve.append((lam, a, eps))

    # the measured-privacy contract: more noise ⇒ lower attack AUC and a
    # smaller accounted ε. Small tolerance absorbs rank-tie jitter between
    # adjacent λs; the end-to-end drop must be decisive.
    for (l_hi, a_hi, e_hi), (l_lo, a_lo, e_lo) in zip(curve, curve[1:]):
        assert a_lo <= a_hi + 0.03, (
            f"vote-channel MI AUC rose with more noise: λ={l_hi}→{l_lo} "
            f"auc {a_hi:.4f}→{a_lo:.4f}"
        )
        assert e_lo < e_hi, f"accounted ε rose with more noise: {e_hi}→{e_lo}"
    drop = curve[0][1] - curve[-1][1]
    assert drop >= 0.08, (
        f"noise sweep λ={curve[0][0]}→{curve[-1][0]} did not suppress the "
        f"vote-channel attack: auc {curve[0][1]:.4f}→{curve[-1][1]:.4f}"
    )


if __name__ == "__main__":
    main()
