"""§Perf hillclimb driver: lower named variants of the three chosen pairs and
record the corrected roofline terms per iteration.

  PYTHONPATH=src python benchmarks/perf_iterations.py [--pair qwen3|jamba|kimi]

Writes benchmarks/perf_results.json. Each entry is one
hypothesis → change → measure cycle; the narrative lives in EXPERIMENTS.md.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time

import jax

from repro.configs import TrainConfig, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.workloads import default_train_config, make_workload
from repro.utils.hlo import (
    collective_bytes,
    cost_analysis_dict,
    loop_aware_collective_bytes,
    peak_memory_bytes,
)
from repro.utils.roofline import roofline_terms
from repro.configs.base import INPUT_SHAPE_BY_NAME

HERE = os.path.dirname(__file__)


def measure(cfg, shape_name, tcfg=None, label="", layout="tp"):
    mesh = make_production_mesh()
    shape = INPUT_SHAPE_BY_NAME[shape_name]
    wl = make_workload(cfg, shape_name, mesh, tcfg=tcfg, layout=layout)
    t0 = time.perf_counter()
    with mesh:
        compiled = (
            jax.jit(wl["fn"], in_shardings=wl["in_shardings"],
                    out_shardings=wl["out_shardings"])
            .lower(*wl["args"]).compile()
        )
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    txt = compiled.as_text()
    res = {
        "arch": cfg.name, "shape": shape_name, "variant": label,
        "compile_s": round(time.perf_counter() - t0, 1),
        "memory": {"peak_bytes_per_device": peak_memory_bytes(mem),
                   "argument_bytes_per_device": int(mem.argument_size_in_bytes)},
        "cost": {"flops": float(cost.get("flops", 0.0)),
                 "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        "collectives": collective_bytes(txt),
        "collectives_corrected": loop_aware_collective_bytes(txt),
    }
    res["roofline"] = roofline_terms(cfg, shape, res, chips=mesh.devices.size)
    rf = res["roofline"]
    print(f"[{cfg.name} × {shape_name} | {label}] "
          f"compute={rf['compute_s']:.3e} memory={rf['memory_s']:.3e} "
          f"collective={rf['collective_s']:.3e} → {rf['bottleneck']} "
          f"| coll/dev={res['collectives_corrected']['total']/2**30:.1f}GiB "
          f"peak={res['memory']['peak_bytes_per_device']/2**30:.1f}GiB")
    return res


def pair_qwen3(results):
    cfg = get_config("qwen3-0.6b")
    # v1: shard-preserving microbatch split + seq-chunked CE (code default now)
    results.append(measure(cfg, "train_4k", label="v1_shard_friendly_accum"))
    # v2: remat policy saves dot outputs → bwd recompute skips TP collectives
    results.append(measure(cfg.replace(remat_policy="dots"), "train_4k",
                           label="v2_remat_dots"))
    # v3: fewer, larger microbatches (4): param-sized collectives ×4 less
    tcfg = default_train_config(cfg, INPUT_SHAPE_BY_NAME["train_4k"])
    tcfg4 = TrainConfig(**{**tcfg.__dict__, "microbatches": 4})
    results.append(measure(cfg.replace(remat_policy="dots"), "train_4k",
                           tcfg=tcfg4, label="v3_mb4"))
    # v4: drop tensor parallelism entirely — 0.6B params replicate; batch over
    # all 256 devices, single microbatch → ONE gradient all-reduce per step.
    tcfg_dp = TrainConfig(**{**tcfg.__dict__, "microbatches": 1, "ce_chunk": 512})
    results.append(measure(cfg, "train_4k", tcfg=tcfg_dp,
                           label="v4_pure_dp", layout="dp"))


import dataclasses as _dc


def pair_jamba(results):
    cfg = get_config("jamba-1.5-large-398b")
    cfg_gather = cfg.replace(moe=_dc.replace(cfg.moe, impl="gather"))
    results.append(measure(cfg_gather, "prefill_32k", label="v1_gather_moe"))
    results.append(measure(cfg_gather.replace(remat_policy="dots"), "prefill_32k",
                           label="v2_remat_dots"))
    # v3/v4 combined in the production config: EP all-to-all + late psum
    results.append(measure(cfg, "prefill_32k", label="v4_a2a_latepsum"))


def pair_kimi(results):
    cfg = get_config("kimi-k2-1t-a32b")
    cfg_gather = cfg.replace(
        moe=_dc.replace(cfg.moe, impl="gather", route_groups=0)
    )
    shape = INPUT_SHAPE_BY_NAME["train_4k"]
    tcfg = default_train_config(cfg, shape)
    results.append(measure(cfg_gather, "train_4k", label="v1_shard_friendly_accum"))
    tcfg_bf16 = TrainConfig(**{**tcfg.__dict__, "moment_dtype": "bfloat16"})
    results.append(measure(cfg_gather, "train_4k", tcfg=tcfg_bf16,
                           label="v2_bf16_moments"))
    cfg_a2a = cfg.replace(moe=_dc.replace(cfg.moe, impl="alltoall", route_groups=0))
    results.append(measure(cfg_a2a, "train_4k", tcfg=tcfg_bf16,
                           label="v4_moe_alltoall"))
    # v6 = production config: + node-limited routing (G=4) + late psum
    results.append(measure(cfg, "train_4k", tcfg=tcfg_bf16,
                           label="v6_a2a_grp4_latepsum"))
    cfg_g2 = cfg.replace(moe=_dc.replace(cfg.moe, route_groups=2))
    results.append(measure(cfg_g2, "train_4k", tcfg=tcfg_bf16,
                           label="v7_grp2_refuted"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", default="all")
    ap.add_argument("--out", default=os.path.join(HERE, "perf_results.json"))
    args = ap.parse_args()
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    if args.pair in ("all", "qwen3"):
        pair_qwen3(results)
    if args.pair in ("all", "jamba"):
        pair_jamba(results)
    if args.pair in ("all", "kimi"):
        pair_kimi(results)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
