"""Fig. 7 — time cost of PPAT vs KGEmb-Update as the number of aligned
entities grows (paper's scalability claim: PPAT cost is linear in #aligned,
KGEmb-Update roughly constant)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, pick, small_universe
from repro.core.ppat import PPATConfig, train_ppat
from repro.kge.trainer import KGETrainer


def main() -> None:
    kgs = small_universe(seed=0, n=2)
    names = list(kgs)
    a, b = kgs[names[0]], kgs[names[1]]
    dim = pick(32, 16)
    tra = KGETrainer(a, "transe", dim=dim, seed=0)
    trb = KGETrainer(b, "transe", dim=dim, seed=1)
    tra.train_epochs(pick(60, 2))
    trb.train_epochs(pick(60, 2))
    ia, ib = a.aligned_with(b)
    cfg = PPATConfig(steps=pick(60, 4), seed=0)

    rng = np.random.default_rng(0)
    for ratio in (0.25, 0.5, 0.75, 1.0):
        k = max(8, int(len(ia) * ratio))
        sel = rng.choice(len(ia), min(k, len(ia)), replace=False)
        x = tra.get_entity_embeddings(ia[sel])
        y = trb.get_entity_embeddings(ib[sel])

        t0 = time.perf_counter()
        train_ppat(x, y, cfg)
        t_ppat = time.perf_counter() - t0

        t0 = time.perf_counter()
        trb.train_epochs(pick(20, 1))  # the KGEmb-Update retrain
        t_update = time.perf_counter() - t0

        emit(
            f"fig7.aligned_{len(sel)}", t_ppat * 1e6,
            f"ppat_s={t_ppat:.2f};kgemb_update_s={t_update:.2f};"
            f"ratio={t_ppat/(t_ppat+t_update)*100:.0f}%",
        )
    # communication cost claim (§4.4): batch·d fwd + d·d bwd per PPAT batch
    d = dim
    comm_bits = (cfg.batch * d + d * d) * 64
    emit("fig7.comm_per_batch", 0.0, f"bits={comm_bits};Mb={comm_bits/1e6:.3f}")


if __name__ == "__main__":
    main()
