"""§Roofline — prints the roofline table from the saved dry-run artifacts
(benchmarks/dryrun_results.json, produced by launch/dryrun.py --all
--both-meshes). No compilation happens here; this reads the artifact."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

HERE = os.path.dirname(__file__)
CANDIDATES = ["dryrun_results.json", "dryrun_1pod.json"]


def main() -> None:
    path = None
    for c in CANDIDATES:
        p = os.path.join(HERE, c)
        if os.path.exists(p):
            path = p
            break
    if path is None:
        emit("roofline.missing", 0.0, "run launch/dryrun.py --all first")
        return
    with open(path) as f:
        results = json.load(f)
    for r in results:
        if r["status"] != "ok":
            if r["status"] == "skipped":
                emit(f"roofline.{r['arch']}.{r['shape']}", 0.0, "skipped:" + r["why"][:40])
            continue
        rf = r["roofline"]
        mesh = "2pod" if r.get("multi_pod") else "1pod"
        emit(
            f"roofline.{r['arch']}.{r['shape']}.{mesh}",
            r["compile_s"] * 1e6,
            f"compute_s={rf['compute_s']:.2e};memory_s={rf['memory_s']:.2e};"
            f"collective_s={rf['collective_s']:.2e};bottleneck={rf['bottleneck']};"
            f"useful_flops={rf['useful_flops_ratio']*100:.0f}%;"
            f"peak_GiB={r['memory']['peak_bytes_per_device']/2**30:.2f}",
        )


if __name__ == "__main__":
    main()
