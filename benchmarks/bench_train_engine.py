"""Device-resident training engine vs the seed dense host-loop path.

Emits ``train_engine.{old|new}.E{N}`` rows with µs/optimizer-step at
E ∈ {10k, 100k}: ``old`` is the seed path (numpy sampling per epoch + dense
O(E·d) updates per minibatch), ``new`` is the compiled multi-epoch scan with
on-device sampling and sparse (touched-rows-only) updates. The acceptance bar
is ≥ 5× at E = 100k on the CI backend.

Parity is asserted in-bench: before timing, one scanned sparse epoch must be
bit-identical to the dense ``_epoch`` on identical batches at each E.
``--csv <path>`` additionally records the rows to a CSV file.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, pick
from repro.kge.engine import shape_spec, sparse_epoch
from repro.kge.models import KGEModel, init_kge
from repro.kge.trainer import KGETrainer, _epoch


@dataclass
class _FakeKG:
    """Minimal KG shim: the trainer only reads ``train`` + ``num_entities``."""

    num_entities: int
    num_relations: int
    train: np.ndarray


def _make(e: int, *, n_triples: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    tri = np.stack(
        [
            rng.integers(0, e, n_triples),
            rng.integers(0, 8, n_triples),
            rng.integers(0, e, n_triples),
        ],
        axis=1,
    ).astype(np.int32)
    return _FakeKG(e, 8, tri)


def _assert_parity(kg: _FakeKG, dim: int, batch: int) -> None:
    """One scanned sparse epoch == the dense epoch, bit-level, on the same
    pos/neg batches (duplicates included via 1:1 corruption collisions)."""
    m = KGEModel("transe", kg.num_entities, kg.num_relations, dim)
    p = init_kge(jax.random.PRNGKey(0), m)
    rng = np.random.default_rng(0)
    nb = min(8, len(kg.train) // batch)
    pos = kg.train[: nb * batch].reshape(nb, batch, 3)
    from repro.kge.data import corrupt_triples

    neg = corrupt_triples(rng, pos.reshape(-1, 3), kg.num_entities)
    pos_j = jnp.asarray(pos)
    neg_j = jnp.asarray(neg.reshape(nb, batch, 3))
    lr = jnp.float32(0.5)
    dense, dl = _epoch(p, m, pos_j, neg_j, lr)
    sparse, sl = sparse_epoch(p, shape_spec(m), pos_j, neg_j, lr)
    assert np.array_equal(np.asarray(dl), np.asarray(sl)), (dl, sl)
    for k in dense:
        assert np.array_equal(np.asarray(dense[k]), np.asarray(sparse[k])), k


def _steps_per_run(kg: _FakeKG, batch: int, epochs: int) -> int:
    from repro.kge.engine import pad_triples

    nb_new = pad_triples(jnp.asarray(kg.train), batch).shape[0] // batch
    return epochs * nb_new


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None, help="also append rows to this file")
    ap.add_argument("--dim", type=int, default=32)
    # default lands on a power-of-two minibatch count (6400/100 = 64), so the
    # engine's pow2 triple padding is a no-op and both paths time the same
    # number of optimizer steps
    ap.add_argument("--triples", type=int, default=pick(6400, 400))
    ap.add_argument("--epochs", type=int, default=pick(3, 1))
    ap.add_argument("--batch", type=int, default=100)
    ap.add_argument("--sizes", type=int, nargs="*",
                    default=pick([10_000, 100_000], [768]))
    args = ap.parse_args(argv)

    rows = []
    for e in args.sizes:
        kg = _make(e, n_triples=args.triples)
        _assert_parity(kg, args.dim, args.batch)  # parity gates the numbers

        def run(impl: str) -> float:
            tr = KGETrainer(kg, "transe", dim=args.dim, seed=0,
                            batch_size=args.batch)
            # warm-up with the SAME epoch count: the engine specializes the
            # scan on it, and compile time must stay out of the timed region
            tr.train_epochs(args.epochs, impl=impl)
            t0 = time.perf_counter()
            tr.train_epochs(args.epochs, impl=impl)
            return time.perf_counter() - t0

        nb_old = len(kg.train) // args.batch
        dt_old = run("reference")
        dt_new = run("xla")
        us_old = dt_old * 1e6 / (args.epochs * nb_old)
        us_new = dt_new * 1e6 / _steps_per_run(kg, args.batch, args.epochs)
        speedup = us_old / us_new
        rows.append((f"train_engine.old.E{e}", us_old, f"dense O(E·d)/step"))
        rows.append((f"train_engine.new.E{e}", us_new, "sparse device scan"))
        # value = the ratio itself (dimensionless) so the committed JSON
        # baselines track the speedup machine-checkably, not a latency
        rows.append(
            (f"train_engine.speedup.E{e}", speedup, f"speedup={speedup:.1f}x")
        )

    for name, us, derived in rows:
        emit(name, us, derived)
    if args.csv:
        with open(args.csv, "a") as f:
            for name, us, derived in rows:
                f.write(f"{name},{us:.1f},{derived}\n")


if __name__ == "__main__":
    main()
