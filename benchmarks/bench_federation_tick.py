"""Batched federation tick engine vs the serial reference tick.

Builds an all-pairs-aligned federation of ``--owners`` KGs (E = 10k entities
each by default), trains them locally, then drives three schedulers from the
same seed — ``tick_impl="reference"`` (the serial per-owner loop),
``tick_impl="batched"`` with ``tick_placement="single"`` (per-signature
entry programs on one device), and ``tick_placement="sharded"`` (signature
buckets shard_map'ed across ``jax.devices()``) — through identical tick
sequences.

Parity is asserted in-bench before any number is reported: all schedulers
must produce the same accept/reject decisions, the same backtrack scores and
ε history, and bit-identical final embeddings (the engine's contract; also
pinned in tier-1 by ``tests/test_tick_engine.py`` /
``tests/test_tick_sharded.py``).

Timing: warm-up ticks run first until the batched program cache stops
growing (compiles stay out of the timed region — steady-state federation
reuses the cached per-signature programs, and the warm ticks also populate
the owner-resident per-device input caches so the timed sharded ticks
measure the steady state: zero re-staging of cached immutable inputs),
then ``--ticks`` matched ticks are timed for each impl. Emits
``tick_engine.{reference|batched|sharded}`` µs-per-tick rows plus the
speedups; EVERY row's derived column records the actual device count and
placement mode, and ``tick_engine.sharded_devices`` lands in the JSON
artifact. The acceptance bar for the batched engine is ≥ 3× at 8 owners on
CPU CI. In a single-device process the sharded run degenerates to one
device — the ``make bench-tick`` / ``make bench-json`` targets force 8
host devices via ``XLA_FLAGS`` so the committed sharded rows measure real
multi-device placement. ``--csv <path>`` appends the rows to a file.

A fourth run times the batched engine with the fault-injection layer ARMED
but inert (``tick_faults="on"``: zero fault rates, norm screens active on
every exchanged embedding) and emits ``tick_engine.fault_armed`` plus the
``tick_engine.fault_overhead`` ratio vs the faults-off batched row. The
faults-OFF rows themselves are the proof that the fault hooks cost nothing
when disabled: they time the exact default path (``tick_faults`` unset ⇒
no injector, no screens, no per-entry draws) and are directly comparable
against the committed pre-fault-layer ``BENCH_federation_tick.json``
baseline keys (``tick_engine.batched.N8.E10000`` etc.). The armed run is
held to the same bit-parity contract — an inert injector must not perturb
a single decision, score, ε, or embedding.
A straggler-storm pair closes the run: one pinned slow owner
(``FaultPlan.slow_owner``, simulated ``--straggle-delay`` seconds on every
entry it hosts, no deadline — the owner is late, never failed) drives two
fresh schedulers through the same storm, once under the lockstep barrier
(``tick_sync="barrier"``) and once streamed (``tick_sync="stream"``, a
staleness bound no draw can exceed, so both runs take bit-identical
decisions and the comparison is work-for-work). The reported metric is the
*simulated* fast-owner completion time (mean over the non-straggler
owners, from the scheduler's simulated-time accounting): under the barrier
every owner inherits the straggler's delay every tick, while the streamed
scheduler lets disjoint owner groups advance and only the entries that
actually consume the straggler's published views wait for them.
``tick_engine.straggler_speedup`` is asserted > 1.2 whenever ≥ 4 owners
run (at 2 owners every handshake touches the straggler and there is
nothing to stream past).
Under ``REPRO_BENCH_SMOKE`` (``make bench-smoke``) the defaults shrink to
N=2 owners / E=800 so the whole path — parity asserts included — runs as a
tier-1 gate.
"""
from __future__ import annotations

import argparse
import math
import time

import numpy as np

from benchmarks.common import emit, pick
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.core.tick_engine import tick_program_cache_size
from repro.kge.data import synthesize_universe


def _build_universe(owners: int, entities: int, triples: int, aligned: int):
    names = [f"K{i}" for i in range(owners)]
    scale = 1 / 400
    stats = [(n, 8, int(entities / scale), int(triples / scale)) for n in names]
    aligns = [
        (names[i], names[j], int(aligned / scale))
        for i in range(owners)
        for j in range(i + 1, owners)
    ]
    return synthesize_universe(
        seed=0, scale=scale, kg_stats=stats, alignments=aligns,
        density_boost=2.0,
    )


def _make(kgs, args, **defense):
    return FederationScheduler(
        kgs, dim=args.dim, ppat_cfg=PPATConfig(steps=args.ppat_steps, seed=0),
        local_epochs=args.local_epochs, update_epochs=args.update_epochs,
        seed=0, score_metric=args.metric, score_max_test=args.max_test,
        batch_size=args.batch, **defense,
    )


def _assert_parity(ref, bat) -> None:
    assert len(ref.events) == len(bat.events)
    for r, b in zip(ref.events, bat.events):
        assert (r.tick, r.host, r.client, r.kind, r.accepted) == (
            b.tick, b.host, b.client, b.kind, b.accepted
        ), (r, b)
        assert r.score_before == b.score_before and r.score_after == b.score_after, (r, b)
        assert (math.isnan(r.epsilon) and math.isnan(b.epsilon)) or (
            r.epsilon == b.epsilon
        ), (r, b)
    assert ref.best_score == bat.best_score
    for n in ref.trainers:
        for k in ref.trainers[n].params:
            assert np.array_equal(
                np.asarray(ref.trainers[n].params[k]),
                np.asarray(bat.trainers[n].params[k]),
            ), f"{n}.{k} diverged between tick impls"


def _assert_parity_streamed(bar, strm) -> None:
    """Barrier vs streamed work-for-work parity: the streamed pass emits
    the same events as the barrier tick in LEVEL order (a permutation of
    plan order), so decisions are compared under a canonical sort; scores,
    ε, best scores, and final embeddings must still match bitwise."""
    def keyed(fed):
        return sorted(
            ((e.tick, e.host, e.client or "", e.kind, e.accepted,
              e.score_before, e.score_after, e.epsilon)
             for e in fed.events),
            key=lambda t: t[:4],
        )

    a, b = keyed(bar), keyed(strm)
    assert len(a) == len(b)
    for r, s in zip(a, b):
        assert r[:5] == s[:5], (r, s)
        assert r[5] == s[5] and r[6] == s[6], (r, s)
        assert (math.isnan(r[7]) and math.isnan(s[7])) or r[7] == s[7], (r, s)
    assert bar.best_score == strm.best_score
    for n in bar.trainers:
        for k in bar.trainers[n].params:
            assert np.array_equal(
                np.asarray(bar.trainers[n].params[k]),
                np.asarray(strm.trainers[n].params[k]),
            ), f"{n}.{k} diverged between barrier and streamed scheduling"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None, help="also append rows to this file")
    ap.add_argument("--owners", type=int, default=pick(8, 2))
    ap.add_argument("--entities", type=int, default=pick(10_000, 800))
    ap.add_argument("--triples", type=int, default=pick(2_000, 400))
    ap.add_argument("--aligned", type=int, default=pick(700, 60))
    ap.add_argument("--dim", type=int, default=pick(32, 16))
    ap.add_argument("--ppat-steps", type=int, default=pick(60, 6))
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--update-epochs", type=int, default=2)
    ap.add_argument("--batch", type=int, default=pick(256, 64))
    ap.add_argument("--metric", default="hit10", choices=["hit10", "accuracy"])
    ap.add_argument("--max-test", type=int, default=pick(48, 12))
    ap.add_argument("--warm-ticks", type=int, default=pick(8, 2))
    ap.add_argument("--ticks", type=int, default=pick(2, 1),
                    help="timed ticks per impl")
    ap.add_argument("--straggle-ticks", type=int, default=pick(6, 2),
                    help="storm length for the one-slow-owner scenario")
    ap.add_argument("--straggle-delay", type=float, default=30.0,
                    help="simulated seconds the slow owner adds per entry")
    args = ap.parse_args(argv)

    kgs = _build_universe(args.owners, args.entities, args.triples, args.aligned)

    import jax

    ndev = len(jax.devices())
    # (scheduler key, tick_impl, tick_placement, tick_faults)
    # "on" arms the fault layer with zero rates + active norm screens — the
    # hooks-armed-but-idle cost; None is the default faults-off fast path.
    # (scheduler key, tick_impl, tick_placement, tick_faults, parity)
    # "adversary" times the batched engine under an ACTIVE poisoning storm
    # with the defense stack armed (robust median aggregation + cosine
    # screen + reputation gating) — the cost of Byzantine robustness while
    # actually under attack. It takes different accept decisions than the
    # clean runs by design, so it is excluded from the parity asserts (the
    # adversary's own engine-parity contract is pinned by
    # tests/test_adversary.py and benchmarks/attack_smoke.py).
    runs = [
        ("reference", "reference", None, None, True),
        ("batched", "batched", "single", None, True),
        ("sharded", "batched", "sharded", None, True),
        ("armed", "batched", "single", "on", True),
        ("adversary", "batched", "single", None, False),
    ]
    feds = {}
    for key, _, _, _, _ in runs:
        defense = {}
        if key == "adversary":
            defense = dict(
                tick_adversary="drift=0.5,seed=9,strength=1.0,frac=0.4",
                robust_agg="median", cos_screen=0.5,
            )
        feds[key] = _make(kgs, args, **defense)
        feds[key].initial_training()

    def _one_tick(key, impl, placement, faults):
        feds[key].run(
            max_ticks=1, tick_impl=impl, tick_placement=placement,
            tick_faults=faults,
        )

    # warm-up: compile every program each impl will use; stop early once the
    # tick-program cache has stopped growing for TWO consecutive rounds
    # (plan composition keeps evolving as queues drain, and a signature's
    # first singleton/self-train appearance can compile ticks after the
    # initial signature set saturates — the timed region must measure the
    # steady state, not a late compile)
    progs, stable = -1, 0
    for w in range(args.warm_ticks):
        for key, impl, placement, faults, _ in runs:
            _one_tick(key, impl, placement, faults)
        for key, _, _, _, parity in runs[1:]:
            if parity:
                _assert_parity(feds["reference"], feds[key])
        stable = stable + 1 if tick_program_cache_size() == progs else 0
        if stable >= 2:
            break
        progs = tick_program_cache_size()

    timed = {key: 0.0 for key, _, _, _, _ in runs}
    for _ in range(args.ticks):
        for key, impl, placement, faults, _ in runs:
            t0 = time.perf_counter()
            _one_tick(key, impl, placement, faults)
            timed[key] += time.perf_counter() - t0
        for key, _, _, _, parity in runs[1:]:
            if parity:
                _assert_parity(feds["reference"], feds[key])

    us_ref = timed["reference"] * 1e6 / args.ticks
    us_bat = timed["batched"] * 1e6 / args.ticks
    us_sh = timed["sharded"] * 1e6 / args.ticks
    us_armed = timed["armed"] * 1e6 / args.ticks
    us_adv = timed["adversary"] * 1e6 / args.ticks
    n_attacks = sum(1 for e in feds["adversary"].events if e.attack)
    n_poison = sum(
        1 for e in feds["adversary"].events if e.fault == "poison"
    )
    adv_overhead = us_adv / us_bat
    speedup = us_ref / us_bat
    sh_speedup = us_ref / us_sh
    fault_overhead = us_armed / us_bat
    # EVERY row records the measurement environment — actual visible device
    # count and the placement mode it timed. The committed baseline was once
    # produced in a 1-device process despite the Makefile forcing 8 host
    # devices (the flag was only on `make bench-tick`, not `bench-json`);
    # stamping D=/placement= on each row makes that impossible to miss.
    env = {
        "reference": f"D={ndev} placement=serial",
        "batched": f"D={ndev} placement=single",
        "sharded": f"D={ndev} placement=sharded",
    }
    rows = [
        (f"tick_engine.reference.N{args.owners}.E{args.entities}", us_ref,
         f"serial per-owner tick loop;{env['reference']}"),
        (f"tick_engine.batched.N{args.owners}.E{args.entities}", us_bat,
         f"per-signature entry programs, single device;{env['batched']}"),
        # the device count lives in the derived column, NOT the row name:
        # BENCH_*.json baselines are diffed across PRs by key, and a
        # D-suffixed key would fragment the sharded trajectory the moment
        # the device count changes
        (f"tick_engine.sharded.N{args.owners}.E{args.entities}", us_sh,
         f"signature buckets shard_map'ed, owner-resident;{env['sharded']}"),
        # the measurement environment, recorded IN the json artifact (derived
        # text is CSV-only): a baseline diff that mixes device counts is
        # visible instead of silent
        (f"tick_engine.sharded_devices.N{args.owners}.E{args.entities}",
         float(ndev), "actual device count behind the sharded rows"),
        # value = the ratio itself (dimensionless), so BENCH_*.json artifacts
        # track the speedup directly and the ≥3× bar is machine-checkable
        (f"tick_engine.speedup.N{args.owners}.E{args.entities}", speedup,
         f"speedup={speedup:.1f}x parity=bitwise;{env['batched']}"),
        (f"tick_engine.speedup_sharded.N{args.owners}.E{args.entities}",
         sh_speedup,
         f"speedup={sh_speedup:.1f}x parity=bitwise;{env['sharded']}"),
        # fault layer: the armed-but-idle cost (zero rates, norm screens on
        # every exchange) vs the faults-off batched row it shadows. The
        # faults-OFF rows above run the exact default path — no injector,
        # no draws, no screens — so they stay comparable against the
        # committed pre-fault-layer BENCH_federation_tick.json baseline;
        # this ratio row bounds what turning the layer ON would add.
        (f"tick_engine.fault_armed.N{args.owners}.E{args.entities}", us_armed,
         f"batched tick, tick_faults=on (zero rates, screens);{env['batched']}"),
        (f"tick_engine.fault_overhead.N{args.owners}.E{args.entities}",
         fault_overhead,
         f"armed/off ratio={fault_overhead:.2f}x parity=bitwise;{env['batched']}"),
        # Byzantine-robustness cost while under ACTIVE attack: batched tick
        # with a drift storm firing and the full defense stack engaged
        # (median robust aggregation + cosine screen + reputation). Attack
        # and poison counts ride in the derived column so a quiesced-early
        # or storm-dead run is visible in the artifact, not silent.
        (f"tick_engine.adversary.N{args.owners}.E{args.entities}", us_adv,
         f"batched tick, drift storm + median/screen defenses; "
         f"attacks={n_attacks} poisons={n_poison};{env['batched']}"),
        (f"tick_engine.adversary_overhead.N{args.owners}.E{args.entities}",
         adv_overhead,
         f"defended-under-attack/off ratio={adv_overhead:.2f}x;{env['batched']}"),
    ]
    # ---- straggler storm: one pinned slow owner, barrier vs streamed ----
    # The injected delay is simulated (added to measured seconds, never
    # slept), so this pair runs at full speed; the comparison lives in the
    # schedulers' simulated-time accounting. A staleness bound no run can
    # exceed keeps the streamed decisions bit-identical to the barrier's —
    # asserted below — so the two rows time the exact same work.
    from repro.core.faults import FaultPlan

    storm = FaultPlan.slow_owner(
        "K0", delay=args.straggle_delay, ticks=args.straggle_ticks
    )
    strag = {}
    for sync in ("barrier", "stream"):
        fed = _make(kgs, args)
        fed.initial_training()
        fed.run(
            max_ticks=args.straggle_ticks, tick_impl="batched",
            tick_placement="single", tick_faults=storm, tick_sync=sync,
            staleness_bound=1_000_000,
        )
        strag[sync] = fed
    _assert_parity_streamed(strag["barrier"], strag["stream"])

    def _fast_mean(fed):
        fast = [t for n, t in fed.sim_times().items() if n != "K0"]
        return sum(fast) / max(len(fast), 1)

    bar_fast = _fast_mean(strag["barrier"])
    str_fast = _fast_mean(strag["stream"])
    strag_speedup = bar_fast / str_fast if str_fast > 0 else float("inf")
    if args.owners >= 4:
        assert strag_speedup > 1.2, (
            f"streamed scheduling must beat the barrier past a straggler "
            f"({args.owners} owners, {args.owners - 1} fast): "
            f"{bar_fast:.1f}s vs {str_fast:.1f}s"
        )
    strag_env = (
        f"slow=K0 delay={args.straggle_delay:g}s "
        f"ticks={args.straggle_ticks};D={ndev} placement=single"
    )
    rows += [
        # value = simulated seconds (not µs): the injected delay dominates
        # and real compute rides inside the same accounting for both modes
        (f"tick_engine.straggler_barrier.N{args.owners}.E{args.entities}",
         bar_fast,
         f"fast-owner mean sim-seconds, lockstep barrier; "
         f"makespan={strag['barrier'].sim_makespan():.1f}s;{strag_env}"),
        (f"tick_engine.straggler_streamed.N{args.owners}.E{args.entities}",
         str_fast,
         f"fast-owner mean sim-seconds, dependency-level streaming; "
         f"makespan={strag['stream'].sim_makespan():.1f}s;{strag_env}"),
        (f"tick_engine.straggler_speedup.N{args.owners}.E{args.entities}",
         strag_speedup,
         f"barrier/streamed fast-owner ratio={strag_speedup:.1f}x "
         f"parity=bitwise;{strag_env}"),
    ]

    for name, us, derived in rows:
        emit(name, us, derived)
    if args.csv:
        with open(args.csv, "a") as f:
            for name, us, derived in rows:
                f.write(f"{name},{us:.1f},{derived}\n")


if __name__ == "__main__":
    main()
