"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--only <prefix>`` filters.
``--json [dir]`` additionally writes one machine-readable
``BENCH_<suite>.json`` file per suite (name → µs/call, plus numeric
``_env.*`` rows recording the measurement environment — device count — so
baselines regenerated under different settings diff loudly), so the perf
trajectory can be tracked across PRs by diffing committed artifacts.

``--smoke`` (the ``make bench-smoke`` tier-1 gate) runs EVERY suite at tiny
extents (N=2 owners, E ≤ 1k, single-digit epochs) — the bench code paths,
including their in-bench parity asserts, execute in CI time. Smoke numbers
are not measurements: combining ``--smoke`` with ``--json`` is refused so
they can never overwrite the committed baselines.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks import (
    attack_eval,
    bench_aggregation,
    bench_alignment_scale,
    bench_eval_engine,
    bench_federation_tick,
    bench_kernels,
    bench_link_prediction,
    bench_noise_ablation,
    bench_privacy,
    bench_roofline,
    bench_serving,
    bench_time_cost,
    bench_train_engine,
    bench_triple_classification,
    serve_chaos_smoke,
)
from benchmarks.common import drain_recorded, write_bench_json

SUITES = [
    ("privacy", bench_privacy.main),             # §4.1.2 (ε̂ = 2.73)
    ("kernels", bench_kernels.main),             # kernel parity + timing
    ("roofline", bench_roofline.main),           # §Roofline from dry-run
    ("time_cost", bench_time_cost.main),         # Fig. 7
    ("triple_classification", bench_triple_classification.main),  # Fig. 4/5
    ("link_prediction", bench_link_prediction.main),              # Tab. 4
    ("eval_engine", lambda: bench_eval_engine.main([])),          # fused ranks
    ("train_engine", lambda: bench_train_engine.main([])),        # sparse scan
    ("federation_tick", lambda: bench_federation_tick.main([])),  # tick engine
    ("serving", lambda: bench_serving.main([])),                  # serving tier
    # pass/fail resilience gate (emits no rows → never lands in BENCH json);
    # registered so the tier-1 bench-smoke run exercises the chaos scenario
    ("serve_chaos", lambda: serve_chaos_smoke.gate()),
    ("noise_ablation", bench_noise_ablation.main),                # Tab. 5
    ("alignment_scale", bench_alignment_scale.main),              # Tab. 6
    ("aggregation", bench_aggregation.main),                      # Tab. 7
    ("attack_eval", attack_eval.main),           # measured leakage vs ε
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    # default to the REPO ROOT, not benchmarks/: the committed BENCH_*.json
    # perf-trajectory artifacts live at the root, and defaulting elsewhere
    # quietly left that trajectory empty
    repo_root = os.path.abspath(
        os.path.join(os.path.dirname(__file__) or ".", "..")
    )
    ap.add_argument(
        "--json", nargs="?", const=repo_root,
        default=None, metavar="DIR",
        help="write BENCH_<suite>.json per suite (default: the repo root)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny-extent tier-1 gate: run every suite at N=2 / E≤1k",
    )
    args = ap.parse_args()
    if args.smoke:
        if args.json is not None:
            ap.error("--smoke numbers must never overwrite BENCH_*.json "
                     "baselines; drop --json")
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    import jax

    print(
        f"# devices={len(jax.devices())} backend={jax.default_backend()}"
        f"{' SMOKE (numbers are not measurements)' if args.smoke else ''}",
        file=sys.stderr,
    )
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in SUITES:
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.perf_counter()
        drain_recorded()
        suite_ok = True
        try:
            fn()
        except Exception:
            suite_ok = False
            failures += 1
            traceback.print_exc()
            print(f"{name}.FAILED,0.0,exception")
        if args.json is not None:
            rows = drain_recorded()
            if not suite_ok:
                # partial rows must not read as a clean (regressed) run when
                # artifacts are diffed across PRs — mark the failure
                rows[f"{name}.FAILED"] = 0.0
            if rows:
                path = write_bench_json(name, rows, args.json)
                print(f"# wrote {path}", file=sys.stderr)
        print(
            f"# suite {name} done in {time.perf_counter()-t0:.0f}s",
            file=sys.stderr,
        )
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
