"""Tier-1 Byzantine gate: seeded targeted-poisoning storm over a 4-owner
federation, run four ways (``make attack-smoke``):

  * **clean** — no adversary, defenses off: the quality baseline;
  * **undefended** — norm-evading drift poisoning with defenses off, run
    under BOTH tick engines: the storm must actually fire, every tampered
    exchange must be isolated to its entry (no tick aborts), the two
    engines must agree bit-for-bit (the adversary lives outside the
    key-stream lockstep), and final quality must measurably degrade
    relative to clean — poisoned exchanges cost accepted progress even
    though the backtrack gate stops them from corrupting snapshots;
  * **defended (median)** — robust aggregation clamps the Byzantine rows
    against the honest majority's delta distribution: final quality must
    recover to within tolerance of the adversary-free run;
  * **defended (median + cosine screen)** — the acceptance screen and
    continuous reputation must engage: poison verdicts fire, blame decays
    the attacker's reputation, quarantine trips, and no fault escalates to
    an ``error`` abort.

All four runs are deterministic (seeded adversary, seeded federation), so
the asserted margins are exact reproductions, not statistical claims. Like
``chaos_smoke`` this is a pass/fail gate, NOT a measurement — deliberately
not registered in ``benchmarks/run.py``.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe

#: norm-evading targeted drift: every PPAT exchange tampered, 40% of rows
#: blended fully onto the attacker's fixed direction, norms capped inside
#: the transfer guard (evade=0.9)
ADV_SPEC = "drift=1.0,seed=9,strength=1.0,frac=0.4,evade=0.9"
MAX_TICKS = 14


def _run(adv=None, robust="none", cos=None, impl=None):
    n = 4
    stats = [(f"O{i}", 6, 40000, 120000) for i in range(n)]
    aligns = [(f"O{i}", f"O{(i + 1) % n}", 12000) for i in range(n)]
    kgs = synthesize_universe(
        seed=3, scale=1 / 1000, kg_stats=stats, alignments=aligns
    )
    fed = FederationScheduler(
        kgs, dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
        tick_adversary=adv, robust_agg=robust, cos_screen=cos,
    )
    fed.initial_training()
    fed.run(max_ticks=MAX_TICKS, tick_impl=impl)
    return fed


def _score(fed) -> float:
    return sum(fed.best_score.values())


def _events_key(fed):
    # level / owner_clock / view_version ride in the parity key: the
    # engines must agree on the streaming-scheduler stamps too, not just
    # the protocol decisions
    return [
        (e.tick, e.host, e.client, e.kind, e.fault, e.attack, e.accepted,
         e.level, e.owner_clock, e.view_version)
        for e in fed.events
    ]


def _params_equal(a, b) -> bool:
    return all(
        np.array_equal(
            np.asarray(a.trainers[n].params[k]),
            np.asarray(b.trainers[n].params[k]),
        )
        for n in a.trainers
        for k in a.trainers[n].params
    )


def main() -> int:
    t0 = time.perf_counter()
    clean = _run()
    undef = _run(adv=ADV_SPEC, impl="reference")
    undef_b = _run(adv=ADV_SPEC, impl="batched")
    med = _run(adv=ADV_SPEC, robust="median")
    scr = _run(adv=ADV_SPEC, robust="median", cos=0.5)
    wall = time.perf_counter() - t0

    s_clean, s_undef, s_med, s_scr = map(
        _score, (clean, undef, med, scr)
    )
    attacked_runs = [undef, undef_b, med, scr]
    attacks = [sum(1 for e in f.events if e.attack) for f in attacked_runs]
    errors = [
        e for f in attacked_runs + [clean] for e in f.events
        if e.fault == "error"
    ]
    poisons = sum(1 for e in scr.events if e.fault == "poison")

    checks = [
        (all(a > 0 for a in attacks),
         f"storm too quiet — attack counts per run: {attacks}"),
        (not errors,
         f"tampered exchanges escalated to tick aborts: {errors}"),
        (sum(1 for e in clean.events if e.attack) == 0,
         "clean run recorded attack events"),
        (_events_key(undef) == _events_key(undef_b),
         "engine parity broke under adversary: event streams differ"),
        (_params_equal(undef, undef_b),
         "engine parity broke under adversary: final params differ"),
        (s_clean - s_undef >= 0.005,
         f"undefended run did not degrade: clean={s_clean:.4f} "
         f"undefended={s_undef:.4f}"),
        (s_med >= s_clean - 0.004,
         f"median defense did not recover quality: clean={s_clean:.4f} "
         f"defended={s_med:.4f}"),
        (s_scr >= s_clean - 0.01,
         f"screen+median defense lost too much quality: "
         f"clean={s_clean:.4f} defended={s_scr:.4f}"),
        (poisons > 0,
         "cosine screen never fired under a full-strength storm"),
        (scr._reputation and min(scr._reputation.values()) < 1.0,
         f"reputation never decayed despite poison verdicts: "
         f"{scr._reputation}"),
        (any(e.accepted and e.kind == "ppat" for e in scr.events),
         "defended federation made no progress"),
        # the barrier runs must stamp coherent streaming-scheduler fields:
        # level 0 everywhere, clocks advancing, versions visible on accepts
        (all(e.level == 0 for f in attacked_runs for e in f.events),
         "barrier-mode events carry a nonzero dependency level"),
        (all(e.owner_clock > 0 for f in attacked_runs for e in f.events),
         "events with unstamped per-owner clocks"),
        (max(e.view_version for e in scr.events) > 0,
         "view versions never advanced across accepted exchanges"),
    ]
    failures = [msg for ok, msg in checks if not ok]
    print(
        f"attack-smoke: wall={wall:.1f}s scores clean={s_clean:.4f} "
        f"undef={s_undef:.4f} median={s_med:.4f} screen={s_scr:.4f} "
        f"attacks={attacks} poisons={poisons} "
        f"rep={ {k: round(v, 3) for k, v in scr._reputation.items()} }"
    )
    for msg in failures:
        print(f"attack-smoke FAIL: {msg}", file=sys.stderr)
    if failures:
        return 1
    print(
        "attack-smoke: PASS — storm isolated, engines agree, defenses "
        "recover what the adversary cost"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
