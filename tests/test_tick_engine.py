"""Batched federation tick engine: batched-vs-reference bit parity, plan
semantics, program-cache reuse, and the sparse entity-norm projection."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation import FederationScheduler, NodeState, TickEntry
from repro.core.ppat import PPATConfig
from repro.core.tick_engine import tick_program_cache_size
from repro.kernels.dispatch import (
    resolve_tick_impl,
    resolve_tick_placement,
    resolve_tick_residency,
)
from repro.kge.data import equal_shape_universe, synthesize_universe
from repro.kge.engine import (
    _train_scan,
    pad_tables,
    pad_triples,
    resolve_renorm,
    shape_spec,
)
from repro.kge.models import KGEModel, init_kge


@pytest.fixture(scope="module")
def universe():
    stats = [("A", 12, 90000, 300000), ("B", 10, 70000, 240000),
             ("C", 8, 60000, 200000)]
    aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
    return synthesize_universe(seed=1, scale=1 / 500, kg_stats=stats,
                               alignments=aligns)


def _make(universe, **kw):
    defaults = dict(
        dim=16, ppat_cfg=PPATConfig(steps=6, seed=0),
        local_epochs=4, update_epochs=2, seed=0, score_max_test=40,
    )
    defaults.update(kw)
    return FederationScheduler(universe, **defaults)


def _run_pair(universe, ticks=3, **kw):
    feds = {}
    for impl in ("reference", "batched"):
        fed = _make(universe, **kw)
        fed.initial_training()
        fed.run(max_ticks=ticks, tick_impl=impl)
        feds[impl] = fed
    return feds["reference"], feds["batched"]


def _assert_parity(ref, bat, universe):
    """The tick-engine contract: identical protocol trajectory, identical
    scores/ε (exact floats), bit-identical final embeddings."""
    er = [(e.tick, e.host, e.client, e.kind, e.accepted) for e in ref.events]
    eb = [(e.tick, e.host, e.client, e.kind, e.accepted) for e in bat.events]
    assert er == eb
    for r, b in zip(ref.events, bat.events):
        assert r.score_before == b.score_before, (r, b)
        assert r.score_after == b.score_after, (r, b)
        assert (math.isnan(r.epsilon) and math.isnan(b.epsilon)) or (
            r.epsilon == b.epsilon
        )
    assert ref.best_score == bat.best_score
    assert ref.epsilons == bat.epsilons
    for n in universe:
        for k in ref.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(ref.trainers[n].params[k]),
                np.asarray(bat.trainers[n].params[k]),
                err_msg=f"{n}.{k} diverged between tick impls",
            )
    assert ref.state == bat.state
    assert all(
        list(ref.queue[n]) == list(bat.queue[n]) for n in universe
    )


@pytest.mark.parametrize("metric", ["accuracy", "hit10"])
def test_tick_parity(universe, metric):
    """Batched ticks reproduce serial ticks exactly: accept/reject decisions,
    scores, ε history, and bit-identical embeddings (same per-pair keys)."""
    ref, bat = _run_pair(universe, score_metric=metric)
    _assert_parity(ref, bat, universe)


def test_tick_parity_without_virtual_and_refine(universe):
    ref, bat = _run_pair(
        universe, ticks=2, use_virtual=False, procrustes_refine=False
    )
    _assert_parity(ref, bat, universe)


def test_tick_parity_custom_score_fn(universe):
    """A user-supplied score_fn cannot be traced — the batched engine must
    fall back to scoring the candidate params host-side, same trajectory."""
    def run(impl):
        fed = _make(universe)
        fed.score_fn = lambda name: fed._valid_accuracy(name)  # opaque fn
        fed.initial_training()
        fed.run(max_ticks=2, tick_impl=impl)
        return fed

    ref, bat = run("reference"), run("batched")
    _assert_parity(ref, bat, universe)


def test_tick_program_reused_across_ticks(universe):
    """Steady-state federation reuses the compiled tick-entry programs:
    ticks whose entry signatures (spec + bucket-padded shapes) were seen
    before must not recompile."""
    fed = _make(universe)
    fed.initial_training()
    # warm-up: each owner has 2 partners, so 2 ticks rotate through every
    # (client, host) pair signature; a drained-queue tick compiles the
    # self-train signatures
    fed.run(max_ticks=2, tick_impl="batched")
    for name in universe:
        fed.queue[name].clear()
        fed._queued[name].clear()
    fed.run(max_ticks=1, tick_impl="batched")
    n = tick_program_cache_size()
    fed.run(max_ticks=2, tick_impl="batched")
    assert tick_program_cache_size() == n, (
        "batched tick recompiled despite unchanged entry signatures"
    )


def test_plan_tick_snapshot_semantics(universe):
    """The plan is fixed at tick start: offers are popped, client views are
    frozen, and idle owners sleep (when self-training is off)."""
    fed = _make(universe)
    fed.initial_training()
    plan = fed.plan_tick()
    assert all(isinstance(e, TickEntry) for e in plan)
    assert {e.host for e in plan} == set(universe)  # everyone was Ready
    assert all(e.kind == "ppat" and e.client_view is not None for e in plan)
    # popped offers are gone from the queues
    for e in plan:
        assert e.client not in fed._queued[e.host]
    # empty-queue owners go to Sleep when self-training is disabled
    fed2 = _make(universe)
    fed2.initial_training()
    for n in universe:
        fed2.queue[n].clear()
        fed2._queued[n].clear()
    assert fed2.plan_tick(self_train=False) == []
    assert all(s is NodeState.SLEEP for s in fed2.state.values())


def test_score_fn_swap_rebuilds_score_cache(universe):
    """Swapping the backtrack metric between runs must rebuild the cached
    scoring inputs, not serve the previous metric's arrays."""
    fed = _make(universe)
    fed.initial_training()
    fed.run(max_ticks=1, tick_impl="batched")   # caches accuracy inputs
    fed.score_fn = fed._valid_hit10
    fed.best_score = {n: fed._valid_hit10(n) for n in universe}
    fed.run(max_ticks=1, tick_impl="batched")   # must rebuild as hit10
    hit10_events = [e for e in fed.events if e.tick == fed._tick]
    assert hit10_events
    assert all(0.0 <= e.score_after <= 1.0 for e in hit10_events)


def test_batched_tick_rejects_reference_train_impl(universe, monkeypatch):
    """The host-loop 'reference' training step cannot be embedded in a tick
    program — an explicit batched run must fail loudly, with no scheduler
    state consumed."""
    fed = _make(universe)
    fed.initial_training()
    monkeypatch.setenv("REPRO_TRAIN_IMPL", "reference")
    keys_before = {n: np.asarray(fed.trainers[n]._key) for n in universe}
    queues_before = {n: list(fed.queue[n]) for n in universe}
    with pytest.raises(ValueError, match="tick_impl='reference'"):
        fed.run(max_ticks=1, tick_impl="batched")
    for n in universe:
        np.testing.assert_array_equal(
            np.asarray(fed.trainers[n]._key), keys_before[n]
        )
        assert fed.state[n] is not NodeState.BUSY
        # the error fires before the plan pops any offers
        assert list(fed.queue[n]) == queues_before[n]


def test_equal_shaped_owners_share_one_entry_program():
    """Trace-time program dedup: N equal-shaped owners must compile exactly
    ONE tick-entry program per tick kind (per unique entry signature), not
    one per owner — the multi-device version of this claim (8 owners, 8
    simulated devices, shard_map buckets) is pinned by
    ``tests/test_tick_sharded.py``."""
    kgs = equal_shape_universe(
        4, entities=120, relations=6, triples=800, shared=32, seed=3
    )
    fed = FederationScheduler(
        kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0), local_epochs=2,
        update_epochs=2, seed=0, use_virtual=False, score_max_test=24,
    )
    fed.initial_training()
    before = tick_program_cache_size()
    # tick 1: every owner hosts one handshake — 4 equal-shaped ppat entries.
    # Placement is pinned to "single" so the exact program counts hold under
    # any forced host-device count (sharded would chunk the bucket by device
    # count); the sharded dedup claim is pinned by tests/test_tick_sharded.py.
    fed.run(max_ticks=1, tick_impl="batched", tick_placement="single")
    assert tick_program_cache_size() == before + 1
    # an all-self-train tick adds exactly one more program (new entry kind)
    for n in kgs:
        fed.queue[n].clear()
        fed._queued[n].clear()
    fed.run(max_ticks=1, tick_impl="batched", tick_placement="single")
    assert tick_program_cache_size() == before + 2


def test_score_inputs_invalidated_by_accepted_extension(universe):
    """Regression: the per-owner backtrack-score caches must be rebuilt when
    an accepted virtual extension grows the owner's embedding universe —
    fixed negatives / CSR filters built pre-accept must not be served against
    the post-accept tables."""
    import jax.numpy as jnp

    fed = _make(universe)
    fed.initial_training()
    name = next(iter(universe))
    tr = fed.trainers[name]
    e0 = tr.model.num_entities
    va0, neg0 = fed._accuracy_inputs(name)
    lp0 = fed._hit10_inputs(name)
    info0 = fed._tick_engine._score_info(name)

    # accept a virtual extension into the owner's live tables: the entity /
    # relation universe grows and stays grown across the next scoring call
    dim = tr.model.dim
    extra = np.array([[e0, tr.model.num_relations, 0]], np.int64)
    tr.extend_tables(
        0.01 * jnp.ones((3, dim)), 0.01 * jnp.ones((1, dim)), extra
    )
    assert tr.model.num_entities == e0 + 3

    va1, neg1 = fed._accuracy_inputs(name)
    np.testing.assert_array_equal(va0, va1)  # positives: unchanged split
    # negatives are REdrawn against the extended universe
    assert not np.array_equal(neg0, neg1)
    assert neg1[:, [0, 2]].max() < e0 + 3
    # hit@10 CSR filters are universe-extent independent (appended virtual
    # ids invalidate nothing) — the expensive rebuild must NOT fire
    assert fed._hit10_inputs(name) is lp0
    assert fed._tick_engine._score_info(name) is not info0
    # both metrics score the extended universe without stale-shape failures
    assert 0.0 <= fed._valid_accuracy(name) <= 1.0
    assert 0.0 <= fed._valid_hit10(name) <= 1.0

    # stripping the extension reverts the version: the rebuilt negatives are
    # bit-identical to the originals (fixed sampling seed)
    tr.strip_virtual()
    va2, neg2 = fed._accuracy_inputs(name)
    np.testing.assert_array_equal(neg0, neg2)
    np.testing.assert_array_equal(va0, va2)


def test_resolve_tick_placement(monkeypatch):
    """Placement resolution: explicit wins, then REPRO_TICK_PLACEMENT, then
    auto by visible device count (sharded iff >1 device, so the suite also
    passes under a forced multi-device XLA_FLAGS)."""
    auto = "sharded" if len(jax.devices()) > 1 else "single"
    assert resolve_tick_placement("single") == "single"
    assert resolve_tick_placement("sharded") == "sharded"
    assert resolve_tick_placement("auto") == auto
    assert resolve_tick_placement(None) == auto
    monkeypatch.setenv("REPRO_TICK_PLACEMENT", "sharded")
    assert resolve_tick_placement(None) == "sharded"
    assert resolve_tick_placement("single") == "single"  # explicit beats env
    monkeypatch.setenv("REPRO_TICK_PLACEMENT", "auto")
    assert resolve_tick_placement(None) == auto
    monkeypatch.delenv("REPRO_TICK_PLACEMENT")
    with pytest.raises(ValueError):
        resolve_tick_placement("nope")


def test_resolve_tick_residency(monkeypatch):
    """Residency resolution: explicit wins, then REPRO_TICK_RESIDENCY, then
    auto → resident (owner-sticky is the default everywhere; normalize is
    the legacy stage-back-to-device-0 escape hatch)."""
    assert resolve_tick_residency(None) == "resident"
    assert resolve_tick_residency("auto") == "resident"
    assert resolve_tick_residency("resident") == "resident"
    assert resolve_tick_residency("normalize") == "normalize"
    monkeypatch.setenv("REPRO_TICK_RESIDENCY", "normalize")
    assert resolve_tick_residency(None) == "normalize"
    assert resolve_tick_residency("resident") == "resident"  # explicit wins
    monkeypatch.delenv("REPRO_TICK_RESIDENCY")
    with pytest.raises(ValueError):
        resolve_tick_residency("nope")


def test_single_device_residency_keeps_state_usable(universe):
    """On one device residency is trivially satisfied; the engine's resident
    caches must still serve steady-state ticks without re-staging cached
    immutable inputs (the miss counter stays flat once every pair/score
    cache is warm)."""
    fed = _make(universe)
    fed.initial_training()
    fed.run(max_ticks=2, tick_impl="batched")  # warm every (client, host)
    for name in universe:  # warm the self-train caches too
        fed.queue[name].clear()
        fed._queued[name].clear()
    fed.run(max_ticks=1, tick_impl="batched")
    eng = fed._tick_engine
    misses = eng.resident_transfers
    fed.run(max_ticks=2, tick_impl="batched")
    assert eng.resident_transfers == misses, (
        "steady-state single-device ticks re-staged cached inputs"
    )


def test_resolve_tick_impl(monkeypatch):
    assert resolve_tick_impl("reference") == "reference"
    assert resolve_tick_impl("batched") == "batched"
    assert resolve_tick_impl(None) == "batched"
    monkeypatch.setenv("REPRO_TICK_IMPL", "reference")
    assert resolve_tick_impl(None) == "reference"
    monkeypatch.delenv("REPRO_TICK_IMPL")
    # host-loop training cannot be embedded in a tick program → fall back
    monkeypatch.setenv("REPRO_TRAIN_IMPL", "reference")
    assert resolve_tick_impl(None) == "reference"
    monkeypatch.delenv("REPRO_TRAIN_IMPL")
    with pytest.raises(ValueError):
        resolve_tick_impl("nope")


# ---------------------------------------------------------------------------
# sparse entity-norm projection (kge.engine renorm="sparse")
# ---------------------------------------------------------------------------
def _scan_kwargs(e, renorm, epochs=3):
    m = KGEModel("transe", e, 5, 16)
    return m, dict(
        spec=shape_spec(m), epochs=epochs, batch=50, impl="xla",
        interpret=True, renorm=renorm,
    )


def test_sparse_renorm_bit_parity_all_touched():
    """When every entity appears in the triple store, the sparse projection
    schedule (project the rows an epoch is about to read, full projection
    once at the end) applies exactly the dense per-epoch full projection —
    bit-identical params and losses."""
    e = 60
    rng = np.random.default_rng(0)
    # every entity occurs as a head → touched every epoch
    tri = np.stack(
        [np.arange(e), rng.integers(0, 5, e), rng.integers(0, e, e)], axis=1
    ).astype(np.int32)
    tri = np.concatenate([tri, tri[rng.integers(0, e, 140)]])
    m, kw_d = _scan_kwargs(e, "dense")
    _, kw_s = _scan_kwargs(e, "sparse")
    p = init_kge(jax.random.PRNGKey(0), m)
    padded, _, _ = pad_tables(p, m)
    args = (pad_triples(jnp.asarray(tri), 50), jax.random.PRNGKey(1),
            jnp.float32(0.5), jnp.int32(e))
    dense, ld = _train_scan(padded, *args, **kw_d)
    sparse, ls = _train_scan(padded, *args, **kw_s)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(ls))
    for k in dense:
        np.testing.assert_array_equal(np.asarray(dense[k]), np.asarray(sparse[k]))


def test_sparse_renorm_close_and_projected_general():
    """General stores: the dense schedule re-projects untouched rows every
    epoch (1-ulp drift on a few rows — x/‖x‖ is not a bit fixpoint), so the
    contract is: trajectories agree to fp tolerance AND the sparse result is
    fully projected (no entity norm above 1)."""
    e = 400
    rng = np.random.default_rng(1)
    tri = np.stack(
        [rng.integers(0, e, 150), rng.integers(0, 5, 150),
         rng.integers(0, e, 150)], axis=1,
    ).astype(np.int32)
    m, kw_d = _scan_kwargs(e, "dense", epochs=4)
    _, kw_s = _scan_kwargs(e, "sparse", epochs=4)
    p = init_kge(jax.random.PRNGKey(2), m)
    padded, _, _ = pad_tables(p, m)
    args = (pad_triples(jnp.asarray(tri), 50), jax.random.PRNGKey(3),
            jnp.float32(0.5), jnp.int32(e))
    dense, ld = _train_scan(padded, *args, **kw_d)
    sparse, ls = _train_scan(padded, *args, **kw_s)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ls), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(dense["ent"]), np.asarray(sparse["ent"]), atol=1e-6
    )
    norms = np.linalg.norm(np.asarray(sparse["ent"]), axis=-1)
    assert (norms <= 1.0 + 1e-5).all()


def test_sparse_renorm_padding_invariance():
    """Sparse renorm keeps the bucket-padding invariant: growing the physical
    table leaves the logical rows bit-identical and padding rows zero."""
    e = 70
    rng = np.random.default_rng(4)
    tri = np.stack(
        [rng.integers(0, e, 120), rng.integers(0, 5, 120),
         rng.integers(0, e, 120)], axis=1,
    ).astype(np.int32)
    m, kw = _scan_kwargs(e, "sparse")
    kw["batch"] = 40
    p = init_kge(jax.random.PRNGKey(5), m)
    args = (pad_triples(jnp.asarray(tri), 40), jax.random.PRNGKey(6),
            jnp.float32(0.5), jnp.int32(e))
    small, l_small = _train_scan(p, *args, **kw)
    grown = {k: jnp.pad(v, ((0, 64), (0, 0))) for k, v in p.items()}
    big, l_big = _train_scan(grown, *args, **kw)
    np.testing.assert_array_equal(np.asarray(l_small), np.asarray(l_big))
    for k in p:
        n = p[k].shape[0]
        np.testing.assert_array_equal(np.asarray(small[k]), np.asarray(big[k][:n]))
    np.testing.assert_array_equal(np.asarray(big["ent"][e:]), 0.0)


def test_resolve_renorm_threshold():
    assert resolve_renorm(100, 100_000) == "sparse"  # 400 rows vs 100k
    assert resolve_renorm(10_000, 10_240) == "dense"  # 40k rows vs 10k
