"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as the REDUCED variant of the same
family (≤2 layers, d_model ≤ 512, ≤4 experts) and runs one forward and one
train step on CPU, asserting output shapes and the absence of NaNs. The full
cards are exercised abstractly by the dry-run only.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, TrainConfig, get_config, reduced
from repro.models.model import forward, init_params
from repro.train.step import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


def _inputs(cfg, key, b=2, s=16):
    kw = {}
    if cfg.encoder_layers:
        kw["frames"] = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.num_patches:
        kw["patches"] = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model))
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return toks, kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_no_nans(arch):
    cfg = reduced(get_config(arch)).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks, kw = _inputs(cfg, key)
    logits, aux = forward(params, cfg, toks, **kw)
    expected_s = 16 + (cfg.num_patches or 0)
    assert logits.shape == (2, expected_s, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_runs_and_loss_finite(arch):
    cfg = reduced(get_config(arch)).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    tcfg = TrainConfig(
        global_batch=4, seq_len=16, microbatches=2, ce_chunk=0,
        total_steps=10, warmup_steps=1, learning_rate=1e-3,
    )
    state = init_train_state(key, cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    toks, kw = _inputs(cfg, key, b=4)
    batch = {"tokens": toks, "labels": toks}
    batch.update({k: jnp.repeat(v[:2], 2, axis=0) for k, v in kw.items()})
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    state2, metrics2 = step(state, batch)
    assert jnp.isfinite(metrics2["loss"])
    # parameters actually moved
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b: jnp.any(a != b), state.params, state2.params)
    )
    assert any(bool(m) for m in moved)


def test_loss_decreases_on_repeated_batch():
    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    tcfg = TrainConfig(
        global_batch=4, seq_len=32, microbatches=1, ce_chunk=0,
        total_steps=30, warmup_steps=1, learning_rate=3e-3, weight_decay=0.0,
    )
    state = init_train_state(key, cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    first = None
    for i in range(15):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first * 0.9


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b", "mixtral-8x22b", "whisper-medium"])
def test_decode_matches_forward(arch):
    import dataclasses

    from repro.models.model import decode_step, init_cache, prefill

    cfg = reduced(get_config(arch)).replace(dtype="float32")
    if cfg.moe.enabled:  # no token drops → exact equality achievable
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    s = 16
    toks, kw = _inputs(cfg, key, s=s)
    full, _ = forward(params, cfg, toks, **kw)
    cache = init_cache(cfg, 2, s, jnp.float32)
    logits_pf, cache = prefill(params, cfg, toks[:, : s - 1], cache, **kw)
    dec, _ = decode_step(params, cfg, toks[:, s - 1 : s], cache, jnp.int32(s - 1))
    assert jnp.allclose(logits_pf[:, 0], full[:, s - 2], atol=2e-4)
    assert jnp.allclose(dec[:, 0], full[:, s - 1], atol=2e-4)
