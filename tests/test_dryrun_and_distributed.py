"""Distribution tests — run in subprocesses so the multi-device XLA flag
never leaks into the main test process (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_small_mesh_lower_compile_and_collectives():
    out = _run(
        """
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, TrainConfig
        from repro.sharding.specs import state_pspecs, batch_pspec
        from repro.train.step import init_train_state, make_train_step
        from repro.utils.hlo import collective_bytes

        cfg = reduced(get_config("qwen3-0.6b"), vocab=2048)
        from repro.sharding.context import auto_axis_types_kw
        mesh = jax.make_mesh((2, 4), ("data", "model"), **auto_axis_types_kw(2))
        tcfg = TrainConfig(global_batch=8, seq_len=64, microbatches=2, ce_chunk=0)
        state = jax.eval_shape(lambda k: init_train_state(k, cfg),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        sspec = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(state),
                             is_leaf=lambda x: isinstance(x, P))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bspec = {k: NamedSharding(mesh, batch_pspec(False)) for k in batch}
        with mesh:
            lowered = jax.jit(make_train_step(cfg, tcfg),
                              in_shardings=(sspec, bspec),
                              out_shardings=(sspec, None)).lower(state, batch)
            compiled = lowered.compile()
        coll = collective_bytes(compiled.as_text())
        from repro.utils.hlo import peak_memory_bytes
        mem = compiled.memory_analysis()
        print(json.dumps({"total": coll["total"], "count": coll["count"],
                          "peak": peak_memory_bytes(mem)}))
        """
    )
    data = json.loads(out.strip().splitlines()[-1])
    assert data["total"] > 0, "sharded train step must produce collectives"
    assert data["peak"] > 0


def test_distributed_ppat_exchange():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import (
            make_party_mesh, init_distributed_ppat, ppat_exchange_step)
        from repro.core.ppat import PPATConfig
        cfg = PPATConfig()
        mesh = make_party_mesh(2)
        d, n, B = 16, 100, 32
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        y = x @ jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
        state = init_distributed_ppat(key, d, cfg)
        step = ppat_exchange_step(mesh, cfg)
        rng = np.random.default_rng(0)
        for i in range(10):
            xb = jnp.stack([x[rng.integers(0, n, B)], jnp.zeros((B, d))])
            yb = jnp.stack([jnp.zeros((B, d)), y[rng.integers(0, n, B)]])
            keys = jax.random.split(jax.random.fold_in(key, i), 2)
            state, metrics, (n0, n1) = step(state, xb, yb, keys)
        # host votes must be a partition of the teacher count
        assert ((np.array(n0[B:]) + np.array(n1[B:])) == cfg.num_teachers).all()
        assert float(jnp.abs(state["w"] - jnp.eye(d)).sum()) > 1e-4
        # the lowered HLO must exchange via collective-permute (the paper's pipes)
        txt = jax.jit(step).lower(state, xb, yb, keys).as_text()
        assert "collective-permute" in txt or "collective_permute" in txt
        print("DIST_PPAT_OK")
        """
    )
    assert "DIST_PPAT_OK" in out


def test_dryrun_entrypoint_one_combo():
    """End-to-end: the real dryrun module on the real 512-device mesh."""
    out = _run(
        """
        from repro.launch.dryrun import dryrun_one
        r = dryrun_one("qwen3-0.6b", "decode_32k", multi_pod=False, verbose=False)
        assert r["status"] == "ok", r
        assert r["chips"] == 256
        assert r["memory"]["peak_bytes_per_device"] > 0
        assert r["roofline"]["bottleneck"] in ("compute", "memory", "collective")
        print("DRYRUN_OK")
        """,
        devices=512,
    )
    assert "DRYRUN_OK" in out


def test_make_production_mesh_shapes():
    out = _run(
        """
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
        assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
        print("MESH_OK")
        """,
        devices=512,
    )
    assert "MESH_OK" in out


def test_moe_alltoall_matches_gather():
    """The shard_map expert-parallel MoE (§Perf) must be numerically
    equivalent to the pjit gather implementation — forward and gradients."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models.moe import init_moe, apply_moe_gather, apply_moe_alltoall
        from repro.sharding import context as shard_ctx

        from repro.sharding.context import auto_axis_types_kw
        mesh = jax.make_mesh((4, 2), ("data", "model"), **auto_axis_types_kw(2))
        shard_ctx.set_mesh(mesh)
        cfg = reduced(get_config("kimi-k2-1t-a32b")).replace(dtype="float32")
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
        with mesh:
            yg, _ = jax.jit(lambda p, x: apply_moe_gather(p, x, cfg))(p, x)
            ya, _ = jax.jit(lambda p, x: apply_moe_alltoall(p, x, cfg, mesh))(p, x)
            gg = jax.jit(jax.grad(lambda p, x: jnp.sum(apply_moe_gather(p, x, cfg)[0]**2)))(p, x)
            ga = jax.jit(jax.grad(lambda p, x: jnp.sum(apply_moe_alltoall(p, x, cfg, mesh)[0]**2)))(p, x)
        assert float(jnp.abs(yg - ya).max()) < 1e-3
        for k in ("w_gate", "w_down", "router"):
            e = float(jnp.abs(gg[k] - ga[k]).max())
            s = float(jnp.abs(gg[k]).max()) + 1e-9
            assert e / s < 1e-3, (k, e, s)
        # grouped (node-limited) routing path also runs + differentiates
        cfg_g = cfg.replace(moe=dataclasses.replace(cfg.moe, route_groups=3))
        with mesh:
            yr, _ = jax.jit(lambda p, x: apply_moe_alltoall(p, x, cfg_g, mesh))(p, x)
        assert jnp.isfinite(yr).all()
        print("MOE_A2A_OK")
        """
    )
    assert "MOE_A2A_OK" in out


def test_loop_aware_collective_accounting():
    """Collectives inside while bodies are multiplied by trip counts."""
    from repro.utils.hlo import collective_bytes, loop_aware_collective_bytes

    txt = """
%cond.1 (p: (s32[], f32[8]{0})) -> pred[] {
  %iv = s32[] get-tuple-element(%p), index=0
  %bound = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %bound), direction=LT
}

%body.1 (p: (s32[], f32[8]{0})) -> (s32[], f32[8]{0}) {
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), to_apply=%sum
  ROOT %t = (s32[], f32[8]{0}) tuple(%iv2, %ar)
}

ENTRY %main.1 (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]{0}) while(%tup), condition=%cond.1, body=%body.1
  %ar2 = f32[16]{0} all-reduce(%y), to_apply=%sum
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    flat = collective_bytes(txt)
    loop = loop_aware_collective_bytes(txt)
    assert flat["all-reduce"] == 8 * 4 + 16 * 4          # counted once each
    assert loop["all-reduce"] == 5 * 8 * 4 + 16 * 4      # body ×5 trips


def test_hlo_collective_parser_units():
    from repro.utils.hlo import collective_bytes

    txt = """
      %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
      %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
      %cp = f32[8,8]{1,0} collective-permute(%z)
      %noise = f32[2,2]{1,0} add(%a, %b)
    """
    out = collective_bytes(txt)
    assert out["all-gather"] == 16 * 128 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"] + out["collective-permute"]
