"""Distribution tests — run in subprocesses so the multi-device XLA flag
never leaks into the main test process (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
import textwrap


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_small_mesh_lower_compile_and_collectives():
    out = _run(
        """
        import jax, jax.numpy as jnp, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced, TrainConfig
        from repro.sharding.specs import state_pspecs, batch_pspec
        from repro.train.step import init_train_state, make_train_step
        from repro.utils.hlo import collective_bytes

        cfg = reduced(get_config("qwen3-0.6b"), vocab=2048)
        from repro.sharding.context import auto_axis_types_kw
        mesh = jax.make_mesh((2, 4), ("data", "model"), **auto_axis_types_kw(2))
        tcfg = TrainConfig(global_batch=8, seq_len=64, microbatches=2, ce_chunk=0)
        state = jax.eval_shape(lambda k: init_train_state(k, cfg),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        sspec = jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(state),
                             is_leaf=lambda x: isinstance(x, P))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        bspec = {k: NamedSharding(mesh, batch_pspec(False)) for k in batch}
        with mesh:
            lowered = jax.jit(make_train_step(cfg, tcfg),
                              in_shardings=(sspec, bspec),
                              out_shardings=(sspec, None)).lower(state, batch)
            compiled = lowered.compile()
        coll = collective_bytes(compiled.as_text())
        from repro.utils.hlo import peak_memory_bytes
        mem = compiled.memory_analysis()
        print(json.dumps({"total": coll["total"], "count": coll["count"],
                          "peak": peak_memory_bytes(mem)}))
        """
    )
    data = json.loads(out.strip().splitlines()[-1])
    assert data["total"] > 0, "sharded train step must produce collectives"
    assert data["peak"] > 0


def test_distributed_ppat_exchange():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import (
            make_party_mesh, init_distributed_ppat, ppat_exchange_step)
        from repro.core.ppat import PPATConfig
        cfg = PPATConfig()
        mesh = make_party_mesh(2)
        d, n, B = 16, 100, 32
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (n, d))
        y = x @ jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))[0]
        state = init_distributed_ppat(key, d, cfg)
        step = ppat_exchange_step(mesh, cfg)
        rng = np.random.default_rng(0)
        for i in range(10):
            xb = jnp.stack([x[rng.integers(0, n, B)], jnp.zeros((B, d))])
            yb = jnp.stack([jnp.zeros((B, d)), y[rng.integers(0, n, B)]])
            keys = jax.random.split(jax.random.fold_in(key, i), 2)
            state, metrics, (n0, n1) = step(state, xb, yb, keys)
        # host votes must be a partition of the teacher count
        assert ((np.array(n0[B:]) + np.array(n1[B:])) == cfg.num_teachers).all()
        assert float(jnp.abs(state["w"] - jnp.eye(d)).sum()) > 1e-4
        # the lowered HLO must exchange via collective-permute (the paper's pipes)
        txt = jax.jit(step).lower(state, xb, yb, keys).as_text()
        assert "collective-permute" in txt or "collective_permute" in txt
        print("DIST_PPAT_OK")
        """
    )
    assert "DIST_PPAT_OK" in out


def test_dryrun_entrypoint_one_combo():
    """End-to-end: the real dryrun module on the real 512-device mesh."""
    out = _run(
        """
        from repro.launch.dryrun import dryrun_one
        r = dryrun_one("qwen3-0.6b", "decode_32k", multi_pod=False, verbose=False)
        assert r["status"] == "ok", r
        assert r["chips"] == 256
        assert r["memory"]["peak_bytes_per_device"] > 0
        assert r["roofline"]["bottleneck"] in ("compute", "memory", "collective")
        print("DRYRUN_OK")
        """,
        devices=512,
    )
    assert "DRYRUN_OK" in out


def test_make_production_mesh_shapes():
    out = _run(
        """
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
        assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
        print("MESH_OK")
        """,
        devices=512,
    )
    assert "MESH_OK" in out


def test_moe_alltoall_matches_gather():
    """The shard_map expert-parallel MoE (§Perf) must be numerically
    equivalent to the pjit gather implementation — forward and gradients."""
    out = _run(
        """
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config, reduced
        from repro.models.moe import init_moe, apply_moe_gather, apply_moe_alltoall
        from repro.sharding import context as shard_ctx

        from repro.sharding.context import auto_axis_types_kw
        mesh = jax.make_mesh((4, 2), ("data", "model"), **auto_axis_types_kw(2))
        shard_ctx.set_mesh(mesh)
        cfg = reduced(get_config("kimi-k2-1t-a32b")).replace(dtype="float32")
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
        with mesh:
            yg, _ = jax.jit(lambda p, x: apply_moe_gather(p, x, cfg))(p, x)
            ya, _ = jax.jit(lambda p, x: apply_moe_alltoall(p, x, cfg, mesh))(p, x)
            gg = jax.jit(jax.grad(lambda p, x: jnp.sum(apply_moe_gather(p, x, cfg)[0]**2)))(p, x)
            ga = jax.jit(jax.grad(lambda p, x: jnp.sum(apply_moe_alltoall(p, x, cfg, mesh)[0]**2)))(p, x)
        assert float(jnp.abs(yg - ya).max()) < 1e-3
        for k in ("w_gate", "w_down", "router"):
            e = float(jnp.abs(gg[k] - ga[k]).max())
            s = float(jnp.abs(gg[k]).max()) + 1e-9
            assert e / s < 1e-3, (k, e, s)
        # grouped (node-limited) routing path also runs + differentiates
        cfg_g = cfg.replace(moe=dataclasses.replace(cfg.moe, route_groups=3))
        with mesh:
            yr, _ = jax.jit(lambda p, x: apply_moe_alltoall(p, x, cfg_g, mesh))(p, x)
        assert jnp.isfinite(yr).all()
        print("MOE_A2A_OK")
        """
    )
    assert "MOE_A2A_OK" in out


def test_loop_aware_collective_accounting():
    """Collectives inside while bodies are multiplied by trip counts."""
    from repro.utils.hlo import collective_bytes, loop_aware_collective_bytes

    txt = """
%cond.1 (p: (s32[], f32[8]{0})) -> pred[] {
  %iv = s32[] get-tuple-element(%p), index=0
  %bound = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %bound), direction=LT
}

%body.1 (p: (s32[], f32[8]{0})) -> (s32[], f32[8]{0}) {
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), to_apply=%sum
  ROOT %t = (s32[], f32[8]{0}) tuple(%iv2, %ar)
}

ENTRY %main.1 (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]{0}) while(%tup), condition=%cond.1, body=%body.1
  %ar2 = f32[16]{0} all-reduce(%y), to_apply=%sum
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""
    flat = collective_bytes(txt)
    loop = loop_aware_collective_bytes(txt)
    assert flat["all-reduce"] == 8 * 4 + 16 * 4          # counted once each
    assert loop["all-reduce"] == 5 * 8 * 4 + 16 * 4      # body ×5 trips


def test_hlo_collective_parser_units():
    from repro.utils.hlo import collective_bytes

    txt = """
      %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
      %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
      %cp = f32[8,8]{1,0} collective-permute(%z)
      %noise = f32[2,2]{1,0} add(%a, %b)
    """
    out = collective_bytes(txt)
    assert out["all-gather"] == 16 * 128 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["collective-permute"] == 64 * 4
    assert out["total"] == out["all-gather"] + out["all-reduce"] + out["collective-permute"]


# ---------------------------------------------------------------------------
# owner-sticky placement primitives (federation tick engine residency layer)
# ---------------------------------------------------------------------------
def test_owner_placement_sticky_and_balanced():
    """Home devices are assigned round-robin in first-seen order and NEVER
    move afterwards — lookups in any later order (plan recomposition) return
    the original assignment."""
    from repro.core.distributed import OwnerPlacement

    devs = ("d0", "d1", "d2")  # any hashable stands in for a jax.Device
    p = OwnerPlacement(devices=devs)
    owners = [f"K{i}" for i in range(7)]
    slots = {n: p.slot(n) for n in owners}
    assert [slots[n] for n in owners] == [0, 1, 2, 0, 1, 2, 0]
    assert p.device("K4") == "d1"
    # re-query in reversed order, interleaved with a never-seen owner: the
    # existing assignments are untouched
    for n in reversed(owners):
        assert p.slot(n) == slots[n]
    assert p.slot("LATE") == (7 % 3)
    assert p.assignments()["K5"] == 2


def test_chunk_extents_pow2_decomposition():
    """Extents come from {devices} ∪ {2^k}: greedy full-mesh chunks, then one
    remainder chunk padded up to the next power of two (capped at the device
    count) — so the distinct extents a signature can ever see is bounded by
    ~log2(devices), not by the number of possible bucket sizes."""
    from repro.core.distributed import chunk_extents

    assert chunk_extents(8, 8) == [(8, 8)]
    assert chunk_extents(5, 8) == [(5, 8)]      # 3 dummy slots
    assert chunk_extents(1, 8) == [(1, 1)]      # singleton, no shard_map
    assert chunk_extents(11, 8) == [(8, 8), (3, 4)]
    assert chunk_extents(5, 3) == [(3, 3), (2, 2)]
    assert chunk_extents(7, 3) == [(3, 3), (3, 3), (1, 1)]
    assert chunk_extents(4, 6) == [(4, 4)]
    assert chunk_extents(5, 6) == [(5, 6)]      # next pow2 (8) caps at 6
    assert chunk_extents(2, 1) == [(1, 1), (1, 1)]
    # every possible bucket size on D devices uses ≤ log2(D)+2 distinct
    # extents in total — the compile bound the tick engine relies on
    for d in (1, 2, 3, 4, 6, 8):
        seen = set()
        for n in range(1, 4 * d):
            seen.update(e for _, e in chunk_extents(n, d))
        import math
        assert len(seen) <= int(math.log2(d)) + 2, (d, seen)


def test_replica_devices_ring():
    """The serving tier's replica ring: consecutive devices from the home
    slot, wrapping, clamped to the mesh — replica 0 is always the owner's
    sticky home device."""
    import pytest
    from repro.core.distributed import replica_devices

    devs = list("abcdef")  # any sequence works; only indexing is used
    assert replica_devices(0, 3, devs) == ["a", "b", "c"]
    assert replica_devices(4, 3, devs) == ["e", "f", "a"]   # wraps
    assert replica_devices(2, 99, devs) == ["c", "d", "e", "f", "a", "b"]
    assert replica_devices(0, 2, ["x"]) == ["x"]            # clamps
    with pytest.raises(ValueError, match=">= 1"):
        replica_devices(0, 0, devs)


def test_assemble_disassemble_group_zero_copy():
    """Group operands are built from per-device resident shards and split
    back into per-device shards — values round-trip exactly and every result
    stays committed to its position's device."""
    out = _run(
        """
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core.distributed import (
            assemble_group, disassemble_group, owner_shard_map,
        )

        devs = jax.devices()
        assert len(devs) == 4
        entries = [
            {"a": jax.device_put(jnp.full((2, 3), float(k)), devs[k]),
             "b": jax.device_put(jnp.int32(k), devs[k])}
            for k in range(4)
        ]
        g = assemble_group(entries, 4)
        assert g["a"].shape == (4, 2, 3) and g["b"].shape == (4,)
        prog = jax.jit(owner_shard_map(
            lambda t: {"a": t["a"] * 2, "b": t["b"] + 10}, 4
        ))
        outs = disassemble_group(prog(g), 4)
        for k, o in enumerate(outs):
            assert o["a"].committed and o["a"].devices() == {devs[k]}
            np.testing.assert_array_equal(np.asarray(o["a"]), 2.0 * k)
            assert int(o["b"]) == k + 10
        print("GROUP_ROUNDTRIP_OK")
        """,
        devices=4,
    )
    assert "GROUP_ROUNDTRIP_OK" in out
