"""Device-resident training engine: sparse-vs-dense parity, bucket padding,
fused-kernel oracle checks, and the retrace-free federation invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.federation as federation_mod
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kernels.dispatch import resolve_train_impl
from repro.kernels.sparse_update import fused_sparse_step, sparse_step_ref
from repro.kge.data import synthesize_universe
from repro.kge.engine import (
    ENT_BUCKET,
    ENT_KEYS,
    _train_scan,
    bucket,
    pad_tables,
    pad_triples,
    shape_spec,
    sparse_epoch,
    train_epochs_device,
    train_scan_cache_size,
)
from repro.kge.models import KGEModel, MODEL_FAMILIES, init_kge
from repro.kge.trainer import KGETrainer, _epoch


# ---------------------------------------------------------------- helpers
def _batches(rng, e, r, nb, b, *, duplicates=True):
    """(nb, B, 3) positive + 1:1-corrupted negative batches; every batch
    carries duplicated rows so the segment-sum composition is exercised."""
    pos = np.stack(
        [
            rng.integers(0, e, (nb, b)),
            rng.integers(0, r, (nb, b)),
            rng.integers(0, e, (nb, b)),
        ],
        axis=-1,
    ).astype(np.int32)
    neg = pos.copy()
    ch = rng.random((nb, b)) < 0.5
    rand = rng.integers(0, e, (nb, b))
    neg[..., 0] = np.where(ch, rand, neg[..., 0])
    neg[..., 2] = np.where(~ch, rand, neg[..., 2])
    if duplicates:
        pos[:, 0] = pos[:, 1]  # row 0 duplicates row 1 in every batch
        neg[:, 0] = neg[:, 1]
    return jnp.asarray(pos), jnp.asarray(neg)


def _sparse_epochs(params, model, pos, neg, lr, epochs):
    """Sparse trajectory on fixed batches via the jitted ``sparse_epoch``
    twin of the dense ``_epoch``."""
    spec = shape_spec(model)
    losses = []
    for _ in range(epochs):
        params, loss = sparse_epoch(params, spec, pos, neg, lr)
        losses.append(float(loss))
    return params, np.asarray(losses)


# ------------------------------------------------- sparse vs dense, bit-level
@pytest.mark.parametrize("family", MODEL_FAMILIES)
def test_sparse_step_bit_parity_all_families(family):
    """3-epoch loss trajectory AND final params bit-identical to the dense
    reference, with duplicate rows in every batch."""
    e, r, d, nb, b, epochs = 60, 6, 16, 4, 10, 3
    m = KGEModel(family, e, r, d, margin=2.0)
    p = init_kge(jax.random.PRNGKey(0), m)
    rng = np.random.default_rng(0)
    pos, neg = _batches(rng, e, r, nb, b)
    lr = jnp.float32(0.25)

    dense, sparse = p, p
    for _ in range(epochs):
        dense, dl = _epoch(dense, m, pos, neg, lr)
    sparse, sl = _sparse_epochs(p, m, pos, neg, lr, epochs)
    for k in dense:
        np.testing.assert_array_equal(
            np.asarray(dense[k]), np.asarray(sparse[k]),
            err_msg=f"{family}:{k} diverged from the dense update",
        )
    # _epoch returns the LAST epoch's mean loss; trajectories must agree too
    np.testing.assert_array_equal(np.asarray(dl), sl[-1])


def test_sparse_step_bit_parity_with_virtual_extension():
    """Batches referencing virtual rows (ids ≥ base E) update the extended
    tables exactly like the dense step."""
    e0, r0, d, b = 40, 4, 16, 12
    m = KGEModel("transe", e0, r0, d)
    p = init_kge(jax.random.PRNGKey(1), m)
    # virtual extension: +6 entity rows, +2 relation rows
    p = dict(p)
    p["ent"] = jnp.concatenate([p["ent"], jnp.full((6, d), 0.1, jnp.float32)])
    p["rel"] = jnp.concatenate([p["rel"], jnp.full((2, d), 0.2, jnp.float32)])
    m = dataclasses.replace(m, num_entities=e0 + 6, num_relations=r0 + 2)
    rng = np.random.default_rng(2)
    pos, neg = _batches(rng, e0 + 6, r0 + 2, 3, b)
    # force several virtual-row hits
    pos = pos.at[:, 2, 0].set(e0 + 1)
    pos = pos.at[:, 3, 1].set(r0)
    lr = jnp.float32(0.5)

    dense, _ = _epoch(p, m, pos, neg, lr)
    sparse, _ = _sparse_epochs(p, m, pos, neg, lr, 1)
    for k in dense:
        np.testing.assert_array_equal(np.asarray(dense[k]), np.asarray(sparse[k]))


# --------------------------------------------------------- fused pallas step
@pytest.mark.parametrize("mode,margin", [("l1", 4.0), ("l2", 2.0), ("dot", 2.0)])
def test_fused_kernel_step_matches_dense_oracle(mode, margin):
    rng = np.random.default_rng(0)
    e, r, d, b = 50, 5, 16, 10
    ent = jnp.asarray(rng.normal(0, 0.3, (e, d)).astype(np.float32))
    rel = jnp.asarray(rng.normal(0, 0.3, (r, d)).astype(np.float32))
    pos, neg = _batches(rng, e, r, 1, b)
    ne, nr, loss = fused_sparse_step(
        ent, rel, pos[0], neg[0], 0.1, mode=mode, margin=margin, interpret=True
    )
    re_, rr_, rl = sparse_step_ref(ent, rel, pos[0], neg[0], 0.1,
                                   mode=mode, margin=margin)
    np.testing.assert_allclose(float(loss), float(rl), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ne), np.asarray(re_), atol=1e-6)
    np.testing.assert_allclose(np.asarray(nr), np.asarray(rr_), atol=1e-6)


def test_engine_pallas_impl_trains(monkeypatch):
    """The fused-kernel impl runs end-to-end through the multi-epoch scan."""
    kgs = synthesize_universe(
        seed=3, kg_stats=[("A", 6, 60000, 220000)], alignments=[]
    )
    tr = KGETrainer(kgs["A"], "transe", dim=16, seed=0, margin=2.0)
    first = tr.train_epochs(2, impl="pallas")
    last = tr.train_epochs(10, impl="pallas")
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first


# ------------------------------------------------------------ bucket padding
def test_bucket_rounding():
    assert bucket(1, 256) == 256
    assert bucket(256, 256) == 256
    assert bucket(257, 256) == 512


def test_pad_triples_pow2_batches_bounded_oversampling():
    """Triple padding rounds the minibatch COUNT to a power of two: < 2×
    oversampling (no full-bucket floor for small stores), every padded row a
    real triple."""
    rng = np.random.default_rng(0)
    tri = jnp.asarray(rng.integers(0, 50, (90, 3)).astype(np.int32))
    out = pad_triples(tri, 30)
    assert out.shape[0] == 120  # nb 3 → 4, NOT 8×30·bucket
    assert out.shape[0] < 2 * 90 + 30
    # padded rows cycle the real store
    np.testing.assert_array_equal(np.asarray(out[90:]), np.asarray(tri[:30]))
    assert pad_triples(tri[:64], 16).shape[0] == 64  # already pow2 → untouched


def test_train_ppat_rejects_empty_aligned_sets():
    from repro.core.ppat import train_ppat

    with pytest.raises(ValueError, match="non-empty aligned sets"):
        train_ppat(jnp.zeros((0, 8)), jnp.ones((5, 8)), PPATConfig(steps=2))


def test_padded_rows_stay_inert():
    """Bucket-padding rows are never sampled as negatives and never touched:
    they remain exactly zero through a full multi-epoch scan."""
    e, r, d = 70, 5, 16
    m = KGEModel("transe", e, r, d)
    p = init_kge(jax.random.PRNGKey(0), m)
    rng = np.random.default_rng(0)
    tri = np.stack(
        [rng.integers(0, e, 600), rng.integers(0, r, 600), rng.integers(0, e, 600)],
        axis=1,
    ).astype(np.int32)
    padded, e_pad, r_pad = pad_tables(p, m)
    assert e_pad == ENT_BUCKET and padded["ent"].shape[0] == ENT_BUCKET
    out, losses = _train_scan(
        padded, pad_triples(jnp.asarray(tri), 50), jax.random.PRNGKey(1),
        jnp.float32(0.5), jnp.int32(e),
        spec=shape_spec(m), epochs=4, batch=50, impl="xla", interpret=True,
    )
    assert np.asarray(losses).shape == (4,)
    np.testing.assert_array_equal(np.asarray(out["ent"][e:]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["rel"][r:]), 0.0)
    # the real rows DID train
    assert not np.array_equal(np.asarray(out["ent"][:e]), np.asarray(padded["ent"][:e]))


def test_padding_does_not_change_training():
    """Growing the physical table (same logical count, same key) leaves the
    logical result bit-identical: scores see no padding."""
    e, r, d = 64, 4, 8
    m = KGEModel("transe", e, r, d)
    p = init_kge(jax.random.PRNGKey(0), m)
    rng = np.random.default_rng(1)
    tri = np.stack(
        [rng.integers(0, e, 400), rng.integers(0, r, 400), rng.integers(0, e, 400)],
        axis=1,
    ).astype(np.int32)
    kw = dict(
        spec=shape_spec(m), epochs=3, batch=40, impl="xla", interpret=True
    )
    args = (pad_triples(jnp.asarray(tri), 40), jax.random.PRNGKey(2),
            jnp.float32(0.5), jnp.int32(e))
    small, l_small = _train_scan(p, *args, **kw)
    grown = {
        k: jnp.pad(v, ((0, 128 if k in ENT_KEYS else 32),) + ((0, 0),) * (v.ndim - 1))
        for k, v in p.items()
    }
    big, l_big = _train_scan(grown, *args, **kw)
    np.testing.assert_array_equal(np.asarray(l_small), np.asarray(l_big))
    for k in p:
        n = p[k].shape[0]
        np.testing.assert_array_equal(np.asarray(small[k]), np.asarray(big[k][:n]))


def test_train_epochs_device_roundtrip_shapes():
    """The trainer-facing wrapper pads and strips: logical shapes in, logical
    shapes out, regardless of bucket size."""
    e, r, d = 130, 7, 12
    m = KGEModel("transe", e, r, d)
    p = init_kge(jax.random.PRNGKey(0), m)
    rng = np.random.default_rng(0)
    tri = np.stack(
        [rng.integers(0, e, 90), rng.integers(0, r, 90), rng.integers(0, e, 90)],
        axis=1,
    ).astype(np.int32)
    out, losses = train_epochs_device(
        p, m, tri, jax.random.PRNGKey(1),
        epochs=2, batch_size=30, lr=0.5, impl="xla", interpret=True,
    )
    assert out["ent"].shape == (e, d) and out["rel"].shape == (r, d)
    assert losses.shape == (2,)


# --------------------------------------------------------- dispatch + retrace
def test_resolve_train_impl():
    assert resolve_train_impl("reference") == "reference"
    assert resolve_train_impl("xla", "transh") == "xla"
    # the kernel only covers the decomposable hot path → fall back
    assert resolve_train_impl("pallas", "transh") == "xla"
    assert resolve_train_impl("pallas", "transe") == "pallas"
    with pytest.raises(ValueError):
        resolve_train_impl("nope")


@pytest.fixture(scope="module")
def fed_universe():
    stats = [("A", 10, 80000, 260000), ("B", 8, 70000, 220000)]
    aligns = [("A", "B", 24000)]
    return synthesize_universe(seed=5, scale=1 / 500, kg_stats=stats,
                               alignments=aligns)


def test_federate_once_does_not_retrace(fed_universe, monkeypatch):
    """≥3 consecutive handshakes with virtual extensions active reuse the
    compiled multi-epoch scan — zero retraces after the warm-up call."""
    fed = FederationScheduler(
        fed_universe, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
        local_epochs=3, update_epochs=2, seed=0, use_virtual=True,
    )
    ve_seen = []
    real_ve = federation_mod.virtual_extension

    def spy(*a, **k):
        out = real_ve(*a, **k)
        ve_seen.append(out)
        return out

    monkeypatch.setattr(federation_mod, "virtual_extension", spy)
    fed.initial_training()
    fed.federate_once("A", "B")  # warm-up: compiles the update-epoch scan
    n_compiled = train_scan_cache_size()
    for _ in range(3):
        fed.federate_once("A", "B")
    assert ve_seen and all(v is not None for v in ve_seen), (
        "virtual extension must be active for the invariant to be meaningful"
    )
    assert train_scan_cache_size() == n_compiled, (
        "federate_once retraced the training scan across handshakes"
    )


# ------------------------------------------------------------- broadcast fix
def test_broadcast_dedupes_offers(fed_universe):
    fed = FederationScheduler(fed_universe, dim=16, local_epochs=1, seed=0)
    for _ in range(5):
        fed.broadcast("A")
    assert list(fed.queue["B"]).count("A") == 1
    assert fed._queued["B"] == {"A"}
    client = fed._pop_offer("B")
    assert client == "A" and fed._queued["B"] == set()
    fed.broadcast("A")  # re-offer after pop must queue again
    assert list(fed.queue["B"]) == ["A"]
