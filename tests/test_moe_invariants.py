"""MoE dispatch invariants (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models.moe import _dispatch_positions, apply_moe_gather, capacity, init_moe


@given(
    n=st.integers(1, 200),
    buckets=st.integers(1, 8),
    cap=st.integers(1, 64),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_dispatch_positions_invariants(n, buckets, cap, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(-1, buckets, n))  # -1 = invalid
    keep, dest = _dispatch_positions(ids, buckets, cap)
    keep = np.asarray(keep)
    dest = np.asarray(dest)
    # kept rows land in their own bucket's slot range, each slot used once
    assert (dest[keep] < buckets * cap).all()
    assert (dest[~keep] == buckets * cap).all()
    assert len(np.unique(dest[keep])) == keep.sum()  # no slot collisions
    for b in range(buckets):
        in_b = keep & (np.asarray(ids) == b)
        assert in_b.sum() <= cap  # capacity respected
        slots = dest[in_b] - b * cap
        assert ((slots >= 0) & (slots < cap)).all()
    # invalid ids are never kept
    assert not keep[np.asarray(ids) < 0].any()


def test_capacity_formula_monotone():
    cfg = reduced(get_config("mixtral-8x22b"))
    caps = [capacity(t, cfg) for t in (64, 128, 256, 1024)]
    assert caps == sorted(caps)
    assert all(c % 8 == 0 for c in caps)


@given(seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_moe_output_zero_for_zero_weights(seed):
    """Zero expert weights → zero output (routing can't leak inputs)."""
    cfg = reduced(get_config("mixtral-8x22b")).replace(dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    p = {k: (jnp.zeros_like(v) if k.startswith("w_") else v) for k, v in p.items()}
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, cfg.d_model))
    y, _ = apply_moe_gather(p, x, cfg)
    assert float(jnp.abs(y).max()) == 0.0


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (capacity wide enough for no drops)."""
    cfg = reduced(get_config("mixtral-8x22b")).replace(dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    y, _ = apply_moe_gather(p, x, cfg)
    perm = np.random.default_rng(0).permutation(16)
    y_perm, _ = apply_moe_gather(p, x[:, perm], cfg)
    np.testing.assert_allclose(
        np.asarray(y[:, perm]), np.asarray(y_perm), atol=1e-5
    )
