"""Kernel sweeps: shapes × dtypes, assert_allclose against ref.py oracles.

All Pallas kernels run in interpret mode (CPU container; TPU is the target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.csls import (
    cosine_matrix,
    cosine_matrix_ref,
    csls_matrix,
    csls_matrix_ref,
)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd_scan import ssd_chunk_kernel_apply
from repro.kernels.triple_score import pairwise_scores, pairwise_scores_ref
from repro.models.ssm import ssd


# ------------------------------------------------------------ flash attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kv,s,dh,causal,window",
    [
        (1, 2, 1, 128, 64, True, 0),
        (2, 4, 2, 256, 64, True, 0),
        (1, 4, 4, 128, 128, True, 0),   # MHA
        (1, 2, 2, 256, 32, False, 0),   # bidirectional (encoder)
        (1, 2, 1, 256, 64, True, 64),   # sliding window
        (2, 8, 2, 128, 64, True, 0),    # GQA 4:1
    ],
)
def test_flash_attention_matches_ref(b, h, kv, s, dh, causal, window, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, s, dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kv, s, dh), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, kv, s, dh), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ---------------------------------------------------------- triple score
@pytest.mark.parametrize("ord_", [1, 2])
@pytest.mark.parametrize("b,e,d", [(8, 256, 64), (13, 300, 100), (32, 512, 128), (5, 97, 48)])
def test_pairwise_scores_matches_ref(b, e, d, ord_):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, d))
    ent = jax.random.normal(jax.random.PRNGKey(1), (e, d))
    out = pairwise_scores(q, ent, ord_=ord_)
    ref = pairwise_scores_ref(q, ent, ord_=ord_)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4, rtol=1e-4)


@given(
    b=st.integers(1, 24), e=st.integers(1, 300), d=st.sampled_from([16, 32, 100])
)
@settings(max_examples=12, deadline=None)
def test_pairwise_scores_property_shapes(b, e, d):
    q = jnp.ones((b, d))
    ent = jnp.zeros((e, d))
    out = pairwise_scores(q, ent, ord_=1)
    assert out.shape == (b, e)
    np.testing.assert_allclose(np.asarray(out), -float(d), atol=1e-5)


# ------------------------------------------------------------------ csls
@pytest.mark.parametrize("n,m,d", [(128, 128, 64), (200, 150, 32), (64, 257, 100)])
def test_cosine_matrix_matches_ref(n, m, d):
    a = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(1), (m, d))
    np.testing.assert_allclose(
        np.asarray(cosine_matrix(a, b)), np.asarray(cosine_matrix_ref(a, b)),
        atol=1e-5, rtol=1e-5,
    )


def test_csls_matches_ref():
    a = jax.random.normal(jax.random.PRNGKey(0), (120, 32))
    b = jax.random.normal(jax.random.PRNGKey(1), (90, 32))
    np.testing.assert_allclose(
        np.asarray(csls_matrix(a, b)), np.asarray(csls_matrix_ref(a, b)),
        atol=1e-5, rtol=1e-5,
    )


# ------------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 64, 2, 64, 32, 64),
    (2, 256, 8, 32, 64, 64),
])
def test_ssd_kernel_matches_model_ssd(b, s, h, p, n, chunk):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.2)
    bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, 1, n)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, 1, n)) * 0.3
    yk, sk = ssd_chunk_kernel_apply(x, dt, a, bm, cm, chunk=chunk)
    yr, sr = ssd(x, dt, a, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=2e-3, rtol=1e-3)


def test_ssd_kernel_respects_initial_state():
    b, s, h, p, n = 1, 64, 2, 16, 8
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.2)
    bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, 1, n)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, 1, n)) * 0.3
    s0 = jax.random.normal(jax.random.PRNGKey(5), (b, h, p, n))
    yk, sk = ssd_chunk_kernel_apply(x, dt, a, bm, cm, chunk=32, state=s0)
    yr, sr = ssd(x, dt, a, bm, cm, 32, s0)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=2e-3, rtol=1e-3)
