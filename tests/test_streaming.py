"""Streamed (dependency-level) federation scheduling: parity, staleness,
re-offer, and crash-consistent resume mid-stream.

The contracts pinned here:

  * ``tick_sync="stream"`` with a staleness bound no run can exceed takes
    bit-identical decisions to the lockstep barrier — same accepts, same
    scores, same ε streams, bit-identical embeddings — with events emitted
    as a level-order permutation of the barrier's plan order;
  * on a dependency-serial plan (single owner) even ``staleness_bound=0``
    reproduces the barrier bit-exactly, in order;
  * ``staleness_bound=0`` on an aligned mesh fires the bounded-staleness
    gate: too-stale views are rejected as ``fault="stale"`` audit events
    and the handshake is re-offered against a re-frozen view, completing
    the round trip;
  * both tick engines agree bit-exactly under streaming with a mixed
    fault + adversary storm firing;
  * a scheduler killed between streamed passes and resumed from its
    checkpoint (frontier empty by construction, per-owner clocks and the
    view-version vector restored) continues bit-identically.
"""
import numpy as np
import pytest

from repro.core.federation import FederationScheduler, NodeState
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe
from repro.kernels.dispatch import resolve_tick_sync


@pytest.fixture(scope="module")
def universe():
    stats = [
        ("A", 12, 90000, 300000), ("B", 10, 70000, 240000),
        ("C", 8, 60000, 200000),
    ]
    aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
    return synthesize_universe(
        seed=1, scale=1 / 500, kg_stats=stats, alignments=aligns
    )


@pytest.fixture(scope="module")
def solo_universe():
    return synthesize_universe(
        seed=2, scale=1 / 500, kg_stats=[("S", 10, 80000, 260000)],
        alignments=[],
    )


def _mini_fed(universe, **kw):
    defaults = dict(
        dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
    )
    defaults.update(kw)
    return FederationScheduler(universe, **defaults)


def _event_key(e):
    # repr-compare floats: exact, and NaN == NaN. ``level`` is deliberately
    # NOT part of the key — it is the one field that legitimately differs
    # between the barrier (always 0) and the streamed level cut.
    return (e.tick, e.host, e.client or "", e.kind, e.fault or "", e.accepted,
            e.owner_clock, e.view_version,
            repr(e.score_before), repr(e.score_after), repr(e.epsilon))


def _assert_same_params(fa, fb, what):
    for n in fa.trainers:
        for k in fa.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(fa.trainers[n].params[k]),
                np.asarray(fb.trainers[n].params[k]),
                err_msg=f"{n}.{k} diverged {what}",
            )


def test_resolve_tick_sync_knob(monkeypatch):
    assert resolve_tick_sync(None) == "barrier"
    assert resolve_tick_sync("auto") == "barrier"
    assert resolve_tick_sync("streamed") == "stream"
    assert resolve_tick_sync("stream") == "stream"
    monkeypatch.setenv("REPRO_TICK_SYNC", "stream")
    assert resolve_tick_sync(None) == "stream"
    monkeypatch.setenv("REPRO_TICK_SYNC", "")
    assert resolve_tick_sync(None) == "barrier"
    with pytest.raises(ValueError, match="tick sync"):
        resolve_tick_sync("lockstep")


def test_staleness_bound_validation(universe):
    with pytest.raises(ValueError, match="staleness_bound"):
        _mini_fed(universe, staleness_bound=-1)
    fed = _mini_fed(universe)
    fed.initial_training()
    with pytest.raises(ValueError, match="staleness_bound"):
        fed.run(max_ticks=1, tick_sync="stream", staleness_bound=-2)


def test_stream_large_bound_bit_parity_vs_barrier(universe):
    """The strongest pin: with a bound no draw can exceed, streaming is a
    pure re-ordering — every decision, score, ε, clock, and embedding bit
    matches the barrier; only the level assignment differs."""
    def run_with(sync):
        fed = _mini_fed(universe)
        fed.initial_training()
        fed.run(max_ticks=3, tick_sync=sync, staleness_bound=10_000)
        return fed

    bar, strm = run_with("barrier"), run_with("stream")
    assert all(e.level == 0 for e in bar.events)
    assert any(e.level > 0 for e in strm.events), (
        "aligned 3-owner plans must cut into more than one level"
    )
    assert not any(e.fault == "stale" for e in strm.events)
    assert sorted(map(_event_key, bar.events)) == sorted(
        map(_event_key, strm.events)
    )
    assert bar.epsilons == strm.epsilons
    assert bar.accountant.epsilon() == strm.accountant.epsilon()
    assert bar.best_score == strm.best_score
    assert bar._owner_clock == strm._owner_clock
    assert bar._view_version == strm._view_version
    _assert_same_params(bar, strm, "between barrier and streamed")
    # mode interop: the same scheduler object can switch disciplines and
    # keep its clocks coherent
    strm.run(max_ticks=1, tick_sync="barrier")
    bar.run(max_ticks=1, tick_sync="barrier")
    assert sorted(map(_event_key, bar.events)) == sorted(
        map(_event_key, strm.events)
    )
    _assert_same_params(bar, strm, "after switching back to barrier")


def test_stream_bound0_serial_plan_is_barrier_in_order(solo_universe):
    """A single-owner universe plans dependency-serial passes (every entry
    shares the owner), so streaming adds no concurrency: bound=0 must
    reproduce the barrier bit-exactly IN ORDER, with no stale events."""
    def run_with(sync, bound):
        fed = _mini_fed(solo_universe)
        fed.initial_training()
        fed.run(max_ticks=3, tick_sync=sync, staleness_bound=bound)
        return fed

    bar, strm = run_with("barrier", 0), run_with("stream", 0)
    assert not any(e.fault == "stale" for e in strm.events)
    assert list(map(_event_key, bar.events)) == list(
        map(_event_key, strm.events)
    )
    assert bar.epsilons == strm.epsilons
    _assert_same_params(bar, strm, "on a dependency-serial plan")


def test_stream_bound0_fires_stale_and_reoffers(universe):
    """bound=0 on an aligned mesh: an accept at an earlier level makes any
    later-level entry reading that owner's view too stale — the entry is
    rejected as a ``fault="stale"`` audit event and re-offered against a
    re-frozen view, which completes the round trip."""
    fed = _mini_fed(universe)
    fed.initial_training()
    fed.run(max_ticks=6, tick_sync="stream", staleness_bound=0)

    stale = [e for e in fed.events if e.fault == "stale"]
    assert stale, "bound=0 on an all-pairs mesh must reject stale views"
    assert all(e.kind == "ppat" and not e.accepted for e in stale)
    # round trip: each rejected offer is re-served — same (host, client) —
    # by a live entry at the same or a later pass
    done = {
        (e.tick, e.host, e.client)
        for e in fed.events
        if e.kind == "ppat" and e.fault != "stale"
    }
    for s in stale:
        assert any(
            h == s.host and c == s.client and t >= s.tick
            for t, h, c in done
        ), f"stale offer {s.host}->{s.client} never re-served"
    # the mesh still converges and drains under the tight bound
    assert any(e.accepted and e.kind == "ppat" for e in fed.events)
    assert all(
        s in (NodeState.READY, NodeState.SLEEP) for s in fed.state.values()
    )
    assert not fed._deferred


def test_stream_mixed_storm_engine_bit_parity(universe):
    """Reference vs batched under streaming with a combined fault storm and
    Byzantine drift attack firing: the per-entry draw/key lockstep must
    hold level by level — same events, same ε, bit-identical embeddings."""
    spec = "crash=0.2,straggle=0.1,corrupt=0.1,seed=7,until=3,delay=1e6"
    adv = "drift=0.4,seed=9,strength=1.0,frac=0.4"

    def run_with(impl):
        fed = _mini_fed(
            universe, tick_faults=spec, tick_adversary=adv,
            tick_deadline=1e5, robust_agg="median",
        )
        fed.initial_training()
        fed.run(max_ticks=4, tick_impl=impl, tick_sync="stream",
                staleness_bound=10_000)
        return fed

    fa, fb = run_with("reference"), run_with("batched")
    assert any(e.fault for e in fa.events), "seeded storm must fire"
    assert any(e.attack for e in fa.events), "seeded attack must fire"
    assert list(map(_event_key, fa.events)) == list(
        map(_event_key, fb.events)
    )
    assert [e.level for e in fa.events] == [e.level for e in fb.events]
    assert fa.epsilons == fb.epsilons
    assert fa.accountant.epsilon() == fb.accountant.epsilon()
    _assert_same_params(fa, fb, "between engines under a streamed storm")


def test_stream_checkpoint_resume_bit_parity(universe, tmp_path):
    """Kill-mid-stream: a checkpoint cut between streamed passes (the
    frontier is empty at every pass boundary) restores per-owner clocks and
    the view-version vector, and the resumed run continues bit-identically
    — stale re-offers included, since bound=0 keeps the gate firing."""
    from repro.checkpoint import restore_scheduler, save_scheduler

    path = str(tmp_path / "stream.npz")
    a = _mini_fed(universe)
    a.initial_training()
    a.run(max_ticks=2, tick_sync="stream", staleness_bound=0)
    cut = a._tick
    clocks, versions = dict(a._owner_clock), dict(a._view_version)
    save_scheduler(path, a)
    a.run(max_ticks=2, tick_sync="stream", staleness_bound=0)

    b = _mini_fed(universe)
    restore_scheduler(path, b)
    assert b._tick == cut
    assert b._owner_clock == clocks and b._view_version == versions
    assert all(
        b._tick_engine.placement.version(n) == v
        for n, v in versions.items()
    )
    b.run(max_ticks=2, tick_sync="stream", staleness_bound=0)

    tail_a = [e for e in a.events if e.tick > cut]
    assert tail_a, "continuation must have executed entries"
    assert list(map(_event_key, tail_a)) == list(map(_event_key, b.events))
    assert [e.level for e in tail_a] == [e.level for e in b.events]
    assert a.epsilons == b.epsilons
    assert a.accountant.epsilon() == b.accountant.epsilon()
    assert a.best_score == b.best_score
    assert a._owner_clock == b._owner_clock
    assert a._view_version == b._view_version
    _assert_same_params(a, b, "after mid-stream resume")
