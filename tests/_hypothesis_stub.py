"""Deterministic fallback for ``hypothesis`` when it isn't installed.

The CI container has no ``hypothesis`` wheel and nothing may be pip-installed,
which made every property-test module fail at *collection* — taking the whole
tier-1 suite down with it. This stub implements the tiny slice of the API the
tests use (``given``, ``settings``, ``strategies.integers/floats/sampled_from``)
by running each property test over a fixed-seed sample of examples. It is
registered in ``conftest.py`` only when the real package is missing; with
``hypothesis`` installed the stub is inert.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)


class strategies:  # noqa: N801 — mirrors the hypothesis module name
    @staticmethod
    def integers(min_value=0, max_value=1 << 31):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda r: r.choice(seq))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # read lazily so @settings works whether applied above or below
            n = getattr(wrapper, "_stub_max_examples", None) or getattr(
                fn, "_stub_max_examples", 10
            )
            rng = random.Random(0)
            for _ in range(n):
                pos = tuple(s.example(rng) for s in arg_strategies)
                kws = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kws, **kwargs)

        # strategy-bound params are filled here, not by pytest — hide them so
        # pytest doesn't treat them as fixture requests (wraps sets
        # __wrapped__, which inspect.signature would otherwise follow)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


class HealthCheck:  # minimal placeholder for settings(suppress_health_check=…)
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def assume(condition) -> bool:
    """Real hypothesis aborts the example; the stub just skips via early
    return value — property bodies in this repo don't use assume, so this
    exists only for API completeness."""
    return bool(condition)
