"""Repo-hygiene guards: generated artifacts must never be tracked.

PR 3 accidentally shipped 12 ``__pycache__/*.pyc`` files; this pins the
cleanup — bytecode and pytest caches are ignored and a tracked one fails
tier-1 (and the ``make check-hygiene`` target) immediately.
"""
import os
import subprocess

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_ls_files():
    try:
        r = subprocess.run(
            ["git", "ls-files"], cwd=REPO, capture_output=True, text=True,
            timeout=60,
        )
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if r.returncode != 0:
        pytest.skip("not a git checkout")
    return r.stdout.splitlines()


def test_no_tracked_bytecode():
    bad = [
        f for f in _git_ls_files()
        if f.endswith((".pyc", ".pyo"))
        or "__pycache__" in f
        or ".pytest_cache" in f
    ]
    assert not bad, f"generated files are tracked in git: {bad}"


def test_gitignore_covers_bytecode():
    with open(os.path.join(REPO, ".gitignore")) as f:
        rules = {line.strip() for line in f if line.strip()}
    for rule in ("__pycache__/", "*.pyc", ".pytest_cache/"):
        assert rule in rules, f".gitignore is missing {rule!r}"
