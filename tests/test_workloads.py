"""Workload construction invariants (pure — no mesh/devices needed)."""
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.launch.workloads import LONG_CONTEXT_ARCHS, input_specs, supported


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape", [s.name for s in INPUT_SHAPES])
def test_input_specs_cover_every_pair(arch, shape):
    cfg = get_config(arch)
    sh = next(s for s in INPUT_SHAPES if s.name == shape)
    ok, why = supported(cfg, sh)
    if not ok:
        assert shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS
        assert why
        return
    specs = input_specs(cfg, shape)
    if sh.kind == "train":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
        assert specs["labels"].dtype == jnp.int32
    elif sh.kind == "prefill":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
    else:
        assert specs["token"].shape == (sh.global_batch, 1)
        assert specs["cache_pos"].shape == ()
    if cfg.encoder_layers:
        assert specs["frames"].shape == (sh.global_batch, cfg.encoder_seq, cfg.d_model)
    if cfg.num_patches and sh.kind != "decode":
        assert specs["patches"].shape == (sh.global_batch, cfg.num_patches, cfg.d_model)


def test_supported_matrix_counts():
    """40 pairs total: 33 supported + 7 documented long-context skips."""
    total = ok = 0
    for arch in ARCHS.values():
        for sh in INPUT_SHAPES:
            total += 1
            ok += supported(arch, sh)[0]
    assert total == 40
    assert ok == 33
    assert LONG_CONTEXT_ARCHS == {"mamba2-2.7b", "jamba-1.5-large-398b", "mixtral-8x22b"}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_counts_match_cards(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen3-0.6b": (0.4e9, 1.0e9),
        "whisper-medium": (0.5e9, 0.85e9),  # 769M card (enc+dec)
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "deepseek-coder-33b": (30e9, 37e9),
        "qwen2.5-3b": (2.6e9, 4e9),
        "internvl2-26b": (17e9, 26e9),   # LM backbone only (vision stubbed)
        "starcoder2-15b": (13e9, 17e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "mixtral-8x22b": (130e9, 150e9),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n/1e9:.1f}B params"
    a = cfg.active_param_count()
    assert a <= n
    if arch == "kimi-k2-1t-a32b":
        assert 25e9 <= a <= 40e9  # "a32b"
