"""KGE substrate: score functions, training, eval, virtual-table invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kge.data import corrupt_triples, synthesize_universe
from repro.kge.eval import link_prediction, triple_classification_accuracy
from repro.kge.models import (
    KGEModel,
    MODEL_FAMILIES,
    init_kge,
    margin_loss,
    normalize_entities,
    score_all_heads,
    score_all_tails,
    score_triples,
)
from repro.kge.trainer import KGETrainer


@pytest.fixture(scope="module")
def small_kgs():
    stats = [("A", 10, 80000, 280000), ("B", 8, 60000, 200000)]
    aligns = [("A", "B", 20000)]
    return synthesize_universe(seed=0, scale=1 / 400, kg_stats=stats, alignments=aligns)


@pytest.mark.parametrize("family", MODEL_FAMILIES)
def test_score_finite_all_families(family):
    m = KGEModel(family, num_entities=50, num_relations=5, dim=16)
    p = init_kge(jax.random.PRNGKey(0), m)
    h = jnp.array([0, 1, 2])
    r = jnp.array([0, 1, 2])
    t = jnp.array([3, 4, 5])
    s = score_triples(p, m, h, r, t)
    assert s.shape == (3,)
    assert jnp.isfinite(s).all()


def test_score_all_matches_pointwise():
    m = KGEModel("transe", 40, 4, 8)
    p = init_kge(jax.random.PRNGKey(1), m)
    h = jnp.array([0, 5])
    r = jnp.array([1, 2])
    full = score_all_tails(p, m, h, r)
    for j, t in enumerate([7, 13]):
        s = score_triples(p, m, h[j : j + 1], r[j : j + 1], jnp.array([t]))
        assert jnp.allclose(full[j, t], s[0], atol=1e-5)
    fullh = score_all_heads(p, m, r, jnp.array([7, 13]))
    s = score_triples(p, m, jnp.array([3]), r[:1], jnp.array([7]))
    assert jnp.allclose(fullh[0, 3], s[0], atol=1e-5)


def test_margin_loss_zero_when_separated():
    pos = jnp.array([10.0, 10.0])
    neg = jnp.array([0.0, 0.0])
    assert float(margin_loss(pos, neg, 4.0)) == 0.0
    assert float(margin_loss(neg, pos, 4.0)) == 14.0


def test_normalize_entities_unit_ball():
    m = KGEModel("transe", 30, 3, 8)
    p = init_kge(jax.random.PRNGKey(0), m)
    p = dict(p, ent=p["ent"] * 100)
    p = normalize_entities(p)
    norms = jnp.linalg.norm(p["ent"], axis=-1)
    assert float(norms.max()) <= 1.0 + 1e-5


def test_training_reduces_loss_and_beats_untrained(small_kgs):
    kg = small_kgs["A"]
    tr = KGETrainer(kg, "transe", dim=32, seed=0, margin=2.0)
    first = tr.train_epochs(5)
    for _ in range(5):
        last = tr.train_epochs(25)
    assert last < first * 0.7
    acc = triple_classification_accuracy(tr.params, tr.model, kg)
    untrained = KGETrainer(kg, "transe", dim=32, seed=9, margin=2.0)
    acc0 = triple_classification_accuracy(untrained.params, untrained.model, kg)
    assert acc > acc0 + 0.05


def test_link_prediction_metrics_sane(small_kgs):
    kg = small_kgs["B"]
    tr = KGETrainer(kg, "transe", dim=32, seed=0, margin=2.0)
    tr.train_epochs(100)
    lp = link_prediction(tr.params, tr.model, kg, max_test=60)
    assert 1.0 <= lp["mean_rank"] <= kg.num_entities
    assert 0.0 <= lp["hit@1"] <= lp["hit@3"] <= lp["hit@10"] <= 1.0


def test_corrupt_triples_changes_one_side():
    rng = np.random.default_rng(0)
    tri = np.array([[1, 0, 2]] * 100, dtype=np.int32)
    neg = corrupt_triples(rng, tri, 50)
    changed_h = neg[:, 0] != 1
    changed_t = neg[:, 2] != 2
    assert ((changed_h & ~changed_t) | (~changed_h & changed_t) |
            (~changed_h & ~changed_t)).all()  # at most one side corrupted
    assert (neg[:, 1] == 0).all()


def test_virtual_extension_roundtrip(small_kgs):
    kg = small_kgs["A"]
    tr = KGETrainer(kg, "transe", dim=16, seed=0)
    e0, r0 = tr.model.num_entities, tr.model.num_relations
    v_ent = jnp.ones((5, 16)) * 0.1
    v_rel = jnp.ones((2, 16)) * 0.2
    extra = np.array([[e0, r0, 3], [1, r0 + 1, e0 + 4]], dtype=np.int64)
    tr.extend_tables(v_ent, v_rel, extra)
    assert tr.model.num_entities == e0 + 5
    assert tr.params["ent"].shape[0] == e0 + 5
    tr.train_epochs(1)  # trains with the virtual triples
    tr.strip_virtual()
    assert tr.model.num_entities == e0
    assert tr.params["ent"].shape[0] == e0


def test_universe_alignment_consistency(small_kgs):
    a, b = small_kgs["A"], small_kgs["B"]
    ia, ib = a.aligned_with(b)
    assert len(ia) > 30
    assert (a.universe_ids[ia] == b.universe_ids[ib]).all()
