"""Serving resilience: seeded chaos plans, failure isolation (retry on a
different replica, bit-equal), the circuit breaker (open → probe →
re-admit), poisoned-output screening, straggler hedging, deadline shedding,
max_queue admission rejects, the submit/dispatch version-race re-check, and
the served + shed + failed == submitted accounting invariant — standalone
and under a combined fault storm × live federation ticks × hot-swap."""
import itertools

import jax
import numpy as np
import pytest

from repro.core.faults import ServeFault, ServeFaultError, ServeFaultPlan
from repro.kernels.dispatch import resolve_serve_faults
from repro.kge.models import KGEModel
from repro.kge.trainer import init_kge
from repro.serving import (
    KGECandidateRanker,
    KGEServingTier,
    TierOverloadError,
    serving_program_cache_size,
)

E, R, D = 300, 6, 16


def _tri(n, seed):
    rng = np.random.default_rng(seed)
    return np.stack(
        [rng.integers(0, E, n), rng.integers(0, R, n), rng.integers(0, E, n)],
        axis=1,
    ).astype(np.int64)


@pytest.fixture(scope="module")
def kge_world():
    m = KGEModel("transe", E, R, D)
    params = init_kge(jax.random.PRNGKey(1), m)
    known = _tri(400, seed=100)
    return m, params, known


def _two_replica_tier(kge_world, **kw):
    m, params, known = kge_world
    dev = jax.devices()[0]
    kw.setdefault("block_e", 64)
    kw.setdefault("max_batch", 8)
    return KGEServingTier(params, m, known, replicas=2,
                          devices=[dev, dev], **kw)


def _check_sums(tier):
    s = tier.stats
    assert s["served"] + s["shed"] + s["failed"] == s["submitted"], s


# ---------------------------------------------------------------------------
# ServeFaultPlan: determinism, grammar, resolution
# ---------------------------------------------------------------------------
def test_serve_fault_plan_draws_deterministic():
    plan = ServeFaultPlan(crash=0.3, straggle=0.3, poison=0.2, seed=7)
    a = [plan.draw(b, r) for b in range(40) for r in range(2)]
    b = [plan.draw(b, r) for b in range(40) for r in range(2)]
    assert [f and f.kind for f in a] == [f and f.kind for f in b]
    kinds = {f.kind for f in a if f is not None}
    assert kinds  # at 80% total rate over 80 draws something must fire
    assert kinds <= {"crash", "straggle", "poison"}
    # a different seed reshuffles the schedule
    c = [ServeFaultPlan(crash=0.3, straggle=0.3, poison=0.2, seed=8).draw(b, r)
         for b in range(40) for r in range(2)]
    assert [f and f.kind for f in a] != [f and f.kind for f in c]


def test_serve_fault_plan_until_and_table():
    plan = ServeFaultPlan(crash=1.0, until=3)
    assert all(plan.draw(b, 0).kind == "crash" for b in range(4))
    assert all(plan.draw(b, 0) is None for b in range(4, 10))
    pinned = ServeFaultPlan(
        table={(2, 1): ServeFault("straggle", delay=0.5)}
    )
    assert pinned.draw(2, 1).delay == 0.5
    assert pinned.draw(2, 0) is None and pinned.draw(1, 1) is None
    with pytest.raises(ValueError):
        ServeFaultPlan(crash=1.5)


def test_serve_fault_plan_parse_grammar():
    p = ServeFaultPlan.parse("crash=0.2,straggle=0.1,seed=7,until=40,delay=0.5,rows=2")
    assert (p.crash, p.straggle, p.seed, p.until, p.delay, p.rows) == \
        (0.2, 0.1, 7, 40, 0.5, 2)
    assert ServeFaultPlan.parse("on").crash == 0.0  # armed but inert
    with pytest.raises(ValueError):
        ServeFaultPlan.parse("explode=1")


def test_resolve_serve_faults(monkeypatch):
    assert resolve_serve_faults(None) is None
    monkeypatch.setenv("REPRO_SERVE_FAULTS", "off")
    assert resolve_serve_faults(None) is None
    monkeypatch.setenv("REPRO_SERVE_FAULTS", "crash=0.5,seed=3")
    assert resolve_serve_faults(None) == "crash=0.5,seed=3"
    plan = ServeFaultPlan(poison=0.1)
    assert resolve_serve_faults(plan) is plan  # programmatic passthrough


# ---------------------------------------------------------------------------
# Failure isolation: retry on another replica, bit-equal results
# ---------------------------------------------------------------------------
def test_crash_retries_on_other_replica_bit_equal(kge_world):
    m, params, known = kge_world
    # launch seq 0 routes to slot 0 (fresh tier) and crashes; the retry
    # must land on slot 1 and serve the SAME pinned version bit-equal
    tier = _two_replica_tier(
        kge_world,
        serve_faults=ServeFaultPlan(table={(0, 0): ServeFault("crash")}),
    )
    q = _tri(5, seed=1)
    req = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    assert req.state == "served" and req.error is None
    assert tier.stats["retried"] == 1 and tier.stats["failed"] == 0
    assert tier.fault_counts == {"crash": 1}
    assert [rp.fails for rp in tier.replicas] == [1, 0]
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    np.testing.assert_array_equal(
        req.result, ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    )
    _check_sums(tier)


def test_retry_exhaustion_fails_requests_not_tier(kge_world):
    tier = _two_replica_tier(
        kge_world, serve_faults=ServeFaultPlan(crash=1.0), retry_limit=1
    )
    q = _tri(4, seed=2)
    req = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    ok = tier.submit_rank(q[:1, 0], q[:1, 1], q[:1, 2])
    # first batch: primary + retry both crash -> its requests fail;
    # the tier itself keeps serving (and failing) later traffic
    tier.run_until_drained()
    assert req.state == "failed" and isinstance(req.error, ServeFaultError)
    assert ok.state == "failed"  # crash=1.0: everything crashes
    assert tier.stats["failed"] == tier.stats["submitted"] == 2
    _check_sums(tier)


def test_poison_screened_and_retried(kge_world):
    m, params, known = kge_world
    tier = _two_replica_tier(
        kge_world,
        serve_faults=ServeFaultPlan(
            table={(0, 0): ServeFault("poison", rows=2)}
        ),
    )
    q = _tri(6, seed=3)
    req = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    assert req.state == "served"
    assert tier.stats["retried"] == 1 and tier.stats["failed"] == 0
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    np.testing.assert_array_equal(
        req.result, ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    )
    _check_sums(tier)


def test_poison_screen_topk(kge_world):
    m, params, known = kge_world
    tier = _two_replica_tier(
        kge_world,
        serve_faults=ServeFaultPlan(table={(0, 0): ServeFault("poison")}),
    )
    q = _tri(4, seed=4)
    req = tier.submit_topk(q[:, 0], q[:, 1], k=5)
    tier.run_until_drained()
    assert req.state == "served" and tier.stats["retried"] == 1
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    ids, vals = req.result
    rids, rvals = ranker.topk_tails(q[:, 0], q[:, 1], k=5)
    np.testing.assert_array_equal(ids, rids)
    np.testing.assert_array_equal(vals, rvals)
    _check_sums(tier)


# ---------------------------------------------------------------------------
# Circuit breaker: open on consecutive failures, probe re-admission
# ---------------------------------------------------------------------------
def test_breaker_opens_probes_and_readmits(kge_world):
    tier = _two_replica_tier(
        kge_world,
        serve_faults=ServeFaultPlan(crash=1.0, until=1),  # seqs 0,1 crash
        retry_limit=0, breaker_fails=1, probe_after=4,
    )
    q = _tri(3, seed=5)
    a = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()  # seq 0 -> slot 0 crashes, breaker opens
    assert a.state == "failed"
    assert tier.stats["breaker_open"] == 1
    assert [rp.healthy for rp in tier.replicas] == [False, True]
    b = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()  # seq 1 -> slot 1 (only healthy) crashes too
    assert b.state == "failed"
    assert tier.stats["breaker_open"] == 2
    assert [rp.healthy for rp in tier.replicas] == [False, False]
    # storm over (seq > until): the whole-ring fallback serves, and the
    # success closes the breaker on whichever replica took the probe
    c = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    assert c.state == "served"
    assert tier.stats["breaker_close"] >= 1
    assert any(rp.healthy for rp in tier.replicas)
    h = tier.health()
    assert {x["slot"] for x in h} == {0, 1}
    assert all(x["ewma_ms"] is None or x["ewma_ms"] >= 0 for x in h)
    _check_sums(tier)


def test_probe_due_replica_rejoins_pool(kge_world):
    tier = _two_replica_tier(kge_world, breaker_fails=1, probe_after=2)
    rep0 = tier.replicas[0]
    tier._note_failure(rep0)
    assert not rep0.healthy and tier.stats["breaker_open"] == 1
    # probe not due yet: pool excludes the open replica
    assert rep0 not in tier._eligible()
    tier._seq = rep0.probe_at  # advance the launch clock to the probe
    assert rep0 in tier._eligible()
    picked = tier._pick_replica()
    if picked is rep0:  # the pick IS the probe: next probe pushed out
        assert rep0.probe_at == tier._seq + tier.probe_after
    tier._note_success(rep0, 0.001)
    assert rep0.healthy and rep0.fails == 0
    assert tier.stats["breaker_close"] == 1


# ---------------------------------------------------------------------------
# Straggle + hedging
# ---------------------------------------------------------------------------
def test_straggle_hedge_first_result_wins_bit_equal(kge_world):
    m, params, known = kge_world
    # primary launch straggles 30s (simulated); the hedge to the other
    # replica wins long before that — results must be bit-equal anyway
    tier = _two_replica_tier(
        kge_world,
        serve_faults=ServeFaultPlan(
            table={(0, 0): ServeFault("straggle", delay=30.0)}
        ),
        hedge_after=0.01,
    )
    q = _tri(5, seed=6)
    req = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    assert req.state == "served" and req.latency < 30.0
    assert tier.stats["hedged"] == 1 and tier.stats["failed"] == 0
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    np.testing.assert_array_equal(
        req.result, ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    )
    # the straggling loser was reaped as a zombie: no leaked in-flight slot
    assert all(rp.inflight == 0 for rp in tier.replicas)
    assert not tier._zombies
    _check_sums(tier)


def test_straggle_without_hedge_just_waits(kge_world):
    tier = _two_replica_tier(
        kge_world,
        serve_faults=ServeFaultPlan(
            table={(0, 0): ServeFault("straggle", delay=0.05)}
        ),
    )
    q = _tri(3, seed=7)
    req = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    assert req.state == "served" and tier.stats["hedged"] == 0
    assert req.latency >= 0.05  # the simulated delay was honored
    _check_sums(tier)


# ---------------------------------------------------------------------------
# Admission control: max_queue reject, deadline shed
# ---------------------------------------------------------------------------
def test_max_queue_rejects_at_submit(kge_world):
    m, params, known = kge_world
    tier = KGEServingTier(params, m, known, block_e=64, max_queue=2)
    q = _tri(2, seed=8)
    tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    with pytest.raises(TierOverloadError):
        tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    assert tier.stats["rejected"] == 1 and tier.stats["submitted"] == 2
    tier.run_until_drained()
    assert tier.stats["served"] == 2
    _check_sums(tier)  # rejected requests never entered the accounting


def test_deadline_shed_at_coalesce(kge_world):
    m, params, known = kge_world
    tier = KGEServingTier(params, m, known, block_e=64, max_batch=8)
    q = _tri(2, seed=9)
    doomed = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2], deadline=0.0)
    live = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    mid = tier.submit_topk(q[:, 0], q[:, 1], k=3, deadline=0.0)
    tier.run_until_drained()
    assert doomed.state == "shed" and doomed.done
    assert doomed.result is None and doomed.error is None  # shed != failed
    assert doomed.finished_at is not None
    assert mid.state == "shed"
    assert live.state == "served"
    assert tier.stats["shed"] == 2 and tier.stats["served"] == 1
    assert tier.stats["failed"] == 0
    _check_sums(tier)


# ---------------------------------------------------------------------------
# Submit/dispatch version race (regression)
# ---------------------------------------------------------------------------
def test_version_race_recheck_against_pinned_version(kge_world):
    m, params, known = kge_world
    tier = KGEServingTier(params, m, known, block_e=64, max_batch=8)
    q = _tri(4, seed=10)
    bad_ent = int(q[0, 0])
    # valid under v0 at submit time...
    racy = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    clean_q = _tri(4, seed=11)
    clean_q[:, [0, 2]] = np.where(
        clean_q[:, [0, 2]] == bad_ent, (bad_ent + 1) % E, clean_q[:, [0, 2]]
    )
    clean = tier.submit_rank(clean_q[:, 0], clean_q[:, 1], clean_q[:, 2])
    # ...then a hot-swap lands BEFORE dispatch and poisons that entity row
    p2 = {k: np.array(v, copy=True) for k, v in tier._active.params.items()}
    p2["ent"][bad_ent] = np.nan
    tier.publish(p2)
    tier.run_until_drained()
    assert racy.state == "failed"
    assert isinstance(racy.error, ValueError)
    assert "dispatch version" in str(racy.error)
    # requests not touching the poisoned row still serve on the new version
    assert clean.state == "served" and clean.version == 1
    _check_sums(tier)


# ---------------------------------------------------------------------------
# Faults-off / armed-inert bit-identity
# ---------------------------------------------------------------------------
def test_armed_inert_screen_is_bit_identical(kge_world):
    m, params, known = kge_world
    q = _tri(12, seed=12)
    base = KGEServingTier(params, m, known, block_e=64, max_batch=8)
    r0 = base.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    base.run_until_drained()
    n0 = serving_program_cache_size()
    armed = KGEServingTier(params, m, known, block_e=64, max_batch=8,
                           serve_faults="screen")
    assert armed.fault_plan is not None  # armed: output screen active
    r1 = armed.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    armed.run_until_drained()
    np.testing.assert_array_equal(r0.result, r1.result)
    assert serving_program_cache_size() == n0  # no new programs
    assert armed.stats["retried"] == 0 and armed.stats["failed"] == 0
    _check_sums(armed)


# ---------------------------------------------------------------------------
# Combined: fault storm x live federation ticks x hot-swap (PR 6 x PR 8)
# ---------------------------------------------------------------------------
def test_fault_storm_under_live_ticks_and_hot_swap():
    from repro.core.federation import FederationScheduler
    from repro.core.ppat import PPATConfig
    from repro.kge.data import synthesize_universe

    kgs = synthesize_universe(
        seed=1, scale=1 / 500,
        kg_stats=[("A", 12, 90000, 300000), ("B", 10, 70000, 250000)],
        alignments=[("A", "B", 30000)],
    )
    ctr = itertools.count()
    sched = FederationScheduler(
        kgs, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
        local_epochs=2, update_epochs=2, seed=0,
        score_fn=lambda name: float(next(ctr)),
    )
    sched.initial_training()
    dev = jax.devices()[0]
    tier = KGEServingTier.for_owner(
        sched, "A", block_e=64, max_batch=16,
        replicas=2, devices=[dev, dev], home_slot=0,
        serve_faults=ServeFaultPlan(crash=0.4, straggle=0.2, seed=3,
                                    until=60, delay=0.005),
        retry_limit=2, breaker_fails=2, probe_after=4,
    )
    v0 = tier.version
    q = np.asarray(kgs["A"].test)
    q = np.concatenate([q] * (24 // len(q) + 1))[:24] if len(q) < 24 else q
    reqs = []
    # phase 1: traffic into the storm, dispatched on v0
    for i in range(0, 12, 3):
        reqs.append(tier.submit_rank(q[i:i + 3, 0], q[i:i + 3, 1],
                                     q[i:i + 3, 2]))
        tier.step()
    # phase 2: federation ticks flip versions mid-storm (in-flight batches
    # finish — and RETRY — on their pinned version)
    sched.run(max_ticks=2)
    assert tier.version > v0
    for i in range(12, 24, 3):
        reqs.append(tier.submit_rank(q[i:i + 3, 0], q[i:i + 3, 1],
                                     q[i:i + 3, 2]))
    tier.run_until_drained()  # asserts served+shed+failed == submitted
    # zero LOST requests: every single one resolved
    assert all(r.done for r in reqs)
    assert {r.state for r in reqs} <= {"served", "failed"}
    assert tier.fault_counts.get("crash", 0) >= 1  # the storm actually hit
    assert tier.stats["retried"] >= 1
    # every served result is bit-equal to a per-call ranker on the exact
    # version that served it
    known = np.concatenate([kgs["A"].train, kgs["A"].valid, kgs["A"].test])
    served = [r for r in reqs if r.state == "served"]
    assert served  # the storm must not have failed everything
    tr = sched.trainers["A"]
    now = KGECandidateRanker(dict(tr.params), tr.model, known, block_e=64)
    cur = tier.version
    for i, r in enumerate(reqs):
        if r.state == "served" and r.version == cur:
            lo = (i * 3) % len(q)
            np.testing.assert_array_equal(
                r.result, now.rank_tails(q[lo:lo + 3, 0], q[lo:lo + 3, 1],
                                         q[lo:lo + 3, 2])
            )
    _check_sums(tier)
    assert tier.stats["publish_errors"] == 0


def test_drain_accounting_invariant_guard(kge_world):
    m, params, known = kge_world
    tier = KGEServingTier(params, m, known, block_e=64)
    q = _tri(2, seed=13)
    tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.stats["submitted"] += 1  # sabotage the books
    with pytest.raises(RuntimeError, match="accounting"):
        tier.run_until_drained()
