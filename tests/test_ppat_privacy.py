"""PPAT + PATE + moments accountant: unit and hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pate import pate_vote, teacher_votes
from repro.core.ppat import PPATConfig, PPATClient, PPATHost, train_ppat
from repro.core.privacy import MomentsAccountant
from repro.core.alignment import csls, csls_retrieval_acc, procrustes


# ------------------------------------------------------------------ PATE
def test_teacher_votes_hard():
    probs = jnp.array([[0.1, 0.9], [0.6, 0.4]])
    v = teacher_votes(probs)
    assert (v == jnp.array([[0, 1], [1, 0]])).all()


def test_pate_vote_counts_clean():
    votes = jnp.array([[1, 0], [1, 0], [1, 1], [0, 0]])  # (T=4, B=2)
    # λ large → Lap(1/λ) noise vanishes → the clean majority wins
    labels, n0, n1 = pate_vote(jax.random.PRNGKey(0), votes, lam=1000.0)
    assert (n1 == jnp.array([3, 1])).all()
    assert (n0 == jnp.array([1, 3])).all()
    assert (labels == jnp.array([1.0, 0.0])).all()


def test_pate_vote_no_noise_mode():
    votes = jnp.array([[1, 0], [1, 0], [1, 1], [0, 0]])
    labels, _, _ = pate_vote(jax.random.PRNGKey(0), votes, lam=0.0)
    assert (labels == jnp.array([1.0, 0.0])).all()


def test_pate_vote_noise_flips_sometimes():
    votes = jnp.ones((4, 200), jnp.int32)  # unanimous 1
    # λ small → Lap(1/λ)=Lap(100) noise → labels ≈ coin flips
    labels, _, _ = pate_vote(jax.random.PRNGKey(1), votes, lam=0.01)
    assert float(labels.mean()) < 0.9


# ---------------------------------------------------- moments accountant
@given(
    st.integers(min_value=0, max_value=4),
    st.integers(min_value=1, max_value=50),
)
@settings(max_examples=25, deadline=None)
def test_accountant_monotone_in_queries(n1, reps):
    acc = MomentsAccountant(lam=0.05, delta=1e-5)
    eps_hist = []
    for _ in range(reps):
        acc.update(4 - n1, n1)
        eps_hist.append(acc.epsilon())
    assert all(b >= a - 1e-12 for a, b in zip(eps_hist, eps_hist[1:]))
    assert acc.queries == reps
    assert np.isfinite(acc.epsilon())


@given(st.floats(min_value=0.01, max_value=2.0))
@settings(max_examples=20, deadline=None)
def test_accountant_alpha_nonnegative(lam):
    acc = MomentsAccountant(lam=lam, delta=1e-5)
    acc.update(0, 4)
    acc.update(2, 2)
    assert (acc.alpha >= 0).all()


def test_accountant_bounded_by_data_independent():
    """Per-query α(l) ≤ 2λ²l(l+1) — the min in Eq. 9."""
    lam = 0.05
    acc = MomentsAccountant(lam=lam, delta=1e-5)
    acc.update(4, 0)
    upper = 2 * lam**2 * acc.ls * (acc.ls + 1)
    assert (acc.alpha <= upper + 1e-12).all()


def test_paper_epsilon_arithmetic():
    """§4.1.2: per-handshake α ≤ 0.29, ln(1/δ)=11.5, l=9 → ε̂ = 2.73 over
    the paper's federation run. We verify the bound arithmetic exactly."""
    alpha_per_handshake = 0.29
    n_handshakes = 45
    delta = 1e-5
    eps = (alpha_per_handshake * n_handshakes + np.log(1 / delta)) / 9
    assert abs(eps - 2.73) < 0.01


# ------------------------------------------------------------------ PPAT
@pytest.fixture(scope="module")
def rotation_pair():
    key = jax.random.PRNGKey(0)
    d, n = 24, 300
    x = jax.random.normal(key, (n, d))
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (d, d)))
    y = x @ q + 0.01 * jax.random.normal(jax.random.PRNGKey(2), (n, d))
    return x, y


def test_ppat_interface_shapes(rotation_pair):
    """The privacy boundary: client→host is (B, d); host→client is (B, d)."""
    x, y = rotation_pair
    cfg = PPATConfig(steps=3)
    host = PPATHost(jax.random.PRNGKey(0), x.shape[1], y, cfg)
    client = PPATClient(jax.random.PRNGKey(1), x.shape[1], x, cfg)
    xb, adv = client.sample_batch()
    assert adv.shape == (cfg.batch, x.shape[1])
    grad, metrics = host.step(jax.random.PRNGKey(2), adv)
    assert grad.shape == adv.shape
    assert set(metrics) >= {"gen_loss", "student_loss", "teacher_loss"}
    client.apply_grad(xb, grad)
    assert host.accountant.queries == cfg.batch  # one PATE query per sample


def test_ppat_plus_refinement_recovers_rotation(rotation_pair):
    x, y = rotation_pair
    client, host, hist = train_ppat(x, y, PPATConfig(steps=120, seed=0))
    synth = client.generate(x)
    r = procrustes(synth, y)
    acc = csls_retrieval_acc(synth @ r, y)
    assert acc > 0.5  # host-local refinement makes the DP release usable
    assert np.isfinite(hist["epsilon"]) and hist["epsilon"] > 0


def test_ppat_w_changes_and_epsilon_grows(rotation_pair):
    x, y = rotation_pair
    c1, h1, hist1 = train_ppat(x, y, PPATConfig(steps=20, seed=0))
    c2, h2, hist2 = train_ppat(x, y, PPATConfig(steps=60, seed=0))
    assert float(jnp.abs(c1.w - jnp.eye(x.shape[1])).sum()) > 1e-3
    assert hist2["epsilon"] >= hist1["epsilon"]  # more queries, more ε


def test_csls_identity_best_on_self():
    a = jax.random.normal(jax.random.PRNGKey(0), (50, 16))
    s = csls(a, a)
    assert float(jnp.mean(jnp.argmax(s, axis=1) == jnp.arange(50))) > 0.9


def test_procrustes_exact_on_orthogonal_map():
    a = jax.random.normal(jax.random.PRNGKey(0), (100, 16))
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (16, 16)))
    r = procrustes(a, a @ q)
    assert jnp.allclose(r, q, atol=1e-4)
