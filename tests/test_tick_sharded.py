"""Multi-device sharded tick execution — run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the flag never
leaks into the main test process (smoke tests must see 1 device).

Pins the two tentpole contracts of the sharded tick engine:
  * bit-parity — sharded execution (shard_map signature buckets +
    hash-placed singletons) reproduces ``tick_impl="reference"`` exactly at
    ≥4 simulated host devices: decisions, scores, ε history, final
    embeddings;
  * trace-time program dedup — 8 equal-shaped owners compile exactly ONE
    tick-entry program per tick kind (``tick_program_cache_size``), not one
    per owner.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PARITY_HELPERS = """
import math
import numpy as np

def assert_parity(ref, bat, kgs):
    er = [(e.tick, e.host, e.client, e.kind, e.accepted) for e in ref.events]
    eb = [(e.tick, e.host, e.client, e.kind, e.accepted) for e in bat.events]
    assert er == eb, (er, eb)
    for r, b in zip(ref.events, bat.events):
        assert r.score_before == b.score_before, (r, b)
        assert r.score_after == b.score_after, (r, b)
        assert (math.isnan(r.epsilon) and math.isnan(b.epsilon)) or (
            r.epsilon == b.epsilon
        ), (r, b)
    assert ref.best_score == bat.best_score
    assert ref.epsilons == bat.epsilons
    for n in kgs:
        for k in ref.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(ref.trainers[n].params[k]),
                np.asarray(bat.trainers[n].params[k]),
                err_msg=f"{n}.{k} diverged between tick impls",
            )
"""


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", PARITY_HELPERS + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_parity_equal_owners_hit10_virtual():
    """shard_map bucket path: 4 equal-shaped owners share one signature, so
    each tick runs as ONE SPMD program over the owner mesh — bit-identical
    to the serial reference loop (hit@10 backtracking, virtual extension)."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 8
        kgs = equal_shape_universe(
            4, entities=120, relations=6, triples=900, shared=32, seed=1
        )

        def make():
            return FederationScheduler(
                kgs, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
                local_epochs=2, update_epochs=2, seed=0,
                score_metric="hit10", score_max_test=24,
            )

        feds = {}
        for impl, kw in (
            ("reference", {}),
            ("batched", dict(tick_placement="sharded")),
        ):
            f = make()
            f.initial_training()
            f.run(max_ticks=3, tick_impl=impl, **kw)
            feds[impl] = f
        assert_parity(feds["reference"], feds["batched"], kgs)
        print("SHARDED_GROUP_PARITY_OK")
        """
    )
    assert "SHARDED_GROUP_PARITY_OK" in out


def test_sharded_parity_distinct_owners_singletons():
    """Singleton path: owners with distinct shapes never share a signature,
    so every entry is device_put onto its signature-hash device (distinct
    signatures may collide on a device — placement trades load balance for
    compile stability) — still bit-identical to the reference loop."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.kge.data import synthesize_universe

        assert len(jax.devices()) == 8
        stats = [("A", 12, 90000, 300000), ("B", 10, 70000, 240000),
                 ("C", 8, 60000, 200000)]
        aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
        kgs = synthesize_universe(seed=1, scale=1 / 500, kg_stats=stats,
                                  alignments=aligns)

        def make():
            return FederationScheduler(
                kgs, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
                local_epochs=2, update_epochs=2, seed=0, score_max_test=30,
            )

        feds = {}
        for impl, kw in (
            ("reference", {}),
            ("batched", dict(tick_placement="sharded")),
        ):
            f = make()
            f.initial_training()
            f.run(max_ticks=2, tick_impl=impl, **kw)
            feds[impl] = f
        assert_parity(feds["reference"], feds["batched"], kgs)
        print("SHARDED_SINGLETON_PARITY_OK")
        """
    )
    assert "SHARDED_SINGLETON_PARITY_OK" in out


def test_sharded_program_dedup_eight_equal_owners():
    """8 equal-shaped owners on 8 devices: an all-handshake tick compiles
    exactly ONE tick-entry program (the shard_map bucket program), and an
    all-self-train tick adds exactly one more; placement auto-resolves to
    sharded in a multi-device process."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.core.tick_engine import tick_program_cache_size
        from repro.kernels.dispatch import resolve_tick_placement
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 8
        assert resolve_tick_placement(None) == "sharded"  # auto, 8 devices

        kgs = equal_shape_universe(
            8, entities=120, relations=6, triples=900, shared=32, seed=2
        )
        fed = FederationScheduler(
            kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
            local_epochs=2, update_epochs=2, seed=0, use_virtual=False,
            score_max_test=24,
        )
        fed.initial_training()
        assert tick_program_cache_size() == 0
        fed.run(max_ticks=1, tick_impl="batched")  # 8 equal ppat entries
        assert tick_program_cache_size() == 1, tick_program_cache_size()
        # steady state: the next all-handshake tick reuses the program
        fed.run(max_ticks=1, tick_impl="batched")
        assert tick_program_cache_size() == 1, tick_program_cache_size()
        for n in kgs:
            fed.queue[n].clear()
            fed._queued[n].clear()
        fed.run(max_ticks=1, tick_impl="batched")  # 8 equal self-train entries
        assert tick_program_cache_size() == 2, tick_program_cache_size()
        # regression: sharded ticks must not leave trainer state committed
        # across devices — switching placement or dropping to the serial
        # reference loop afterwards has to keep working
        fed.run(max_ticks=1, tick_impl="batched", tick_placement="single")
        fed.run(max_ticks=1, tick_impl="reference")
        fed.run(max_ticks=1, tick_impl="batched", tick_placement="sharded")
        print("SHARDED_DEDUP_OK")
        """
    )
    assert "SHARDED_DEDUP_OK" in out
