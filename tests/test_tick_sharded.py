"""Multi-device sharded tick execution — run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the flag never
leaks into the main test process (smoke tests must see 1 device).

Pins the tentpole contracts of the sharded tick engine:
  * bit-parity — sharded execution (shard_map signature buckets +
    home-placed singletons) reproduces ``tick_impl="reference"`` exactly at
    ≥4 simulated host devices: decisions, scores, ε history, final
    embeddings;
  * trace-time program dedup — 8 equal-shaped owners compile exactly ONE
    tick-entry program per tick kind (``tick_program_cache_size``), not one
    per owner;
  * owner-sticky device residency — owners keep their home device across
    plan recompositions, steady-state ticks move ZERO cached immutable
    inputs (transfer-guard pinned), group chunks pad to full-mesh/pow-2
    extents with masked dummy entries, and non-sharded consumers accept the
    committed results.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PARITY_HELPERS = """
import math
import numpy as np

def assert_parity(ref, bat, kgs):
    er = [(e.tick, e.host, e.client, e.kind, e.accepted) for e in ref.events]
    eb = [(e.tick, e.host, e.client, e.kind, e.accepted) for e in bat.events]
    assert er == eb, (er, eb)
    for r, b in zip(ref.events, bat.events):
        assert r.score_before == b.score_before, (r, b)
        assert r.score_after == b.score_after, (r, b)
        assert (math.isnan(r.epsilon) and math.isnan(b.epsilon)) or (
            r.epsilon == b.epsilon
        ), (r, b)
    assert ref.best_score == bat.best_score
    assert ref.epsilons == bat.epsilons
    for n in kgs:
        for k in ref.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(ref.trainers[n].params[k]),
                np.asarray(bat.trainers[n].params[k]),
                err_msg=f"{n}.{k} diverged between tick impls",
            )
"""


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", PARITY_HELPERS + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_parity_equal_owners_hit10_virtual():
    """shard_map bucket path: 4 equal-shaped owners share one signature, so
    each tick runs as ONE SPMD program over the owner mesh — bit-identical
    to the serial reference loop (hit@10 backtracking, virtual extension)."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 8
        kgs = equal_shape_universe(
            4, entities=120, relations=6, triples=900, shared=32, seed=1
        )

        def make():
            return FederationScheduler(
                kgs, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
                local_epochs=2, update_epochs=2, seed=0,
                score_metric="hit10", score_max_test=24,
            )

        feds = {}
        for impl, kw in (
            ("reference", {}),
            ("batched", dict(tick_placement="sharded")),
        ):
            f = make()
            f.initial_training()
            f.run(max_ticks=3, tick_impl=impl, **kw)
            feds[impl] = f
        assert_parity(feds["reference"], feds["batched"], kgs)
        print("SHARDED_GROUP_PARITY_OK")
        """
    )
    assert "SHARDED_GROUP_PARITY_OK" in out


def test_sharded_parity_distinct_owners_singletons():
    """Singleton path: owners with distinct shapes never share a signature,
    so every entry runs alone on its owner's sticky home device (distinct
    owners may share a home when owners outnumber devices — placement trades
    load balance for residency) — still bit-identical to the reference
    loop."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.kge.data import synthesize_universe

        assert len(jax.devices()) == 8
        stats = [("A", 12, 90000, 300000), ("B", 10, 70000, 240000),
                 ("C", 8, 60000, 200000)]
        aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
        kgs = synthesize_universe(seed=1, scale=1 / 500, kg_stats=stats,
                                  alignments=aligns)

        def make():
            return FederationScheduler(
                kgs, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
                local_epochs=2, update_epochs=2, seed=0, score_max_test=30,
            )

        feds = {}
        for impl, kw in (
            ("reference", {}),
            ("batched", dict(tick_placement="sharded")),
        ):
            f = make()
            f.initial_training()
            f.run(max_ticks=2, tick_impl=impl, **kw)
            feds[impl] = f
        assert_parity(feds["reference"], feds["batched"], kgs)
        print("SHARDED_SINGLETON_PARITY_OK")
        """
    )
    assert "SHARDED_SINGLETON_PARITY_OK" in out


def test_sharded_program_dedup_eight_equal_owners():
    """8 equal-shaped owners on 8 devices: an all-handshake tick compiles
    exactly ONE tick-entry program (the shard_map bucket program), and an
    all-self-train tick adds exactly one more; placement auto-resolves to
    sharded in a multi-device process."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.core.tick_engine import tick_program_cache_size
        from repro.kernels.dispatch import resolve_tick_placement
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 8
        assert resolve_tick_placement(None) == "sharded"  # auto, 8 devices

        kgs = equal_shape_universe(
            8, entities=120, relations=6, triples=900, shared=32, seed=2
        )
        fed = FederationScheduler(
            kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
            local_epochs=2, update_epochs=2, seed=0, use_virtual=False,
            score_max_test=24,
        )
        fed.initial_training()
        assert tick_program_cache_size() == 0
        fed.run(max_ticks=1, tick_impl="batched")  # 8 equal ppat entries
        assert tick_program_cache_size() == 1, tick_program_cache_size()
        # steady state: the next all-handshake tick reuses the program
        fed.run(max_ticks=1, tick_impl="batched")
        assert tick_program_cache_size() == 1, tick_program_cache_size()
        for n in kgs:
            fed.queue[n].clear()
            fed._queued[n].clear()
        fed.run(max_ticks=1, tick_impl="batched")  # 8 equal self-train entries
        assert tick_program_cache_size() == 2, tick_program_cache_size()
        # owner-sticky residency leaves trainer state committed per owner —
        # switching placement or dropping to the serial reference loop
        # afterwards has to accept those committed arrays and keep working
        fed.run(max_ticks=1, tick_impl="batched", tick_placement="single")
        fed.run(max_ticks=1, tick_impl="reference")
        fed.run(max_ticks=1, tick_impl="batched", tick_placement="sharded")
        # the normalize escape hatch restores the stage-back-to-device-0
        # behavior for consumers that cannot handle committed arrays
        fed.run(max_ticks=1, tick_impl="batched", tick_placement="sharded",
                tick_residency="normalize")
        for e in fed.events:
            if e.tick == fed._tick and e.accepted:
                ent = fed.trainers[e.host].params["ent"]
                assert ent.devices() == {jax.devices()[0]}, e.host
        print("SHARDED_DEDUP_OK")
        """
    )
    assert "SHARDED_DEDUP_OK" in out


def test_owner_sticky_residency_zero_steady_state_transfers():
    """The tentpole pins, on a 4-owner / 4-device symmetric federation:

      * sticky placement — every owner's home slot survives plan
        recomposition (handshake ticks, drained-queue self-train ticks);
      * zero steady-state transfers — once the pair rotation has warmed the
        per-device caches, further sharded ticks run under
        ``jax.transfer_guard(\"disallow\")`` (host→device AND device→device):
        no cached immutable input is re-staged, no implicit transfer happens
        at all, and the resident-cache miss counter stays flat; only the
        per-tick mutable leaves (keys, client views, params) move, via
        explicit device_put;
      * residency — owners whose last decision was an accept keep their
        params committed to their home device."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.core.tick_engine import tick_program_cache_size
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 4
        kgs = equal_shape_universe(
            4, entities=120, relations=6, triples=900, shared=32, seed=5
        )
        fed = FederationScheduler(
            kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
            local_epochs=2, update_epochs=2, seed=0, use_virtual=False,
            score_max_test=24,
        )
        fed.initial_training()
        eng = fed._tick_engine
        # warm: 3 ticks rotate through every (client, host) pair; a drained
        # tick compiles + caches the self-train signature too
        fed.run(max_ticks=3, tick_impl="batched", tick_placement="sharded")
        homes = dict(eng.placement.assignments())
        assert sorted(homes.values()) == [0, 1, 2, 3]
        saved = {n: list(fed.queue[n]) for n in kgs}
        for n in kgs:
            fed.queue[n].clear(); fed._queued[n].clear()
        fed.run(max_ticks=1, tick_impl="batched", tick_placement="sharded")
        for n, q in saved.items():
            for c in q:
                if c not in fed._queued[n]:
                    fed.queue[n].append(c); fed._queued[n].add(c)

        progs = tick_program_cache_size()
        misses = eng.resident_transfers
        # steady state: strictest possible pin — NO implicit transfer in
        # either direction may happen during the guarded ticks
        with jax.transfer_guard_host_to_device("disallow"), \\
             jax.transfer_guard_device_to_device("disallow"):
            fed.run(max_ticks=2, tick_impl="batched", tick_placement="sharded")
        assert eng.resident_transfers == misses, (
            "steady-state tick re-staged cached immutable inputs"
        )
        assert tick_program_cache_size() == progs, "steady-state retrace"
        # plan recomposition did not move anyone's home
        assert dict(eng.placement.assignments()) == homes
        # accepted owners' tables live on their home device
        last = {}
        for e in fed.events:
            if e.kind != "init":
                last[e.host] = e
        for n, e in last.items():
            if e.accepted:
                ent = fed.trainers[n].params["ent"]
                assert ent.committed and ent.devices() == {
                    jax.devices()[homes[n]]
                }, (n, homes[n], ent.devices())
        print("STICKY_RESIDENCY_OK")
        """,
        devices=4,
    )
    assert "STICKY_RESIDENCY_OK" in out


def test_non_pow2_mesh_partial_chunks_parity_and_compile_bound():
    """5 equal-shaped owners on a 3-device mesh: a signature bucket of 5
    decomposes into a full-mesh chunk (extent 3) plus a power-of-two
    remainder chunk (extent 2) — parity still bitwise, and group compiles
    per signature stay ≤ floor(log2(devices)) + 1 = 2 (the pow-2 extent
    lever: a bucket shrinking by one owner re-pads into a compiled extent
    instead of compiling one program per exact size)."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.core.tick_engine import tick_program_cache_size
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 3
        kgs = equal_shape_universe(
            5, entities=120, relations=6, triples=900, shared=32, seed=7
        )

        def make():
            return FederationScheduler(
                kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
                local_epochs=2, update_epochs=2, seed=0, use_virtual=False,
                score_max_test=24,
            )

        feds = {}
        for impl, kw in (
            ("reference", {}),
            ("batched", dict(tick_placement="sharded")),
        ):
            f = make()
            f.initial_training()
            f.run(max_ticks=2, tick_impl=impl, **kw)
            feds[impl] = f
        assert_parity(feds["reference"], feds["batched"], kgs)
        # one ppat signature, two chunk extents {3, 2} -> exactly 2 programs
        assert tick_program_cache_size() == 2, tick_program_cache_size()
        print("NON_POW2_CHUNKS_OK")
        """,
        devices=3,
    )
    assert "NON_POW2_CHUNKS_OK" in out


def test_dummy_padded_chunk_parity_single_program():
    """5 equal-shaped owners on an 8-device mesh: the bucket rounds up to ONE
    full-mesh chunk with 3 masked dummy entries (replicas of a real entry
    whose outputs are discarded) — one group program per tick kind, and the
    dummies leave no trace in the protocol trajectory (bit-parity)."""
    out = _run(
        """
        import jax
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.core.tick_engine import tick_program_cache_size
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 8
        kgs = equal_shape_universe(
            5, entities=120, relations=6, triples=900, shared=32, seed=9
        )

        def make():
            return FederationScheduler(
                kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
                local_epochs=2, update_epochs=2, seed=0, use_virtual=False,
                score_max_test=24,
            )

        ref = make(); ref.initial_training()
        ref.run(max_ticks=2, tick_impl="reference")
        bat = make(); bat.initial_training()
        bat.run(max_ticks=1, tick_impl="batched", tick_placement="sharded")
        # 5 ppat entries pad to one extent-8 shard_map chunk: ONE program
        assert tick_program_cache_size() == 1, tick_program_cache_size()
        bat.run(max_ticks=1, tick_impl="batched", tick_placement="sharded")
        assert tick_program_cache_size() == 1, tick_program_cache_size()
        assert_parity(ref, bat, kgs)
        print("DUMMY_PAD_OK")
        """
    )
    assert "DUMMY_PAD_OK" in out


def test_non_sharded_consumers_accept_committed_results():
    """After owner-sticky sharded ticks an owner's tables are committed to
    its home device; every non-sharded consumer must take them as-is:
    the serial reference tick (cross-owner handshake math), direct trainer
    handoff (train_epochs), eval (link_prediction), checkpoint round-trip,
    and the serving ranker."""
    out = _run(
        """
        import os, tempfile
        import jax
        import numpy as np
        from repro.core.federation import FederationScheduler
        from repro.core.ppat import PPATConfig
        from repro.kge.data import equal_shape_universe

        assert len(jax.devices()) == 2
        kgs = equal_shape_universe(
            2, entities=120, relations=6, triples=900, shared=32, seed=11
        )
        fed = FederationScheduler(
            kgs, dim=16, ppat_cfg=PPATConfig(steps=4, seed=0),
            local_epochs=2, update_epochs=2, seed=0, score_max_test=24,
        )
        fed.initial_training()
        fed.run(max_ticks=2, tick_impl="batched", tick_placement="sharded")
        name = [n for n in kgs][1]
        tr = fed.trainers[name]

        # serial reference path on committed state (client and host owners
        # may live on different devices)
        fed.run(max_ticks=1, tick_impl="reference")

        # trainer handoff: direct local training on resident tables
        tr.train_epochs(1)

        # eval: the streaming rank engine runs on the owner's device
        from repro.kge.eval import link_prediction
        lp = link_prediction(tr.params, tr.model, kgs[name], max_test=16)
        assert 0.0 <= lp["hit@10"] <= 1.0

        # checkpoint round-trip
        from repro.checkpoint import load_checkpoint, save_checkpoint
        path = os.path.join(tempfile.mkdtemp(), "owner.npz")
        save_checkpoint(path, tr.params, metadata={"owner": name})
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), dict(tr.params)
        )
        restored, meta = load_checkpoint(path, like)
        assert meta["owner"] == name
        for k in tr.params:
            np.testing.assert_array_equal(
                np.asarray(restored[k]), np.asarray(tr.params[k])
            )

        # serving: candidate ranker over the committed tables
        from repro.serving import KGECandidateRanker
        ranker = KGECandidateRanker(
            tr.params, tr.model, known_triples=kgs[name].train, block_e=64
        )
        test = np.asarray(kgs[name].test)[:4]
        ranks = ranker.rank_tails(test[:, 0], test[:, 1], test[:, 2])
        assert len(ranks) == len(test) and (ranks >= 1).all()
        ids, scores = ranker.topk_tails(test[:, 0], test[:, 1], k=5)
        assert ids.shape == (len(test), 5)
        print("COMMITTED_CONSUMERS_OK")
        """,
        devices=2,
    )
    assert "COMMITTED_CONSUMERS_OK" in out
