"""Substrate units: optimizer, schedule, loss chunking, data, checkpoint,
MoE routing, chunked attention, sharding-spec structure."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_config, reduced
from repro.data.pipeline import ByteTokenizer, SyntheticTextDataset, make_batches
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule


# ------------------------------------------------------------------ optim
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(
            grads, state, params, lr=0.05, weight_decay=0.0
        )
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    huge = {"w": jnp.array([1e9, -1e9, 1e9])}
    p2, _ = adamw_update(huge, state, params, lr=0.1, grad_clip=1.0, weight_decay=0.0)
    assert jnp.isfinite(p2["w"]).all()


@given(st.integers(1, 5000))
@settings(max_examples=30, deadline=None)
def test_schedule_bounded(step):
    lr = cosine_schedule(step, base_lr=1e-3, warmup=100, total=5000)
    assert 0.0 <= float(lr) <= 1e-3 * (1 + 1e-5)


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6


# ------------------------------------------------------------------- loss
def test_chunked_ce_equals_dense():
    from repro.models.model import init_params
    from repro.train.loss import lm_loss

    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
    l0, _ = lm_loss(params, cfg, toks, labels, ce_chunk=0)
    l1, _ = lm_loss(params, cfg, toks, labels, ce_chunk=16)
    assert jnp.allclose(l0, l1, atol=1e-4)


def test_ce_label_mask():
    from repro.models.model import init_params
    from repro.train.loss import lm_loss

    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    masked = labels.at[:, 8:].set(-1)
    l_full, _ = lm_loss(params, cfg, toks, labels)
    l_mask, _ = lm_loss(params, cfg, toks, masked)
    assert not jnp.allclose(l_full, l_mask)
    assert jnp.isfinite(l_mask)


# -------------------------------------------------------------------- moe
def test_moe_capacity_drops_and_residual():
    import dataclasses

    from repro.models.moe import apply_moe, init_moe

    cfg = reduced(get_config("mixtral-8x22b")).replace(dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    # generous capacity must process ≥ as much signal as tight capacity
    cfg_tight = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    y2, _ = apply_moe(p, x, cfg_tight)
    assert float(jnp.abs(y2).sum()) <= float(jnp.abs(y).sum()) + 1e-3


def test_moe_aux_loss_balanced_router_lower():
    """Uniform routing probabilities → aux ≈ aux_weight (its minimum)."""
    import dataclasses

    from repro.models.moe import apply_moe, init_moe

    cfg = reduced(get_config("mixtral-8x22b")).replace(dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    _, aux = apply_moe(p, x, cfg)
    assert abs(float(aux) - cfg.moe.aux_loss_weight) < 0.05


# ------------------------------------------------------- chunked attention
def test_chunked_attention_matches_dense():
    import repro.models.attention as A

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = A.init_attention(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model))
    dense = A.attention(params, cfg, x)
    old = A.CHUNKED_ATTN_THRESHOLD
    try:
        A.CHUNKED_ATTN_THRESHOLD = 32  # force the chunked path
        chunked = A.attention(params, cfg, x)
    finally:
        A.CHUNKED_ATTN_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked), atol=1e-4)


# ------------------------------------------------------------------- data
def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "federated knowledge graphs"
    assert tok.decode(tok.encode(s)) == s


def test_dataset_deterministic_and_learnable_structure():
    ds = SyntheticTextDataset(vocab_size=512, seed=3)
    a = ds.tokens(1000, seed=7)
    b = ds.tokens(1000, seed=7)
    assert (a == b).all()
    assert a.min() >= 0 and a.max() < 512
    # bigram structure: repeated pairs appear far more often than chance
    pairs = set(zip(a[:-1].tolist(), a[1:].tolist()))
    assert len(pairs) < 900


def test_make_batches_shapes():
    ds = SyntheticTextDataset(vocab_size=128, seed=0)
    batches = list(make_batches(ds, batch=4, seq_len=16, steps=3))
    assert len(batches) == 3
    for b in batches:
        assert b["tokens"].shape == (4, 16)
        assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.models.model import init_params

    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, metadata={"step": 7})
    like = jax.eval_shape(lambda: params)
    restored, meta = load_checkpoint(path, like)
    assert meta["step"] == 7
    ok = jax.tree.map(lambda a, b: bool(jnp.allclose(a, b)), params, restored)
    assert all(jax.tree.leaves(ok))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint

    path = os.path.join(tmp_path, "c.npz")
    save_checkpoint(path, {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jax.ShapeDtypeStruct((4, 3), jnp.float32)})


# --------------------------------------------------------- sharding specs
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_pspecs_cover_all_archs(arch):
    from jax.sharding import PartitionSpec as P

    from repro.models.model import init_params
    from repro.sharding.specs import param_pspecs

    cfg = get_config(arch)
    params = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    specs = param_pspecs(params)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) <= p.ndim
        # every sharded dim must divide by the mesh axis extent (16 per axis)
        for dim, axis in zip(p.shape, tuple(s) + (None,) * (p.ndim - len(s))):
            if axis is None:
                continue
            extent = 16 if not isinstance(axis, tuple) else 16 ** len(axis)
            assert dim % extent == 0, (arch, s, p.shape)
