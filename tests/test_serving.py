"""Serving: token-engine continuous batching + the KGE serving tier.

KGE coverage: ranker rank parity vs the seed reference math, top-k filter
exclusion, request validation (range + non-finite bitmask), version swap,
tier batching bit-parity vs per-call, program-cache pinning across traffic
mixes, replica routing, and the version hot-swap boundary (manual publish
and a federation-tick flip) — zero failed requests, ranks bit-equal per
version."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels.dispatch import resolve_serve_impl, resolve_serve_replicas
from repro.kge.models import KGEModel, score_all_tails
from repro.kge.trainer import init_kge
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.serving import (
    FilterPack,
    KGECandidateRanker,
    KGEServingTier,
    serving_program_cache_size,
)
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference(cfg, params, prompt, n):
    cache = init_cache(cfg, 1, 64, jnp.float32)
    logits, cache = prefill(params, cfg, jnp.asarray(prompt[None]), cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cache = decode_step(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), cache, jnp.int32(pos)
        )
        out.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return out


def test_continuous_batching_matches_sequential(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6 + i).astype(np.int32) for i in range(4)]
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run_until_drained()
    assert len(done) == 4
    for rid, prompt in zip(rids, prompts):
        got = next(r for r in done if r.rid == rid).generated
        assert got == _reference(cfg, params, prompt, 5), rid


def test_slots_recycled(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, max_batch=1, max_len=64)  # forces queueing
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=3)
    done = eng.run_until_drained()
    assert len(done) == 3
    assert all(len(r.generated) == 3 for r in done)


# ---------------------------------------------------------------------------
# KGE candidate ranker + serving tier
# ---------------------------------------------------------------------------
E, R, D = 300, 6, 16


def _tri(n, seed):
    rng = np.random.default_rng(seed)
    return np.stack(
        [rng.integers(0, E, n), rng.integers(0, R, n), rng.integers(0, E, n)],
        axis=1,
    ).astype(np.int64)


@pytest.fixture(scope="module")
def kge_world():
    m = KGEModel("transe", E, R, D)
    params = init_kge(jax.random.PRNGKey(1), m)
    known = _tri(400, seed=100)
    return m, params, known


def _ref_tail_ranks(params, m, known, h, r, t):
    """Seed-style oracle: dense (B, E) scores + per-row Python filtering."""
    from repro.kge.eval import _filter_mask

    hr_t, _ = _filter_mask(known, m.num_entities)
    dense = np.asarray(
        score_all_tails(params, m, jnp.asarray(h), jnp.asarray(r), via_kernel=False)
    )
    ranks = []
    for j in range(len(h)):
        row = dense[j].copy()
        for other in hr_t.get((int(h[j]), int(r[j])), ()):
            if other != int(t[j]):
                row[other] = -np.inf
        ranks.append(1 + int((row > row[int(t[j])]).sum()))
    return np.asarray(ranks)


def test_ranker_rank_parity_vs_reference(kge_world):
    m, params, known = kge_world
    q = _tri(24, seed=2)
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    got = ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    np.testing.assert_array_equal(
        got, _ref_tail_ranks(params, m, known, q[:, 0], q[:, 1], q[:, 2])
    )


def test_ranker_topk_excludes_known_and_matches_bruteforce(kge_world):
    m, params, known = kge_world
    # query keys that certainly have known tails
    q = known[:10]
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    ids, scores = ranker.topk_tails(q[:, 0], q[:, 1], k=7)
    dense = np.asarray(
        score_all_tails(params, m, jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1]),
                        via_kernel=False)
    )
    for j in range(len(q)):
        row = dense[j].copy()
        key = (int(q[j, 0]), int(q[j, 1]))
        for t in ranker._hr_t.get(key, ()):
            row[t] = -np.inf
        expect = np.argsort(-row, kind="stable")[:7]
        assert not (set(ids[j].tolist()) & ranker._hr_t.get(key, set()))
        np.testing.assert_allclose(row[expect], scores[j], rtol=1e-6, atol=1e-6)


def test_filter_pack_pow2_width_and_sentinel(kge_world):
    m, _, known = kge_world
    pack = FilterPack(known, m.num_entities)
    assert pack.width & (pack.width - 1) == 0  # power of two
    assert pack.rows.shape[1] == pack.width
    # unknown key → sentinel row, all −1 (no exclusions)
    rows = pack.rows_for(np.array([0]), np.array([R - 1]))
    if (0, R - 1) not in pack.hr_t:
        assert (rows == -1).all()
    # pinned width refuses to silently truncate
    from repro.kge.eval import pack_padded_filters

    with pytest.raises(ValueError, match="exceeds width"):
        pack_padded_filters([[1, 2, 3]], width=2)


def test_ranker_swap_matches_fresh_ranker(kge_world):
    m, params, known = kge_world
    p2 = init_kge(jax.random.PRNGKey(9), m)
    q = _tri(8, seed=3)
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    before = ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    ranker.swap(p2)
    assert ranker.version == 1
    after = ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    fresh = KGECandidateRanker(p2, m, known, block_e=64)
    np.testing.assert_array_equal(after, fresh.rank_tails(q[:, 0], q[:, 1], q[:, 2]))
    # swap back restores the original ranks bit-exactly
    ranker.swap(params)
    np.testing.assert_array_equal(
        before, ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    )


def test_tier_validation_and_nonfinite_bitmask(kge_world):
    m, params, _ = kge_world
    bad = {k: np.asarray(v).copy() for k, v in params.items()}
    bad["ent"][3, 0] = np.nan
    bad["rel"][1, 2] = np.inf
    tier = KGEServingTier(bad, m, None, block_e=64)
    with pytest.raises(ValueError, match=r"head entity ids .*\[-1\]"):
        tier.submit_rank([-1], [0], [1])
    with pytest.raises(ValueError, match=rf"tail entity ids .*\[{E}\]"):
        tier.submit_rank([0], [0], [E])
    with pytest.raises(ValueError, match=r"non-finite query embedding: entity ids \[3\]"):
        tier.submit_rank([3], [0], [1])
    with pytest.raises(ValueError, match=r"relation ids \[1\]"):
        tier.submit_topk([0], [1], k=3)
    with pytest.raises(ValueError, match="k must be in"):
        tier.submit_topk([0], [0], k=0)
    # publishing a repaired version clears the refusal — masks are per-version
    tier.publish(params)
    req = tier.submit_rank([3], [0], [1])
    tier.run_until_drained()
    assert req.done and req.error is None


def test_tier_batched_parity_mixed_traffic(kge_world):
    m, params, known = kge_world
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    tier = KGEServingTier(params, m, known, block_e=64, max_batch=16)
    rank_reqs, topk_reqs = [], []
    for i, n in enumerate((3, 5, 2, 7, 1, 4)):
        q = _tri(n, seed=10 + i)
        rank_reqs.append((q, tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])))
    for i, n in enumerate((2, 3)):
        q = _tri(n, seed=20 + i)
        topk_reqs.append((q, tier.submit_topk(q[:, 0], q[:, 1], k=5)))
    tier.run_until_drained()
    assert tier.stats["failed"] == 0
    # coalescing actually happened: fewer batches than requests
    assert tier.stats["batches"] < len(rank_reqs) + len(topk_reqs)
    for q, req in rank_reqs:
        np.testing.assert_array_equal(
            req.result, ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
        )
    for q, req in topk_reqs:
        ids, vals = ranker.topk_tails(q[:, 0], q[:, 1], k=5)
        np.testing.assert_array_equal(req.result[0], ids)
        np.testing.assert_allclose(req.result[1], vals, rtol=0, atol=0)


def test_tier_direct_impl_is_per_request(kge_world):
    m, params, known = kge_world
    tier = KGEServingTier(params, m, known, block_e=64, serve_impl="direct")
    ranker = KGECandidateRanker(params, m, known, block_e=64)
    qs = [_tri(n, seed=30 + n) for n in (2, 3, 4)]
    reqs = [tier.submit_rank(q[:, 0], q[:, 1], q[:, 2]) for q in qs]
    tier.run_until_drained()
    assert tier.stats["batches"] == len(reqs)  # no coalescing
    for q, req in zip(qs, reqs):
        np.testing.assert_array_equal(
            req.result, ranker.rank_tails(q[:, 0], q[:, 1], q[:, 2])
        )


def test_tier_program_cache_pinned_across_traffic_mixes(kge_world):
    m, params, known = kge_world
    tier = KGEServingTier(params, m, known, block_e=64, max_batch=16)
    # warm every bucket the tier can emit for this traffic envelope
    for i, n in enumerate((1, 3, 8, 16, 11)):
        q = _tri(n, seed=40 + i)
        tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    for i, n in enumerate((2, 5)):
        q = _tri(n, seed=50 + i)
        tier.submit_topk(q[:, 0], q[:, 1], k=5)
    tier.run_until_drained()
    warm = serving_program_cache_size()
    # a different mix of sizes within the same bucket envelope (rank batches
    # pad to 16, topk batches to 8) must not retrace
    for i, n in enumerate((2, 7, 13, 1, 16, 4, 9)):
        q = _tri(n, seed=60 + i)
        tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    for i, n in enumerate((1, 4, 2)):
        q = _tri(n, seed=70 + i)
        tier.submit_topk(q[:, 0], q[:, 1], k=5)
    tier.run_until_drained()
    assert serving_program_cache_size() == warm
    assert tier.stats["failed"] == 0


def test_tier_warm_buckets_swap_pays_no_compile(kge_world):
    """Per-replica warm-up on publish: a tier constructed with
    ``warm_buckets`` pre-traces those buckets against the staged tables, so
    first traffic — and the first post-swap batch — never compiles."""
    m, params, known = kge_world
    p2 = init_kge(jax.random.PRNGKey(12), m)
    tier = KGEServingTier(
        params, m, known, block_e=64, max_batch=16,
        warm_buckets=[("rank", 8), ("rank", 16), ("topk", 8, 5)],
    )
    # constructor publish warmed each spec once per replica
    assert tier.stats["warmed"] == 3 * len(tier.replicas)
    warm = serving_program_cache_size()
    # first real traffic landing in the warmed buckets: zero retraces
    for i, n in enumerate((3, 16, 11)):
        q = _tri(n, seed=140 + i)
        tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    q = _tri(5, seed=150)
    tier.submit_topk(q[:, 0], q[:, 1], k=5)
    tier.run_until_drained()
    assert serving_program_cache_size() == warm
    # hot-swap: the publish-time re-warm is a no-op (shapes already traced)
    # and the first post-swap batch still pays no compile
    tier.publish(p2)
    assert tier.stats["warmed"] == 3 * len(tier.replicas)
    assert serving_program_cache_size() == warm
    b = tier.submit_rank(*(_tri(9, seed=160).T))
    q = _tri(4, seed=170)
    tier.submit_topk(q[:, 0], q[:, 1], k=5)
    tier.run_until_drained()
    assert serving_program_cache_size() == warm
    assert tier.stats["failed"] == 0
    assert b.version == 1
    # parity: warmed tier still serves bit-identical ranks
    q = _tri(9, seed=160)
    r2 = KGECandidateRanker(p2, m, known, block_e=64)
    np.testing.assert_array_equal(
        b.result, r2.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    )


def test_tier_warm_buckets_validation(kge_world):
    m, params, known = kge_world
    for bad in ([("rank", 8, 3)], [("topk", 8)], [("scan", 8)], [()]):
        with pytest.raises(ValueError, match="warm bucket"):
            KGEServingTier(params, m, known, block_e=64, warm_buckets=bad)


def test_tier_replica_routing_least_loaded(kge_world, monkeypatch):
    from repro.serving import tier as tier_mod

    m, params, known = kge_world
    dev = jax.devices()[0]
    # two replica slots (same physical device on 1-device CI): the router
    # must still spread consecutive batches by in-flight count
    tier = KGEServingTier(params, m, known, block_e=64, replicas=2,
                          devices=[dev, dev], max_batch=4, max_inflight=4)
    # freeze completion: CPU batches finish between steps, so without this
    # the in-flight gauge drains and the routing decision is timing-luck
    monkeypatch.setattr(tier_mod._InFlight, "ready", lambda self: False)
    for i in range(4):
        q = _tri(4, seed=80 + i)
        tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
        tier.step()
    assert [rp.inflight for rp in tier.replicas] == [2, 2]
    monkeypatch.undo()
    tier.run_until_drained()
    assert dict(tier.replica_load()) == {0: 2, 1: 2}
    assert tier.stats["failed"] == 0
    # sequential low-traffic batches (each drained before the next, so
    # in-flight is always 0 at pick time) must STILL spread across the
    # ring: lifetime dispatch count tie-breaks before slot
    for i in range(2):
        q = _tri(4, seed=86 + i)
        tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
        tier.run_until_drained()
    assert dict(tier.replica_load()) == {0: 3, 1: 3}


def test_tier_hot_swap_boundary_bit_equal(kge_world):
    m, params, known = kge_world
    p2 = init_kge(jax.random.PRNGKey(11), m)
    tier = KGEServingTier(params, m, known, block_e=64, max_batch=8)
    q = _tri(6, seed=90)
    # dispatch A before the flip (in-flight on v0), then publish, then B
    a = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.step()
    tier.publish(p2)
    b = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    assert tier.stats["failed"] == 0
    assert (a.version, b.version) == (0, 1)
    r1 = KGECandidateRanker(params, m, known, block_e=64)
    r2 = KGECandidateRanker(p2, m, known, block_e=64)
    np.testing.assert_array_equal(a.result, r1.rank_tails(q[:, 0], q[:, 1], q[:, 2]))
    np.testing.assert_array_equal(b.result, r2.rank_tails(q[:, 0], q[:, 1], q[:, 2]))


def test_federation_tick_version_flip_serves_bit_equal():
    """The acceptance bar: a tier attached to a federating owner hot-swaps
    on every accepted tick update with ZERO failed requests, and ranks
    served after the flip are bit-equal to a per-call ranker on the
    owner's accepted params."""
    from repro.core.federation import FederationScheduler
    from repro.core.ppat import PPATConfig
    from repro.kge.data import synthesize_universe

    kgs = synthesize_universe(
        seed=1, scale=1 / 500,
        kg_stats=[("A", 12, 90000, 300000), ("B", 10, 70000, 250000)],
        alignments=[("A", "B", 30000)],
    )
    ctr = itertools.count()
    # monotone score ⇒ every handshake/self-train is accepted: the flip is
    # deterministic, not at the mercy of tiny-universe training dynamics
    sched = FederationScheduler(
        kgs, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
        local_epochs=2, update_epochs=2, seed=0,
        score_fn=lambda name: float(next(ctr)),
    )
    sched.initial_training()
    tier = KGEServingTier.for_owner(sched, "A", block_e=64, max_batch=16)
    v0 = tier.version
    q = np.asarray(kgs["A"].test)[:6]
    pre = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.step()  # dispatched before any tick → pinned to v0
    sched.run(max_ticks=2)
    post = tier.submit_rank(q[:, 0], q[:, 1], q[:, 2])
    tier.run_until_drained()
    accepts = sum(
        1 for e in sched.events
        if e.accepted and e.host == "A" and e.kind != "init"
    )
    assert accepts >= 1
    assert tier.version == v0 + accepts  # one publish per accepted update
    assert tier.stats["failed"] == 0 and tier.stats["publish_errors"] == 0
    assert (pre.version, post.version) == (v0, tier.version)
    known = np.concatenate([kgs["A"].train, kgs["A"].valid, kgs["A"].test])
    tr = sched.trainers["A"]
    now = KGECandidateRanker(dict(tr.params), tr.model, known, block_e=64)
    np.testing.assert_array_equal(
        post.result, now.rank_tails(q[:, 0], q[:, 1], q[:, 2])
    )
    assert pre.result is not None and pre.error is None


def test_resolve_serve_knobs(monkeypatch):
    assert resolve_serve_impl() == "batched"
    assert resolve_serve_impl("direct") == "direct"
    monkeypatch.setenv("REPRO_SERVE_IMPL", "direct")
    assert resolve_serve_impl() == "direct"
    assert resolve_serve_impl("batched") == "batched"  # explicit wins
    with pytest.raises(ValueError, match="unknown serve impl"):
        resolve_serve_impl("turbo")
    monkeypatch.setenv("REPRO_SERVE_REPLICAS", "3")
    assert resolve_serve_replicas() == 3
    assert resolve_serve_replicas(1) == 1  # explicit wins
    with pytest.raises(ValueError, match="replicas must be >= 1"):
        resolve_serve_replicas(0)
