"""Serving engine: continuous batching correctness + slot recycling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import decode_step, init_cache, init_params, prefill
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3-0.6b")).replace(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference(cfg, params, prompt, n):
    cache = init_cache(cfg, 1, 64, jnp.float32)
    logits, cache = prefill(params, cfg, jnp.asarray(prompt[None]), cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n - 1):
        lg, cache = decode_step(
            params, cfg, jnp.asarray([[out[-1]]], jnp.int32), cache, jnp.int32(pos)
        )
        out.append(int(jnp.argmax(lg[0, 0])))
        pos += 1
    return out


def test_continuous_batching_matches_sequential(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 6 + i).astype(np.int32) for i in range(4)]
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    done = eng.run_until_drained()
    assert len(done) == 4
    for rid, prompt in zip(rids, prompts):
        got = next(r for r in done if r.rid == rid).generated
        assert got == _reference(cfg, params, prompt, 5), rid


def test_slots_recycled(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, max_batch=1, max_len=64)  # forces queueing
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, 5).astype(np.int32), max_new_tokens=3)
    done = eng.run_until_drained()
    assert len(done) == 3
    assert all(len(r.generated) == 3 for r in done)
