"""Federation protocol: handshake, backtrack, broadcast, quiescence."""
import numpy as np
import pytest

from repro.core.federation import FederationScheduler, NodeState
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe


@pytest.fixture(scope="module")
def universe():
    stats = [("A", 12, 90000, 300000), ("B", 10, 70000, 240000), ("C", 8, 60000, 200000)]
    aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
    return synthesize_universe(seed=1, scale=1 / 500, kg_stats=stats, alignments=aligns)


@pytest.fixture(scope="module")
def trained_fed(universe):
    fed = FederationScheduler(
        universe, dim=24, ppat_cfg=PPATConfig(steps=60, seed=0),
        local_epochs=80, update_epochs=25, seed=0,
    )
    fed.initial_training()
    fed.run(max_ticks=2)
    return fed


def test_initial_training_broadcasts(universe):
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=5), local_epochs=5, seed=0
    )
    fed.initial_training()
    # every owner with alignments got handshake offers queued
    assert all(len(fed.queue[n]) > 0 for n in universe)
    assert all(fed.state[n] is NodeState.READY for n in universe)


def test_best_score_never_decreases(trained_fed):
    """Backtrack invariant: accepted federations only ever improve."""
    best = {}
    for ev in trained_fed.events:
        if ev.kind == "init":
            best[ev.host] = ev.score_after
            continue
        if ev.accepted:
            assert ev.score_after > best[ev.host]
            best[ev.host] = ev.score_after
        else:
            assert ev.score_after <= best[ev.host] + 1e-9
    assert best == trained_fed.best_score


def test_rejected_federation_restores_snapshot(universe):
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
        local_epochs=30, update_epochs=2, seed=0,
    )
    fed.initial_training()
    snap_before = {k: np.asarray(v["ent"]) for k, v in
                   ((n, fed.best_snapshot[n]) for n in universe)}
    ev = fed.federate_once("A", "B")
    if not ev.accepted:
        assert np.allclose(np.asarray(fed.trainers["A"].params["ent"]), snap_before["A"])


def test_federation_improves_some_kg(trained_fed):
    inits = {e.host: e.score_after for e in trained_fed.events if e.kind == "init"}
    improved = [n for n, s in trained_fed.best_score.items() if s > inits[n] + 1e-9]
    assert improved, "federation should improve at least one KG"


def test_epsilon_recorded_per_handshake(trained_fed):
    ppat_events = [e for e in trained_fed.events if e.kind == "ppat"]
    assert ppat_events
    assert all(np.isfinite(e.epsilon) and e.epsilon > 0 for e in ppat_events)


def test_busy_state_cleared(trained_fed):
    assert all(s is not NodeState.BUSY for s in trained_fed.state.values())


# ------------------------------------------- scheduler protocol invariants
def test_broadcast_wakes_sleeping_partners(universe):
    """Alg. 1 l. 30 / Fig. 2: a handshake signal is also a wake-up signal."""
    fed = FederationScheduler(universe, dim=16, local_epochs=1, seed=0)
    for n in universe:
        fed.state[n] = NodeState.SLEEP
    fed.broadcast("A")
    for partner in fed.registry.partners("A"):
        assert fed.state[partner] is NodeState.READY
        assert list(fed.queue[partner]) == ["A"]
    assert fed.state["A"] is NodeState.SLEEP  # no self-wake


def test_broadcast_dedup_is_o1_under_repeated_broadcasts(universe):
    """Repeated broadcasts from every owner leave each queue with at most
    one offer per partner — the pending-set mirror stays consistent."""
    fed = FederationScheduler(universe, dim=16, local_epochs=1, seed=0)
    for _ in range(7):
        for n in universe:
            fed.broadcast(n)
    for n in universe:
        offers = list(fed.queue[n])
        assert len(offers) == len(set(offers))
        assert set(offers) == fed._queued[n]
        assert set(offers) == set(fed.registry.partners(n))


def test_quiescence_terminates_without_self_train(universe):
    """With self-training off and a score_fn that never improves, every
    owner drains its queue and sleeps — run() stops before max_ticks."""
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=2, seed=0),
        local_epochs=1, update_epochs=1, seed=0,
        score_fn=lambda name: 0.0,  # never beats the init score
    )
    fed.best_score = {n: 1.0 for n in universe}
    fed.best_snapshot = {n: fed.trainers[n].snapshot() for n in universe}
    for n in universe:
        fed.broadcast(n)
    fed.run(max_ticks=50, self_train=False)
    assert fed._tick < 50, "run() should hit quiescence, not the tick cap"
    assert all(not q for q in fed.queue.values())
    assert not any(e.accepted for e in fed.events)
    # a further run immediately puts everyone to sleep and stays quiescent
    fed.run(max_ticks=2, self_train=False)
    assert all(s is NodeState.SLEEP for s in fed.state.values())


@pytest.mark.parametrize("tick_impl", ["reference", "batched"])
def test_rejected_backtrack_restores_bit_identical_params(universe, tick_impl):
    """Alg. 1 l. 17: a rejected KGEmb-Update must leave EVERY param table
    bit-identical to the pre-handshake snapshot, under both tick engines."""
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
    )
    fed.initial_training()
    fed.score_fn = lambda name: -1.0  # force every backtrack to reject
    snaps = {
        n: {k: np.asarray(v) for k, v in fed.best_snapshot[n].items()}
        for n in universe
    }
    fed.run(max_ticks=2, tick_impl=tick_impl)
    rejected = [e for e in fed.events if e.kind != "init"]
    assert rejected and not any(e.accepted for e in rejected)
    for n in universe:
        for k, v in snaps[n].items():
            np.testing.assert_array_equal(
                np.asarray(fed.trainers[n].params[k]), v,
                err_msg=f"{tick_impl}: {n}.{k} not restored bit-identically",
            )


# ----------------------------------------------------------- fault tolerance
from repro.core.faults import Fault, FaultInjector, FaultPlan  # noqa: E402


def _mini_fed(universe, **kw):
    defaults = dict(
        dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
    )
    defaults.update(kw)
    return FederationScheduler(universe, **defaults)


def _event_key(e):
    # repr-compare floats: exact, and NaN == NaN (plain float compare isn't)
    return (e.tick, e.host, e.client or "", e.kind, e.fault or "", e.accepted,
            repr(e.score_before), repr(e.score_after), repr(e.epsilon))


def test_fault_plan_parse_and_determinism():
    plan = FaultPlan.parse("crash=0.3,straggle=0.2,seed=9,until=5,delay=0.25")
    assert plan.crash == 0.3 and plan.straggle == 0.2
    assert plan.seed == 9 and plan.until == 5 and plan.delay == 0.25
    draws = [plan.draw(t, "A", "B") for t in range(1, 20)]
    assert draws == [plan.draw(t, "A", "B") for t in range(1, 20)]
    assert all(d is None for t, d in zip(range(1, 20), draws) if t > 5)
    assert FaultPlan.parse("on") == FaultPlan()
    with pytest.raises(ValueError):
        FaultPlan.parse("crash=2.0")
    with pytest.raises(ValueError):
        FaultPlan.parse("bogus=1")


@pytest.mark.parametrize("tick_impl", ["reference", "batched"])
def test_crash_isolated_and_requeued_with_backoff(universe, tick_impl):
    """One crashing owner never aborts the tick: the other entries land,
    the host restores bit-identically, and the handshake re-queues with
    exponential backoff."""
    plan = FaultPlan(table={(1, "A"): Fault("crash")})
    fed = _mini_fed(universe, tick_faults=FaultInjector(plan), backoff_ticks=2)
    fed.initial_training()
    snap = {k: np.asarray(v) for k, v in fed.best_snapshot["A"].items()}
    fed.run(max_ticks=1, tick_impl=tick_impl)
    evs = [e for e in fed.events if e.tick == 1]
    crashed = [e for e in evs if e.fault == "crash"]
    assert len(crashed) == 1 and crashed[0].host == "A"
    assert not crashed[0].accepted
    assert [e for e in evs if e.fault is None], "other entries must complete"
    for k, v in snap.items():
        np.testing.assert_array_equal(
            np.asarray(fed.trainers["A"].params[k]), v,
            err_msg=f"{tick_impl}: A.{k} not restored after crash",
        )
    client = crashed[0].client
    assert fed._retries[("A", client)] == 1
    assert fed._deferred == [(3, "A", client)]  # 1 + backoff 2 * 2**0
    assert fed.state["A"] is NodeState.READY


def test_exponential_backoff_quarantine_entry_and_release(universe):
    fed = _mini_fed(universe, backoff_ticks=1, retry_budget=3,
                    quarantine_ticks=2)
    fed.initial_training()
    fed._tick = 10
    for _ in range(3):
        fed._entry_failed("A", "B", "crash")
    assert fed._retries[("A", "B")] == 3
    # releases 10+1, 10+2, 10+4: exponential in the attempt count
    assert [r for r, _, _ in fed._deferred] == [11, 12, 14]
    # third attributed failure hits retry_budget → the host is quarantined
    assert fed.state["A"] is NodeState.QUARANTINED
    assert fed._quarantine_until["A"] == 12
    # quarantined owners plan no entries, and offers FROM them are deferred
    fed._tick = 11
    entries = fed.plan_tick()
    assert all(e.host != "A" for e in entries)
    assert {(h, c) for _, h, c in fed._deferred if c == "A"} == {
        ("B", "A"), ("C", "A"),
    }
    # timed release back to READY
    fed._tick = 12
    fed.plan_tick()
    assert fed.state["A"] is NodeState.READY
    assert "A" not in fed._quarantine_until


@pytest.mark.parametrize("tick_impl", ["reference", "batched"])
def test_corrupt_embeddings_rejected_and_client_blamed(universe, tick_impl):
    """NaN rows in the client's exchanged embeddings are caught by the
    receiver-side screen, the handshake rejects through the backtrack
    restore, and the SENDER accrues the blame."""
    plan = FaultPlan(
        table={(1, "A"): Fault("corrupt", rows=10_000, mode="nan")}
    )
    fed = _mini_fed(universe, tick_faults=FaultInjector(plan),
                    retry_budget=1, quarantine_ticks=3)
    fed.initial_training()
    snap = {k: np.asarray(v) for k, v in fed.best_snapshot["A"].items()}
    fed.run(max_ticks=1, tick_impl=tick_impl)
    evs = [e for e in fed.events if e.fault == "corrupt"]
    assert len(evs) == 1 and evs[0].host == "A"
    for k, v in snap.items():
        np.testing.assert_array_equal(
            np.asarray(fed.trainers["A"].params[k]), v,
            err_msg=f"{tick_impl}: receiver damaged by corrupt handshake",
        )
    # retry_budget=1: the blamed sender goes straight to quarantine — and
    # stays quarantined even though its own tick entry completed after the
    # blame was assigned (mid-tick quarantine survives entry completion)
    assert fed.state[evs[0].client] is NodeState.QUARANTINED


@pytest.mark.parametrize("tick_impl", ["reference", "batched"])
def test_straggler_past_deadline_deferred(universe, tick_impl):
    plan = FaultPlan(table={(1, "A"): Fault("straggle", delay=1e6)})
    fed = _mini_fed(universe, tick_faults=FaultInjector(plan),
                    tick_deadline=1e5)
    fed.initial_training()
    fed.run(max_ticks=1, tick_impl=tick_impl)
    evs = [e for e in fed.events if e.fault == "straggle"]
    assert len(evs) == 1 and evs[0].host == "A"
    assert not evs[0].accepted, "late results must be discarded"
    assert evs[0].seconds > 1e5  # simulated delay counted in wall-clock
    assert ("A", evs[0].client) in fed._retries  # deferred for retry
    # entries under the deadline were untouched by the straggler
    assert [e for e in fed.events if e.tick == 1 and e.fault is None]


def test_drop_blames_nobody(universe):
    plan = FaultPlan(table={(1, "A"): Fault("drop")})
    fed = _mini_fed(universe, tick_faults=FaultInjector(plan))
    fed.initial_training()
    fed.run(max_ticks=1, tick_impl="reference")
    evs = [e for e in fed.events if e.fault == "drop"]
    assert len(evs) == 1
    assert not fed._peer_failures, "a lost message is the network's fault"
    assert ("A", evs[0].client) in fed._retries  # the pair still retries


def test_fault_injection_engine_parity(universe):
    """Both tick engines honor the same seeded plan identically: same fault
    draws at the same entries, same surviving decisions/scores/ε, and
    bit-identical embeddings — failed entries skip the same key-stream
    positions under either engine."""
    spec = "crash=0.2,straggle=0.1,corrupt=0.1,seed=7,until=3,delay=1e6"

    def run_with(impl):
        fed = _mini_fed(universe, tick_faults=spec, tick_deadline=1e5)
        fed.initial_training()
        fed.run(max_ticks=4, tick_impl=impl)
        return fed

    fa, fb = run_with("reference"), run_with("batched")
    assert any(e.fault for e in fa.events), "seeded storm must fire"
    assert sorted(map(_event_key, fa.events)) == sorted(map(_event_key, fb.events))
    assert fa.epsilons == fb.epsilons
    assert fa.accountant.epsilon() == fb.accountant.epsilon()
    for n in universe:
        for k in fa.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(fa.trainers[n].params[k]),
                np.asarray(fb.trainers[n].params[k]),
                err_msg=f"{n}.{k} diverged between engines under faults",
            )


# --------------------------------------------------- crash-consistent resume
def test_save_scheduler_guards(universe, tmp_path):
    from repro.checkpoint import save_scheduler

    fed = FederationScheduler(universe, dim=16, local_epochs=1, seed=0)
    with pytest.raises(ValueError, match="initial_training"):
        save_scheduler(str(tmp_path / "x.npz"), fed)
    fed.best_snapshot = {n: fed.trainers[n].snapshot() for n in universe}
    fed.state["A"] = NodeState.BUSY
    with pytest.raises(ValueError, match="mid-tick"):
        save_scheduler(str(tmp_path / "x.npz"), fed)


def test_checkpoint_resume_bit_parity(universe, tmp_path):
    """A scheduler killed between ticks and resumed from its checkpoint
    makes bit-identical decisions to the uninterrupted run: same events,
    same scores, same ε streams, same embeddings."""
    from repro.checkpoint import restore_scheduler, save_scheduler

    def make():
        return _mini_fed(universe)

    path = str(tmp_path / "fed.npz")
    a = make()
    a.initial_training()
    a.run(max_ticks=2)
    cut = a._tick
    save_scheduler(path, a, metadata={"note": "mid-run"})
    a.run(max_ticks=2)  # the uninterrupted continuation

    b = make()  # the "new process": fresh scheduler over the same universe
    meta = restore_scheduler(path, b)
    assert meta == {"note": "mid-run"}
    assert b._tick == cut
    b.run(max_ticks=2)

    tail_a = [e for e in a.events if e.tick > cut]
    assert tail_a, "continuation must have executed entries"
    assert list(map(_event_key, tail_a)) == list(map(_event_key, b.events))
    assert a.epsilons == b.epsilons
    assert a.accountant.epsilon() == b.accountant.epsilon()
    assert a.best_score == b.best_score
    for n in universe:
        for k in a.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(a.trainers[n].params[k]),
                np.asarray(b.trainers[n].params[k]),
                err_msg=f"{n}.{k} diverged after resume",
            )


def test_resume_repopulates_resident_caches(universe, tmp_path):
    """Device residency is rebuilt lazily after resume: the restored tables
    land on the default device and the first post-resume tick repopulates
    the per-device caches (visible as resident_transfers growth)."""
    from repro.checkpoint import restore_scheduler, save_scheduler

    a = _mini_fed(universe)
    a.initial_training()
    a.run(max_ticks=1)
    path = str(tmp_path / "fed.npz")
    save_scheduler(path, a)
    b = _mini_fed(universe)
    restore_scheduler(path, b)
    assert b._tick_engine.resident_transfers == 0
    b.run(max_ticks=1)
    assert b._tick_engine.resident_transfers > 0


def test_chaos_soak_eight_owners_converges():
    """Seeded storm over an 8-owner ring: crashes, stragglers, and corrupt
    peers for the first ticks, then the chaos window closes — the
    federation must heal (deferred work drains, quarantines release, no
    BUSY/QUARANTINED leak) and still converge to improved scores."""
    stats = [(f"O{i}", 6, 50000, 150000) for i in range(8)]
    aligns = [(f"O{i}", f"O{(i + 1) % 8}", 15000) for i in range(8)]
    uni = synthesize_universe(
        seed=3, scale=1 / 1000, kg_stats=stats, alignments=aligns
    )
    fed = FederationScheduler(
        uni, dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
        tick_faults=(
            "crash=0.25,straggle=0.15,corrupt=0.15,seed=11,until=4,delay=1e6"
        ),
        tick_deadline=1e5, retry_budget=2, backoff_ticks=1,
        quarantine_ticks=2,
    )
    inits = fed.initial_training()
    fed.run(max_ticks=30)
    # the storm actually hit, across multiple kinds, and no tick aborted
    faults = [e.fault for e in fed.events if e.fault]
    assert len(set(faults)) >= 2, f"storm too quiet: {faults}"
    # healed at quiescence: zero leaked transient states, nothing stranded
    assert all(
        s in (NodeState.READY, NodeState.SLEEP) for s in fed.state.values()
    ), {n: s.value for n, s in fed.state.items()}
    assert not fed._deferred and not fed._quarantine_until
    assert fed._tick < 30, "soak should quiesce before the tick cap"
    # converged: backtrack invariant holds and federation still improved
    assert all(fed.best_score[n] >= inits[n] for n in uni)
    assert any(e.accepted and e.kind == "ppat" for e in fed.events)


def test_quarantine_release_coinciding_with_deferred_retry_dedups(universe):
    """Edge case: the quarantine sentence expires on the SAME tick a
    deferred retry for the same (host, client) pair comes due — and an
    earlier retry for that pair is also already past due. One
    ``_release_due`` pass must fold all of it into a single queued offer,
    never a duplicate."""
    fed = _mini_fed(universe, backoff_ticks=2, retry_budget=3,
                    quarantine_ticks=4)
    fed.initial_training()
    # drain the broadcast offers so re-queues are the only queue source
    for n in fed.queue:
        fed.queue[n].clear()
        fed._queued[n].clear()
    fed._tick = 10
    for _ in range(3):
        fed._entry_failed("A", "B", "crash")
    # retries release at 12/14/18; the third blame quarantines A until 14
    assert [r for r, _, _ in fed._deferred] == [12, 14, 18]
    assert fed.state["A"] is NodeState.QUARANTINED
    assert fed._quarantine_until["A"] == 14
    fed._tick = 14
    fed._release_due()
    # quarantine released, and BOTH due retries (12 and 14) collapse into
    # one queue entry for the pair
    assert fed.state["A"] is NodeState.READY
    assert "A" not in fed._quarantine_until
    assert list(fed.queue["A"]) == ["B"]
    assert fed._queued["A"] == {"B"}
    assert fed._deferred == [(18, "A", "B")]


def test_checkpoint_roundtrips_blame_ledger_mid_quarantine(universe, tmp_path):
    """A checkpoint cut while a peer is serving a quarantine sentence must
    round-trip the whole blame ledger — quarantine clock, retry counts,
    deferred releases, reputation — and the sentence must still expire on
    schedule in the resumed process."""
    from repro.checkpoint import restore_scheduler, save_scheduler

    def make():
        return _mini_fed(universe, robust_agg="median", backoff_ticks=1,
                         retry_budget=2, quarantine_ticks=5)

    fed = make()
    fed.initial_training()
    fed._tick = 4
    for _ in range(2):
        fed._entry_failed("A", "B", "poison")  # poison blames the SENDER
    assert fed.state["B"] is NodeState.QUARANTINED
    assert fed._reputation["B"] == pytest.approx(0.25)
    path = str(tmp_path / "quarantine.npz")
    save_scheduler(path, fed)

    b = make()
    restore_scheduler(path, b)
    assert b.state["B"] is NodeState.QUARANTINED
    assert b._quarantine_until == fed._quarantine_until
    assert b._retries == fed._retries
    assert b._deferred == fed._deferred
    assert b._peer_failures == fed._peer_failures
    assert b._reputation == pytest.approx(fed._reputation)
    b._tick = b._quarantine_until["B"]
    b._release_due()
    assert b.state["B"] is NodeState.READY and not b._quarantine_until
