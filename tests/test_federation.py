"""Federation protocol: handshake, backtrack, broadcast, quiescence."""
import numpy as np
import pytest

from repro.core.federation import FederationScheduler, NodeState
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe


@pytest.fixture(scope="module")
def universe():
    stats = [("A", 12, 90000, 300000), ("B", 10, 70000, 240000), ("C", 8, 60000, 200000)]
    aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
    return synthesize_universe(seed=1, scale=1 / 500, kg_stats=stats, alignments=aligns)


@pytest.fixture(scope="module")
def trained_fed(universe):
    fed = FederationScheduler(
        universe, dim=24, ppat_cfg=PPATConfig(steps=60, seed=0),
        local_epochs=80, update_epochs=25, seed=0,
    )
    fed.initial_training()
    fed.run(max_ticks=2)
    return fed


def test_initial_training_broadcasts(universe):
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=5), local_epochs=5, seed=0
    )
    fed.initial_training()
    # every owner with alignments got handshake offers queued
    assert all(len(fed.queue[n]) > 0 for n in universe)
    assert all(fed.state[n] is NodeState.READY for n in universe)


def test_best_score_never_decreases(trained_fed):
    """Backtrack invariant: accepted federations only ever improve."""
    best = {}
    for ev in trained_fed.events:
        if ev.kind == "init":
            best[ev.host] = ev.score_after
            continue
        if ev.accepted:
            assert ev.score_after > best[ev.host]
            best[ev.host] = ev.score_after
        else:
            assert ev.score_after <= best[ev.host] + 1e-9
    assert best == trained_fed.best_score


def test_rejected_federation_restores_snapshot(universe):
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=5, seed=0),
        local_epochs=30, update_epochs=2, seed=0,
    )
    fed.initial_training()
    snap_before = {k: np.asarray(v["ent"]) for k, v in
                   ((n, fed.best_snapshot[n]) for n in universe)}
    ev = fed.federate_once("A", "B")
    if not ev.accepted:
        assert np.allclose(np.asarray(fed.trainers["A"].params["ent"]), snap_before["A"])


def test_federation_improves_some_kg(trained_fed):
    inits = {e.host: e.score_after for e in trained_fed.events if e.kind == "init"}
    improved = [n for n, s in trained_fed.best_score.items() if s > inits[n] + 1e-9]
    assert improved, "federation should improve at least one KG"


def test_epsilon_recorded_per_handshake(trained_fed):
    ppat_events = [e for e in trained_fed.events if e.kind == "ppat"]
    assert ppat_events
    assert all(np.isfinite(e.epsilon) and e.epsilon > 0 for e in ppat_events)


def test_busy_state_cleared(trained_fed):
    assert all(s is not NodeState.BUSY for s in trained_fed.state.values())


# ------------------------------------------- scheduler protocol invariants
def test_broadcast_wakes_sleeping_partners(universe):
    """Alg. 1 l. 30 / Fig. 2: a handshake signal is also a wake-up signal."""
    fed = FederationScheduler(universe, dim=16, local_epochs=1, seed=0)
    for n in universe:
        fed.state[n] = NodeState.SLEEP
    fed.broadcast("A")
    for partner in fed.registry.partners("A"):
        assert fed.state[partner] is NodeState.READY
        assert list(fed.queue[partner]) == ["A"]
    assert fed.state["A"] is NodeState.SLEEP  # no self-wake


def test_broadcast_dedup_is_o1_under_repeated_broadcasts(universe):
    """Repeated broadcasts from every owner leave each queue with at most
    one offer per partner — the pending-set mirror stays consistent."""
    fed = FederationScheduler(universe, dim=16, local_epochs=1, seed=0)
    for _ in range(7):
        for n in universe:
            fed.broadcast(n)
    for n in universe:
        offers = list(fed.queue[n])
        assert len(offers) == len(set(offers))
        assert set(offers) == fed._queued[n]
        assert set(offers) == set(fed.registry.partners(n))


def test_quiescence_terminates_without_self_train(universe):
    """With self-training off and a score_fn that never improves, every
    owner drains its queue and sleeps — run() stops before max_ticks."""
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=2, seed=0),
        local_epochs=1, update_epochs=1, seed=0,
        score_fn=lambda name: 0.0,  # never beats the init score
    )
    fed.best_score = {n: 1.0 for n in universe}
    fed.best_snapshot = {n: fed.trainers[n].snapshot() for n in universe}
    for n in universe:
        fed.broadcast(n)
    fed.run(max_ticks=50, self_train=False)
    assert fed._tick < 50, "run() should hit quiescence, not the tick cap"
    assert all(not q for q in fed.queue.values())
    assert not any(e.accepted for e in fed.events)
    # a further run immediately puts everyone to sleep and stays quiescent
    fed.run(max_ticks=2, self_train=False)
    assert all(s is NodeState.SLEEP for s in fed.state.values())


@pytest.mark.parametrize("tick_impl", ["reference", "batched"])
def test_rejected_backtrack_restores_bit_identical_params(universe, tick_impl):
    """Alg. 1 l. 17: a rejected KGEmb-Update must leave EVERY param table
    bit-identical to the pre-handshake snapshot, under both tick engines."""
    fed = FederationScheduler(
        universe, dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
    )
    fed.initial_training()
    fed.score_fn = lambda name: -1.0  # force every backtrack to reject
    snaps = {
        n: {k: np.asarray(v) for k, v in fed.best_snapshot[n].items()}
        for n in universe
    }
    fed.run(max_ticks=2, tick_impl=tick_impl)
    rejected = [e for e in fed.events if e.kind != "init"]
    assert rejected and not any(e.accepted for e in rejected)
    for n in universe:
        for k, v in snaps[n].items():
            np.testing.assert_array_equal(
                np.asarray(fed.trainers[n].params[k]), v,
                err_msg=f"{tick_impl}: {n}.{k} not restored bit-identically",
            )
