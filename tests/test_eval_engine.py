"""Streaming fused-rank evaluation engine: kernel-vs-ref + end-to-end parity.

The engine must reproduce the seed per-triple numpy ranking EXACTLY (filtered
and raw, head and tail corruption, L1 and L2, non-divisible tail blocks) while
never materializing a (B, E) score matrix on host.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dispatch import resolve_interpret, resolve_rank_impl
from repro.kernels.triple_score import (
    fused_ranks,
    fused_ranks_ref,
    pairwise_scores,
    pairwise_scores_ref,
)
from repro.kge.data import synthesize_universe
from repro.kge.eval import (
    best_threshold_accuracy,
    build_filter_arrays,
    link_prediction,
    streaming_rank_counts,
)
from repro.kge.trainer import KGETrainer
from repro.serving.engine import KGECandidateRanker


@pytest.fixture(scope="module")
def tiny_kg():
    stats = [("A", 10, 80000, 280000)]
    kgs = synthesize_universe(seed=0, scale=1 / 400, kg_stats=stats, alignments=[])
    return kgs["A"]


def _trained(kg, family="transe", norm_ord=1, epochs=3, dim=24):
    tr = KGETrainer(kg, family, dim=dim, seed=0, margin=2.0)
    if norm_ord != 1:
        tr.model = dataclasses.replace(tr.model, norm_ord=norm_ord)
    tr.train_epochs(epochs)
    return tr


# ------------------------------------------------------- kernel vs ref oracle
@pytest.mark.parametrize("mode", ["l1", "l2", "dot", "cl1"])
@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize(
    "b,e,d,block_e", [(8, 256, 32, 64), (13, 300, 48, 128), (5, 97, 16, 32)]
)
def test_fused_ranks_matches_ref(b, e, d, block_e, impl, mode):
    """Both implementations == the (B, E)-materializing oracle, including
    non-divisible B/E tail blocks and in-kernel filter exclusion."""
    q = jax.random.normal(jax.random.PRNGKey(0), (b, d))
    ent = jax.random.normal(jax.random.PRNGKey(1), (e, d))
    gold_idx = np.arange(b) % e
    filt = np.full((b, 4), -1, np.int32)
    filt[:, 0] = gold_idx
    filt[:, 1] = (gold_idx + 7) % e
    filt[::2, 2] = (gold_idx[::2] + 11) % e
    scores = pairwise_scores_ref(q, ent, mode=mode)
    gold = scores[jnp.arange(b), jnp.asarray(gold_idx)]
    ref = np.asarray(fused_ranks_ref(q, ent, gold, jnp.asarray(filt), mode=mode))
    out = np.asarray(
        fused_ranks(q, ent, gold, jnp.asarray(filt), mode=mode,
                    block_e=block_e, impl=impl, interpret=True)
    )
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("mode", ["l1", "l2", "dot", "cl1"])
def test_pairwise_scores_dot_and_minkowski(mode):
    q = jax.random.normal(jax.random.PRNGKey(0), (9, 40))
    ent = jax.random.normal(jax.random.PRNGKey(1), (130, 40))
    out = pairwise_scores(q, ent, mode=mode, interpret=True)
    ref = pairwise_scores_ref(q, ent, mode=mode)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4, rtol=1e-4)


# ------------------------------------------------------- end-to-end parity
@pytest.mark.parametrize("filtered", [True, False])
@pytest.mark.parametrize(
    "family,norm_ord",
    [
        ("transe", 1),
        ("transe", 2),
        ("distmult", 1),
        # ComplEx/RotatE route through the dot / cl1 decompositions (ROADMAP
        # follow-up from PR 1) — they must hit the fused engine, not the
        # generic score_triples fallback, and still match the seed ranking
        ("complex", 1),
        ("rotate", 1),
    ],
)
def test_link_prediction_engine_parity(tiny_kg, family, norm_ord, filtered):
    """Engine metrics == seed reference metrics, bit-identical, on a fixed-seed
    universe — batch 16 does not divide the 50-triple test slice."""
    tr = _trained(tiny_kg, family, norm_ord)
    kw = dict(filtered=filtered, max_test=50, batch=16)
    ref = link_prediction(tr.params, tr.model, tiny_kg, engine="reference", **kw)
    fused = link_prediction(tr.params, tr.model, tiny_kg, engine="fused",
                            block_e=48, **kw)
    assert ref == fused


@pytest.mark.parametrize("filtered", [True, False])
def test_link_prediction_generic_family_parity(tiny_kg, filtered):
    """Non-decomposable families stream through score_triples blockwise and
    must match the reference too (transh exercises the generic path)."""
    tr = _trained(tiny_kg, "transh")
    kw = dict(filtered=filtered, max_test=30, batch=16)
    ref = link_prediction(tr.params, tr.model, tiny_kg, engine="reference", **kw)
    fused = link_prediction(tr.params, tr.model, tiny_kg, engine="fused", **kw)
    assert ref == fused


def test_head_and_tail_counts_separately(tiny_kg):
    """Per-side rank counts match a hand-rolled numpy ranking, head AND tail."""
    tr = _trained(tiny_kg)
    test = np.asarray(tiny_kg.test)[:20]
    all_triples = np.concatenate([tiny_kg.train, tiny_kg.valid, tiny_kg.test])
    filt_t, filt_h = build_filter_arrays(test, all_triples, filtered=True)
    c_tail, c_head = streaming_rank_counts(
        tr.params, tr.model, test, filt_t, filt_h, block_e=64
    )

    from repro.kge.models import score_all_heads, score_all_tails

    h, r, t = (jnp.asarray(test[:, i]) for i in range(3))
    s_tail = np.asarray(score_all_tails(tr.params, tr.model, h, r, via_kernel=False))
    s_head = np.asarray(score_all_heads(tr.params, tr.model, r, t, via_kernel=False))
    for j, (hh, rr, tt) in enumerate(test):
        row = s_tail[j].copy()
        row[filt_t[j][filt_t[j] >= 0]] = -np.inf
        assert int(c_tail[j]) == int((row > s_tail[j, int(tt)]).sum())
        row = s_head[j].copy()
        row[filt_h[j][filt_h[j] >= 0]] = -np.inf
        assert int(c_head[j]) == int((row > s_head[j, int(hh)]).sum())


def test_no_full_score_matrix_on_host(tiny_kg, monkeypatch):
    """The engine path must never call the (B, E)-materializing scorers."""
    import repro.kge.eval as eval_mod

    def _boom(*a, **k):  # pragma: no cover - should never run
        raise AssertionError("engine materialized a (B, E) score matrix")

    monkeypatch.setattr(eval_mod, "score_all_tails", _boom)
    monkeypatch.setattr(eval_mod, "score_all_heads", _boom)
    tr = _trained(tiny_kg)
    lp = link_prediction(tr.params, tr.model, tiny_kg, max_test=20, engine="fused")
    assert 1.0 <= lp["mean_rank"] <= tiny_kg.num_entities


# ------------------------------------------------------------ dispatch rules
def test_resolve_interpret_backend_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # CPU CI default backend → interpreter
    assert resolve_interpret(None) is (jax.default_backend() not in
                                       ("tpu", "gpu", "cuda", "rocm"))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert resolve_interpret(None) is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "true")
    assert resolve_interpret(None) is True
    # explicit argument still wins over the env override
    assert resolve_interpret(False) is False


def test_resolve_rank_impl(monkeypatch):
    monkeypatch.delenv("REPRO_RANK_IMPL", raising=False)
    assert resolve_rank_impl("pallas") == "pallas"
    assert resolve_rank_impl(None) in ("pallas", "xla")
    monkeypatch.setenv("REPRO_RANK_IMPL", "pallas")
    assert resolve_rank_impl(None) == "pallas"
    with pytest.raises(ValueError):
        resolve_rank_impl("tensorflow")


# ------------------------------------------------- vectorized threshold scan
def test_best_threshold_accuracy_matches_loop():
    rng = np.random.default_rng(0)
    pos = rng.normal(1.0, 1.0, 400)
    neg = rng.normal(-1.0, 1.0, 400)
    thr, acc = best_threshold_accuracy(pos, neg)
    cand = np.unique(np.concatenate([pos, neg]))
    ref = [((pos >= c).mean() + (neg < c).mean()) / 2.0 for c in cand]
    assert acc == pytest.approx(np.max(ref))
    assert acc > 0.8


# ----------------------------------------------- virtual-entity negatives fix
def test_trainer_corrupts_against_extended_entities(tiny_kg, monkeypatch):
    tr = KGETrainer(tiny_kg, "transe", dim=16, seed=0)
    e0, r0 = tr.model.num_entities, tr.model.num_relations
    tr.extend_tables(
        jnp.ones((5, 16)) * 0.1, jnp.ones((2, 16)) * 0.2,
        np.array([[e0, r0, 3], [1, r0 + 1, e0 + 4]], dtype=np.int64),
    )
    seen = {}
    import repro.kge.data as data_mod

    real = data_mod.corrupt_triples

    def spy(rng, triples, num_entities):
        seen["num_entities"] = num_entities
        return real(rng, triples, num_entities)

    monkeypatch.setattr(data_mod, "corrupt_triples", spy)
    # impl="reference" pins the host numpy-sampling path this spy observes;
    # the device engine's equivalent (traced corruption bound = extended
    # count, bucket-padding rows excluded) is covered in test_train_engine.
    tr.train_epochs(1, impl="reference")
    assert seen["num_entities"] == e0 + 5  # extended count, not kg.num_entities
    tr.strip_virtual()
    tr.train_epochs(1, impl="reference")
    assert seen["num_entities"] == e0


# ------------------------------------------------------------ serving ranker
def test_candidate_ranker_rank_and_topk(tiny_kg):
    tr = _trained(tiny_kg)
    known = np.concatenate([tiny_kg.train, tiny_kg.valid, tiny_kg.test])
    ranker = KGECandidateRanker(tr.params, tr.model, known, block_e=64)
    test = np.asarray(tiny_kg.test)[:12]
    ranks = ranker.rank_tails(test[:, 0], test[:, 1], test[:, 2])
    assert ranks.shape == (12,)
    assert (ranks >= 1).all() and (ranks <= tr.model.num_entities).all()

    # streaming top-k == full argsort of the dense scores with known excluded
    from repro.kge.models import score_all_tails

    h, r = jnp.asarray(test[:, 0]), jnp.asarray(test[:, 1])
    ids, scores = ranker.topk_tails(test[:, 0], test[:, 1], k=5)
    dense = np.asarray(score_all_tails(tr.params, tr.model, h, r, via_kernel=False))
    for j in range(len(test)):
        row = dense[j].copy()
        key = (int(test[j, 0]), int(test[j, 1]))
        for known_t in ranker._hr_t.get(key, ()):
            row[known_t] = -np.inf
        expect = np.argsort(-row, kind="stable")[:5]
        np.testing.assert_allclose(row[expect], scores[j], rtol=1e-6, atol=1e-6)


def test_candidate_ranker_rejects_bad_ids(tiny_kg):
    """Serving boundary: out-of-range / negative ids are refused with a
    clear ValueError instead of wrapping into the wrong table row."""
    tr = _trained(tiny_kg)
    ranker = KGECandidateRanker(tr.params, tr.model, tiny_kg.train, block_e=64)
    e, r = tr.model.num_entities, tr.model.num_relations
    with pytest.raises(ValueError, match=r"head entity ids .*\[-1\]"):
        ranker.rank_tails([-1], [0], [1])
    with pytest.raises(ValueError, match=rf"tail entity ids .*\[{e}\]"):
        ranker.rank_tails([0], [0], [e])
    with pytest.raises(ValueError, match="relation ids"):
        ranker.rank_tails([0], [r + 3], [1])
    with pytest.raises(ValueError, match="head entity ids"):
        ranker.topk_tails([0, e + 7], [0], k=3)
    with pytest.raises(ValueError, match="relation ids"):
        ranker.topk_tails([0], [-2], k=3)
    # in-range requests still serve
    assert ranker.rank_tails([0], [0], [1]).shape == (1,)


def test_candidate_ranker_rejects_non_finite_query(tiny_kg):
    """A NaN/Inf embedding row poisons every rank it participates in — a
    query that would serve from one is refused, naming the offending id."""
    tr = _trained(tiny_kg)
    params = {k: np.asarray(v).copy() for k, v in tr.params.items()}
    params["ent"][3, 0] = np.nan
    params["rel"][1, 2] = np.inf
    ranker = KGECandidateRanker(params, tr.model, tiny_kg.train, block_e=64)
    with pytest.raises(ValueError, match=r"non-finite query embedding: entity ids \[3\]"):
        ranker.rank_tails([3], [0], [1])
    with pytest.raises(ValueError, match=r"relation ids \[1\]"):
        ranker.topk_tails([0], [1], k=3)
    # untouched ids still serve fine
    assert ranker.rank_tails([0], [0], [1]).shape == (1,)
