"""Byzantine adversary layer: attack plans, tampering, robust acceptance,
reputation, engine parity under storm, and leakage-attack scoring units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adversary import Adversary, AdversaryPlan, Attack
from repro.core.aggregation import robust_rows
from repro.core.federation import FederationScheduler
from repro.core.ppat import PPATConfig
from repro.kge.data import synthesize_universe


@pytest.fixture(scope="module")
def universe():
    stats = [("A", 12, 90000, 300000), ("B", 10, 70000, 240000),
             ("C", 8, 60000, 200000)]
    aligns = [("A", "B", 30000), ("B", "C", 20000), ("A", "C", 18000)]
    return synthesize_universe(
        seed=1, scale=1 / 500, kg_stats=stats, alignments=aligns
    )


def _mini_fed(universe, **kw):
    defaults = dict(
        dim=16, ppat_cfg=PPATConfig(steps=3, seed=0),
        local_epochs=2, update_epochs=1, seed=0,
    )
    defaults.update(kw)
    return FederationScheduler(universe, **defaults)


def _event_key(e):
    return (e.tick, e.host, e.client or "", e.kind, e.fault or "",
            e.attack or "", e.accepted,
            repr(e.score_before), repr(e.score_after), repr(e.epsilon))


def _assert_params_equal(a, b, msg):
    for n in a.trainers:
        for k in a.trainers[n].params:
            np.testing.assert_array_equal(
                np.asarray(a.trainers[n].params[k]),
                np.asarray(b.trainers[n].params[k]),
                err_msg=f"{msg}: {n}.{k}",
            )


# ------------------------------------------------------------------ the plan
def test_adversary_plan_parse_and_determinism():
    plan = AdversaryPlan.parse(
        "drift=0.4,sybil=0.2,peers=B+C,seed=7,until=9,strength=0.8,"
        "evade=0.85,frac=0.5"
    )
    assert plan.drift == 0.4 and plan.sybil == 0.2 and plan.replay == 0.0
    assert plan.peers == ("B", "C") and plan.seed == 7 and plan.until == 9
    assert plan.strength == 0.8 and plan.evade == 0.85 and plan.frac == 0.5
    draws = [plan.draw(t, "A", "B") for t in range(1, 30)]
    assert draws == [plan.draw(t, "A", "B") for t in range(1, 30)]
    # storm window closes after `until`
    assert all(d is None for t, d in zip(range(1, 30), draws) if t > 9)
    # peers restriction: A is not adversarial; self-train never attacks
    assert all(plan.draw(t, "B", "A") is None for t in range(1, 30))
    assert plan.draw(1, "A", None) is None
    assert AdversaryPlan.parse("on") == AdversaryPlan()
    with pytest.raises(ValueError):
        AdversaryPlan.parse("drift=1.5")
    with pytest.raises(ValueError):
        AdversaryPlan.parse("bogus=1")
    with pytest.raises(ValueError):
        AdversaryPlan.parse("drift")


def test_tamper_norm_evasion_and_determinism():
    """Tampered rows stay strictly inside the receiver's norm screen (the
    whole point of the adversary: the integrity layer passes it), the
    poisoned subset is the seeded `frac` fraction, and two independent
    Adversary instances tamper bit-identically."""
    plan = AdversaryPlan.parse("drift=1.0,seed=3,strength=1.0,frac=0.5,bound=4.0")
    rows = np.arange(20)
    view = {"ent": jnp.asarray(np.random.default_rng(0).normal(size=(32, 8)),
                               dtype=jnp.float32)}
    atk = Attack("drift", strength=1.0, evade=0.9, frac=0.5)
    out1 = Adversary(plan).tamper_view(dict(view), atk, 2, "A", "B", rows=rows)
    out2 = Adversary(plan).tamper_view(dict(view), atk, 2, "A", "B", rows=rows)
    np.testing.assert_array_equal(np.asarray(out1["ent"]),
                                  np.asarray(out2["ent"]))
    ent0, ent1 = np.asarray(view["ent"]), np.asarray(out1["ent"])
    changed = np.where(np.any(ent0 != ent1, axis=1))[0]
    assert len(changed) == 10  # frac=0.5 of 20 targeted rows
    assert set(changed) <= set(rows.tolist())
    # finite and norm-evading: ≤ evade * bound, so screen_rows passes
    assert np.isfinite(ent1).all()
    assert (np.linalg.norm(ent1[changed], axis=1) <= 0.9 * 4.0 + 1e-5).all()
    # sybil direction is shared across clients; drift is per-client
    adv = Adversary(plan)
    assert np.allclose(adv._direction("B", 8, "sybil"),
                       adv._direction("C", 8, "sybil"))
    assert not np.allclose(adv._direction("B", 8, "drift"),
                           adv._direction("C", 8, "drift"))


def test_replay_caches_first_view_then_reships_it():
    plan = AdversaryPlan.parse("replay=1.0,seed=1")
    adv = Adversary(plan)
    atk = Attack("replay")
    v1 = {"ent": jnp.ones((4, 3), jnp.float32)}
    v2 = {"ent": jnp.full((4, 3), 9.0, jnp.float32)}
    out1 = adv.tamper_view(v1, atk, 1, "A", "B", rows=np.arange(4))
    np.testing.assert_array_equal(np.asarray(out1["ent"]), np.ones((4, 3)))
    # second fire ships the CACHED view, not the fresh one
    out2 = adv.tamper_view(v2, atk, 2, "A", "B", rows=np.arange(4))
    np.testing.assert_array_equal(np.asarray(out2["ent"]), np.ones((4, 3)))
    # cache round-trips through the checkpoint surface
    tree = adv.stale_arrays()
    assert list(tree) == ["B::A"]
    adv2 = Adversary(plan)
    adv2.load_stale(tree)
    out3 = adv2.tamper_view(v2, atk, 3, "A", "B", rows=np.arange(4))
    np.testing.assert_array_equal(np.asarray(out3["ent"]), np.ones((4, 3)))


# ------------------------------------------------------- robust aggregation
def test_robust_rows_modes_and_padded_tail():
    rng = np.random.default_rng(0)
    n, pad, d = 20, 32, 8
    cur = jnp.asarray(rng.normal(size=(pad, d)), jnp.float32)
    synth = cur + jnp.asarray(0.05 * rng.normal(size=(pad, d)), jnp.float32)
    # one Byzantine row: a huge targeted delta
    synth = synth.at[3].set(cur[3] + 50.0)
    out_none, cos_none = robust_rows(
        cur, synth, jnp.int32(n), mode="none", want_cos=True
    )
    np.testing.assert_array_equal(np.asarray(out_none), np.asarray(synth))
    for mode in ("clip", "median", "trimmed"):
        out, _ = robust_rows(cur, synth, jnp.int32(n), mode=mode, want_cos=False)
        out = np.asarray(out)
        # the outlier is clamped toward the honest delta distribution...
        poisoned_delta = np.linalg.norm(out[3] - np.asarray(cur)[3])
        assert poisoned_delta < 5.0, (mode, poisoned_delta)
        # ...honest rows barely move, and rows past n pass through untouched
        honest = [i for i in range(n) if i != 3]
        np.testing.assert_allclose(
            out[honest], np.asarray(synth)[honest], atol=0.35,
            err_msg=mode,
        )
        np.testing.assert_array_equal(out[n:], np.asarray(synth)[n:],
                                      err_msg=mode)
    # mean_cos matches a numpy oracle over the true rows only
    c, s = np.asarray(cur, np.float64), np.asarray(synth, np.float64)
    want = np.mean([
        float(c[i] @ s[i] / (np.linalg.norm(c[i]) * np.linalg.norm(s[i]) + 1e-12))
        for i in range(n)
    ])
    assert abs(float(cos_none) - want) < 1e-5
    with pytest.raises(ValueError, match="unknown robust_agg"):
        from repro.core.aggregation import robust_rows_graph
        robust_rows_graph(cur, synth, jnp.int32(n), mode="krum", want_cos=False)


# ----------------------------------------------------------- engine parity
ADV = "drift=0.5,sybil=0.3,replay=0.2,seed=5,strength=0.9,frac=0.5"


@pytest.mark.parametrize(
    "defense",
    [dict(), dict(robust_agg="median", cos_screen=0.3)],
    ids=["defenses-off", "defenses-on"],
)
def test_adversary_engine_parity(universe, defense):
    """Both tick engines must replay the same storm bit-identically —
    tamper order, replay-cache advancement, screens, robustization, and
    reputation all live outside the engines' key-stream lockstep."""
    def run(impl):
        fed = _mini_fed(universe, tick_adversary=ADV, **defense)
        fed.initial_training()
        fed.run(max_ticks=3, tick_impl=impl)
        return fed

    ref, bat = run("reference"), run("batched")
    attacks = [e.attack for e in ref.events if e.attack]
    assert attacks, "storm never fired"
    assert len(set(attacks)) >= 2, f"want multiple kinds, saw {set(attacks)}"
    assert list(map(_event_key, ref.events)) == list(map(_event_key, bat.events))
    assert ref._reputation == bat._reputation
    _assert_params_equal(ref, bat, "adversary parity")


def test_armed_but_inert_adversary_is_bit_identical(universe):
    """tick_adversary="on" (zero rates) must not perturb a single decision
    or array vs the adversary-off path — the hooks are free when idle."""
    def run(adv):
        fed = _mini_fed(universe, tick_adversary=adv)
        fed.initial_training()
        fed.run(max_ticks=2)
        return fed

    off, on = run(None), run("on")
    assert list(map(_event_key, off.events)) == list(map(_event_key, on.events))
    _assert_params_equal(off, on, "inert adversary")


# ------------------------------------------------- reputation + acceptance
def test_reputation_decay_recovery_and_screen_sharpening(universe):
    fed = _mini_fed(universe, robust_agg="median", cos_screen=0.4,
                    rep_decay=0.5, rep_recover=0.25)
    fed.initial_training()
    assert fed._defended
    assert fed._cos_tau("B") == pytest.approx(0.4)
    fed._entry_failed("A", "B", "poison", emit=False)
    assert fed._reputation["B"] == pytest.approx(0.5)
    # decayed reputation sharpens the screen toward 1.0
    assert fed._cos_tau("B") == pytest.approx(1.0 - 0.5 * 0.6)
    fed._entry_failed("A", "B", "poison", emit=False)
    assert fed._reputation["B"] == pytest.approx(0.25)
    # accepted handshakes recover additively; pristine entries are dropped
    fed._rep_recover("A", "B")
    assert "A" not in fed._reputation  # never decayed → stays absent
    assert fed._reputation["B"] == pytest.approx(0.5)
    for _ in range(2):
        fed._rep_recover("B")
    assert "B" not in fed._reputation
    assert fed._cos_tau("B") == pytest.approx(0.4)


def test_reputation_priority_ordering_when_defended(universe):
    """With defenses armed, the lowest-reputation queued offer waits behind
    peers in good standing; defenses off, the queue stays FIFO."""
    from collections import deque

    fed = _mini_fed(universe, robust_agg="median")
    fed.initial_training()
    fed._reputation = {"B": 0.2}
    fed.queue["A"] = deque(["B", "C"])
    fed._queued["A"] = {"B", "C"}
    assert fed._next_offer("A") == "C"  # C pristine, B suspected
    assert fed._next_offer("A") == "B"
    off = _mini_fed(universe)
    off.initial_training()
    off._reputation = {"B": 0.2}  # state may exist, must not gate
    off.queue["A"] = deque(["B", "C"])
    off._queued["A"] = {"B", "C"}
    assert off._next_offer("A") == "B"


def test_poisoning_storm_defenses_flag_and_blame(universe):
    """An aggressive drift storm against armed defenses: poison verdicts
    fire, the sender (not the host) accrues blame, reputation decays, and
    no fault escalates to an abort."""
    fed = _mini_fed(
        universe,
        tick_adversary="drift=1.0,seed=9,strength=1.0,frac=0.4",
        robust_agg="median", cos_screen=0.5,
    )
    fed.initial_training()
    fed.run(max_ticks=10)
    poisons = [e for e in fed.events if e.fault == "poison"]
    assert poisons, "screen never fired under a full-strength storm"
    assert all(e.attack for e in poisons), "poison verdicts on clean entries"
    assert not [e for e in fed.events if e.fault == "error"]
    assert fed._reputation and min(fed._reputation.values()) < 1.0
    # poison blames the SENDER: every flagged client decayed
    assert set(fed._reputation) <= {e.client for e in poisons}


# -------------------------------------------------------- checkpoint resume
def test_resume_mid_storm_bit_parity(universe, tmp_path):
    """A run killed mid-storm and resumed replays the remaining attacks
    bit-identically — including re-shipping the SAME cached stale views
    (the replay cache rides the checkpoint) and the reputation state."""
    from repro.checkpoint import restore_scheduler, save_scheduler

    spec = "drift=0.4,replay=0.6,seed=2,strength=0.9,frac=0.5"
    def make():
        return _mini_fed(universe, tick_adversary=spec,
                         robust_agg="median", cos_screen=0.3)

    path = str(tmp_path / "storm.npz")
    a = make()
    a.initial_training()
    a.run(max_ticks=2)
    assert a._adversary is not None and a._adversary._stale, \
        "replay cache empty — the resume test would prove nothing"
    cut = a._tick
    stale_at_save = sorted(a._adversary._stale)
    save_scheduler(path, a)
    a.run(max_ticks=2)

    b = make()
    restore_scheduler(path, b)
    assert b._adversary is not None
    assert sorted(b._adversary._stale) == stale_at_save
    assert b._reputation == {
        k: float(v) for k, v in a._reputation.items()
    } or b._reputation == a._reputation
    b.run(max_ticks=2)
    tail = [e for e in a.events if e.tick > cut]
    assert tail and list(map(_event_key, tail)) == list(map(_event_key, b.events))
    _assert_params_equal(a, b, "resume mid-storm")


def test_restore_refuses_stale_cache_without_adversary(universe, tmp_path):
    from repro.checkpoint import restore_scheduler, save_scheduler

    a = _mini_fed(universe, tick_adversary="replay=1.0,seed=2")
    a.initial_training()
    a.run(max_ticks=2)
    assert a._adversary._stale
    path = str(tmp_path / "storm.npz")
    save_scheduler(path, a)
    b = _mini_fed(universe)  # no tick_adversary configured
    with pytest.raises(ValueError, match="adversary replay state"):
        restore_scheduler(path, b)


# ---------------------------------------------------------- attack scoring
def test_auc_and_advantage_units():
    from repro.core.attacks import advantage, auc

    assert auc(np.array([2.0, 3.0]), np.array([0.0, 1.0])) == 1.0
    assert auc(np.array([0.0, 1.0]), np.array([2.0, 3.0])) == 0.0
    # heavy ties → tie-averaged ranks keep AUC at chance, not polarity-biased
    assert auc(np.ones(50), np.ones(70)) == pytest.approx(0.5)
    assert auc(np.array([]), np.array([1.0])) == 0.5
    assert advantage(0.5) == 0.0 and advantage(1.0) == 1.0
    assert advantage(0.0) == 1.0  # symmetric in score polarity


def test_membership_inference_separates_planted_signal():
    """A release whose geometry encodes the member triples (e_t = e_h + r̂)
    must be attacked successfully; a random release must not."""
    from repro.core.attacks import membership_inference

    rng = np.random.default_rng(0)
    d, n_ent = 8, 40
    ent = rng.normal(size=(n_ent, d))
    offset = rng.normal(size=d)
    members = []
    for i in range(0, 30, 2):
        ent[i + 1] = ent[i] + offset + 0.01 * rng.normal(size=d)
        members.append((i, 0, i + 1))
    nonmembers = [(int(a), 0, int(b))
                  for a, b in rng.integers(30, n_ent, size=(15, 2))]
    rel = {i: ent[i] for i in range(n_ent)}
    mi = membership_inference(
        rel, np.asarray(members, np.int64), np.asarray(nonmembers, np.int64)
    )
    assert mi["auc"] > 0.9 and mi["n_member"] == 15
    noise = {i: rng.normal(size=d) for i in range(n_ent)}
    mi0 = membership_inference(
        noise, np.asarray(members, np.int64), np.asarray(nonmembers, np.int64)
    )
    assert abs(mi0["auc"] - 0.5) < 0.35  # no structure → near chance


def test_reconstruction_attack_units():
    from repro.core.attacks import reconstruction_attack

    rng = np.random.default_rng(1)
    true = rng.normal(size=(30, 6))
    # released = rotated true: procrustes must recover it exactly
    q, _ = np.linalg.qr(rng.normal(size=(6, 6)))
    rec = reconstruction_attack(true @ q, true)
    assert rec["cosine"] > 0.999 and rec["mse"] < 1e-10
    noise = reconstruction_attack(rng.normal(size=(30, 6)), true)
    assert noise["cosine"] < 0.8
    with pytest.raises(ValueError, match="match"):
        reconstruction_attack(true[:5], true)


def test_noisy_vote_labels_channel():
    """The attacker-facing vote channel: deterministic per key, and at
    λ=0 (clean votes) it returns the exact majority-vote labels."""
    from repro.core.ppat import _init_host_params, noisy_vote_labels

    params = _init_host_params(jax.random.PRNGKey(0), 8, PPATConfig())
    rows = jnp.asarray(np.random.default_rng(0).normal(size=(12, 8)),
                       jnp.float32)
    a = noisy_vote_labels(params, rows, 0.3, jax.random.PRNGKey(1), rounds=4)
    b = noisy_vote_labels(params, rows, 0.3, jax.random.PRNGKey(1), rounds=4)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (12,) and ((a >= 0) & (a <= 1)).all()
    clean = noisy_vote_labels(params, rows, 0.0, jax.random.PRNGKey(2))
    assert set(np.unique(clean)) <= {0.0, 1.0}
