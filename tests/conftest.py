# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 placeholders.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # container has no hypothesis wheel; fall back to the deterministic stub
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub

import jax

jax.config.update("jax_enable_x64", False)
