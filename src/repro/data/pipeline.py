"""Token data pipeline: tokenizer, synthetic corpus, sharded batching.

The LM-substrate training driver needs a deterministic, dependency-free data
path. ``SyntheticTextDataset`` generates a Zipf-distributed token stream with
local n-gram structure (so a model can actually reduce loss); ``make_batches``
yields host-side numpy batches which the launcher places onto the mesh with
the batch PartitionSpec.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


class ByteTokenizer:
    """Trivial byte-level tokenizer (vocab 256 + specials)."""

    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)

    def decode(self, ids) -> str:
        ids = [i for i in np.asarray(ids).tolist() if i < 256]
        return bytes(ids).decode("utf-8", errors="replace")


@dataclass
class SyntheticTextDataset:
    """Zipf tokens with Markov bigram structure — learnable, deterministic."""

    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: float = 0.7  # prob of following the bigram table

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse bigram successor table: each token has 4 preferred successors
        self._succ = rng.integers(0, v, size=(min(v, 65536), 4))

    def stream(self, *, seed: Optional[int] = None) -> Iterator[int]:
        rng = np.random.default_rng(self.seed if seed is None else seed)
        v = self.vocab_size
        cur = int(rng.integers(0, v))
        while True:
            yield cur
            if rng.random() < self.markov_order and cur < len(self._succ):
                cur = int(self._succ[cur][rng.integers(0, 4)])
            else:
                # Zipf over the head of the vocab
                cur = int(min(rng.zipf(self.zipf_a), v) - 1)

    def tokens(self, n: int, *, seed: Optional[int] = None) -> np.ndarray:
        it = self.stream(seed=seed)
        return np.fromiter((next(it) for _ in range(n)), dtype=np.int32, count=n)


def make_batches(
    ds: SyntheticTextDataset,
    *,
    batch: int,
    seq_len: int,
    steps: int,
    seed: int = 0,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {tokens, labels} with labels = next-token shift."""
    for step in range(steps):
        toks = ds.tokens(batch * (seq_len + 1), seed=seed * 100_003 + step)
        toks = toks.reshape(batch, seq_len + 1)
        yield {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
