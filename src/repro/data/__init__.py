from repro.data.pipeline import SyntheticTextDataset, ByteTokenizer, make_batches  # noqa: F401
