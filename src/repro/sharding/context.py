"""Ambient mesh for layers that need manual collectives (shard_map MoE).

``make_workload`` / the train driver set this before tracing; layers read it.
``None`` means single-host execution (CPU tests) → layers fall back to their
pure-pjit implementations.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def auto_axis_types_kw(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto,)*n`` kwargs for ``jax.make_mesh`` on jax
    versions that have ``jax.sharding.AxisType`` (> 0.4.37); empty dict (the
    same Auto default) on older versions."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n_axes} if at is not None else {}


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` (new API, ``check_vma=``) with a fallback to
    ``jax.experimental.shard_map`` (``check_rep=``) on jax ≤ 0.4.37."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def batch_axes() -> Tuple[str, ...]:
    if _MESH is None:
        return ()
    return ("pod", "data") if "pod" in _MESH.axis_names else ("data",)
