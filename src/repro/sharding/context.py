"""Ambient mesh for layers that need manual collectives (shard_map MoE).

``make_workload`` / the train driver set this before tracing; layers read it.
``None`` means single-host execution (CPU tests) → layers fall back to their
pure-pjit implementations.
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def batch_axes() -> Tuple[str, ...]:
    if _MESH is None:
        return ()
    return ("pod", "data") if "pod" in _MESH.axis_names else ("data",)
