"""PartitionSpec rule tables for every parameter / cache / batch tensor.

Rules are matched on the parameter's pytree path (names assigned in
``repro.models``), so a new architecture composed from the same layer library
inherits correct sharding for free. Layer stacks carry a leading repeat axis
(scan-over-layers) which is never sharded.

Baseline layout (single pod): mesh ('data', 'model') = (16, 16).
  * embeddings / unembedding: vocab over 'model'
  * attention: head dim of QKV over 'model', wo mirrored
  * dense MLP: d_ff over 'model'
  * MoE experts: expert axis over 'data' (expert parallelism), d_ff over
    'model' — token→expert dispatch lowers to all-to-all traffic
  * SSM: channel/head axes over 'model'
  * optimizer moments: same spec as their parameter
Multi-pod adds a leading 'pod' axis composed into the batch axes.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey


def _names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
        else:
            out.append(str(k))
    return tuple(out)


DATA_SIZE = 16  # production mesh 'data' axis extent (per pod)


def _rule(names: Tuple[str, ...], ndim: int, shape: Tuple[int, ...]) -> P:
    """Map a param path + rank/shape to a PartitionSpec (layer stacks add a
    leading unsharded axis, handled by rank arithmetic)."""
    n = set(names)
    lead = (None,) * (ndim - 2)  # layer-stack axes (and expert handled below)

    # --- MoE expert weights: (L, E, d, f) / (L, E, f, d) -----------------
    # Expert parallelism (expert axis over 'data') only when the expert count
    # divides the data axis; small-expert cards (Mixtral: 8) fall back to
    # pure tensor parallelism over the expert FFN dims.
    if "w_gate" in n or "w_up" in n or "w_down" in n:
        e_axis = ndim - 3
        experts = shape[e_axis]
        if experts % DATA_SIZE == 0:
            # expert parallelism: experts over 'data', inner dim over 'model'
            if "w_down" in n:
                return P(*((None,) * e_axis), "data", "model", None)
            return P(*((None,) * e_axis), "data", None, "model")
        # few-expert cards (Mixtral: 8 < 16): replicate experts but shard BOTH
        # matrix dims so the weights still split 256 ways
        if "w_down" in n:
            return P(*((None,) * e_axis), None, "model", "data")
        return P(*((None,) * e_axis), None, "data", "model")
    if "shared_gate" in n or "shared_up" in n:
        return P(*((None,) * (ndim - 3)), None, None, "model")
    if "shared_down" in n:
        return P(*((None,) * (ndim - 3)), None, "model", None)
    if "router" in n:
        return P(*((None,) * ndim))

    # --- embeddings ------------------------------------------------------
    if "table" in n:  # (V, d)
        return P("model", None)
    if "pos_emb" in n:
        return P(*((None,) * ndim))

    # --- attention -------------------------------------------------------
    if n & {"wq", "wk", "wv"}:
        if names[-1] == "b":
            return P(*((None,) * (ndim - 1)), "model")
        return P(*lead, None, "model")
    if "wo" in n:
        if names[-1] == "b":
            return P(*((None,) * ndim))
        return P(*lead, "model", None)
    if "unembed" in n:
        if names[-1] == "b":
            return P(*((None,) * (ndim - 1)), "model")
        return P(*lead, None, "model")  # (d, V): vocab over model

    # --- dense MLP ---------------------------------------------------------
    if n & {"up", "gate"}:
        if names[-1] == "b":
            return P(*((None,) * (ndim - 1)), "model")
        return P(*lead, None, "model")
    if "down" in n:
        if names[-1] == "b":
            return P(*((None,) * ndim))
        return P(*lead, "model", None)

    # --- SSM ---------------------------------------------------------------
    if "in_proj" in n:
        return P(*lead, None, "model")
    if "out_proj" in n:
        return P(*lead, "model", None)
    if "conv_w" in n:
        return P(*((None,) * (ndim - 1)), "model")
    if "conv_b" in n or "norm_scale" in n:
        return P(*((None,) * (ndim - 1)), "model")
    if n & {"A_log", "D", "dt_bias"}:
        return P(*((None,) * (ndim - 1)), "model")

    # --- frontend stubs / norms / everything else: replicated --------------
    return P(*((None,) * ndim))


def param_pspecs(params: Any, *, layout: str = "tp") -> Any:
    """Pytree of PartitionSpecs matching ``params``.

    layout="tp" (default): tensor/expert parallel rules above.
    layout="dp": fully replicated parameters — correct for small cards
    (< ~2B params) where per-layer TP activation all-reduces dwarf the one
    gradient all-reduce of pure data parallelism (§Perf iteration 4)."""
    if layout == "dp":
        return jax.tree.map(lambda x: P(*((None,) * jnp.ndim(x))), params)
    return jax.tree_util.tree_map_with_path(
        lambda path, x: _rule(_names(path), jnp.ndim(x), tuple(x.shape)), params
    )


def state_pspecs(state: Any, *, layout: str = "tp") -> Any:
    """TrainState(params, AdamWState(step, mu, nu)) → same-shaped spec tree."""
    from repro.optim.adamw import AdamWState
    from repro.train.step import TrainState

    pspec = param_pspecs(state.params, layout=layout)
    return TrainState(
        params=pspec,
        opt=AdamWState(
            step=P(),
            mu=param_pspecs(state.opt.mu, layout=layout),
            nu=param_pspecs(state.opt.nu, layout=layout),
        ),
    )


def batch_pspec(multi_pod: bool, *, layout: str = "tp") -> P:
    if layout == "dp":  # batch over every mesh axis
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return P(axes, None)
    return P(("pod", "data") if multi_pod else "data", None)


def _kv_cache_spec(kv_heads: int, batch: int, model_size: int, batch_axes) -> dict:
    """(R, B, T, KV, Dh) cache spec: prefer heads over 'model', fall back to
    sequence sharding when KV heads don't divide; batch over data axes when
    batch > 1, else sequence also takes the data axes (long-context decode)."""
    if batch > 1:
        if kv_heads % model_size == 0:
            kv = P(None, batch_axes, None, "model", None)
        else:
            kv = P(None, batch_axes, "model", None, None)
    else:
        if kv_heads % model_size == 0:
            kv = P(None, None, batch_axes, "model", None)
        else:
            axes = (batch_axes, "model") if not isinstance(batch_axes, tuple) else (*batch_axes, "model")
            kv = P(None, None, axes, None, None)
    return {"k": kv, "v": kv}


def cache_pspecs(cache: Any, cfg, batch: int, *, multi_pod: bool) -> Any:
    """Spec tree matching ``repro.models.model.init_cache`` output."""
    model_size = 16
    batch_axes = ("pod", "data") if multi_pod else "data"

    def per_layer_cache(c: dict) -> dict:
        out = {}
        if "kv" in c:
            out["kv"] = _kv_cache_spec(cfg.num_kv_heads, batch, model_size, batch_axes)
        if "ssm" in c:
            h = cfg.ssm.num_heads(cfg.d_model)
            state = (
                P(None, batch_axes, "model", None, None)
                if batch > 1 and h % model_size == 0
                else (
                    P(None, None, "model", None, None)
                    if h % model_size == 0
                    else P(None, batch_axes if batch > 1 else None, None, None, None)
                )
            )
            conv = P(None, batch_axes if batch > 1 else None, None, "model")
            out["ssm"] = {"state": state, "conv": conv}
        if "cross_kv" in c:
            kvh = cfg.num_kv_heads
            spec = (
                P(None, batch_axes if batch > 1 else None, None, "model", None)
                if kvh % model_size == 0
                else P(None, batch_axes if batch > 1 else None, None, None, None)
            )
            out["cross_kv"] = {"k": spec, "v": spec}
        return out

    return {"layers": [per_layer_cache(c) for c in cache["layers"]]}
