"""Batched serving engine: slot-based continuous batching over the decode step.

A fixed pool of ``max_batch`` slots shares one KV cache; requests are admitted
into free slots (prefill writes that slot's cache rows), and one fused
``decode_step`` advances every active slot per tick. Finished slots are
recycled without disturbing the others — the standard continuous-batching
pattern (vLLM-style, static-shape TPU variant with per-slot position masks).

Positions are tracked per slot; the decode attention mask uses each slot's
own length (ragged batches decode correctly because cache rows beyond a
slot's length are masked by its position).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_cache, prefill


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = field(default_factory=time.time)
    finished_at: Optional[float] = None


class ServingEngine:
    def __init__(self, params, cfg, *, max_batch: int = 4, max_len: int = 512,
                 seed: int = 0):
        if cfg.encoder_layers:
            raise NotImplementedError(
                "continuous batching engine supports decoder-only archs; "
                "use launch/serve.py for enc-dec (whisper)"
            )
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len + cfg.num_patches
        self.cache = init_cache(cfg, max_batch, self.max_len)
        self.lengths = np.zeros(max_batch, np.int32)   # tokens in each slot
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._key = jax.random.PRNGKey(seed)
        self._next_rid = 0

        # one-slot prefill: pad batch dim by running a single-row cache merge
        def _prefill_one(params, tokens, cache_slice):
            return prefill(params, cfg, tokens, cache_slice)

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(
            lambda p, tok, c, positions: self._decode_masked(p, tok, c, positions)
        )

    # --- decode with PER-SLOT positions -----------------------------------
    def _decode_masked(self, params, tok, cache, positions):
        # positions: (B,) current length per slot. decode_step uses one scalar
        # cache_pos; we call it with the max and rely on per-slot rope via the
        # scalar — for exactness with ragged slots we decode each slot at its
        # own position using vmap over single-slot views.
        def one(p, t, c, pos):
            t = t[None]  # (1, 1)
            c = jax.tree.map(lambda x: x[:, None], c)  # restore the batch dim
            logits, new_c = decode_step(p, self.cfg, t, c, pos)
            return logits[0], jax.tree.map(lambda x: x[:, 0], new_c)

        # vmap over the slot axis (dim 1 of the layer-stacked caches)
        cache_axes = jax.tree.map(lambda _: 1, cache)
        return jax.vmap(one, in_axes=(None, 0, cache_axes, 0), out_axes=(0, cache_axes))(
            params, tok, cache, positions
        )

    # --- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, temperature))
        return rid

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            p = len(req.prompt)
            tokens = jnp.asarray(req.prompt[None, :])
            # prefill into this slot: run a batch-1 prefill on a slot view
            slot_cache = jax.tree.map(lambda x: x[:, slot : slot + 1], self.cache)
            logits, new_slot = self._prefill(self.params, tokens, slot_cache)
            self.cache = jax.tree.map(
                lambda full, piece: jax.lax.dynamic_update_slice_in_dim(
                    full, piece, slot, axis=1
                ),
                self.cache,
                new_slot,
            )
            first = int(jnp.argmax(logits[0, -1]))
            req.generated.append(first)
            self.slot_req[slot] = req
            self.lengths[slot] = p + self.cfg.num_patches
            self.last_token[slot, 0] = first

    def _retire(self) -> None:
        for slot, req in enumerate(self.slot_req):
            if req is not None and len(req.generated) >= req.max_new_tokens:
                req.done = True
                req.finished_at = time.time()
                self.finished.append(req)
                self.slot_req[slot] = None
                self.lengths[slot] = 0

    def step(self) -> int:
        """One engine tick: admit, decode all active slots, retire. Returns
        the number of active slots decoded."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits, new_cache = self._decode(
            self.params,
            jnp.asarray(self.last_token),
            self.cache,
            jnp.asarray(self.lengths),
        )
        self.cache = new_cache
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.lengths[slot] += 1
            self.last_token[slot, 0] = tok
        self._retire()
        return len(active)

    def run_until_drained(self, *, max_ticks: int = 1000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished
