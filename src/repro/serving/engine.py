"""Batched serving engine: slot-based continuous batching over the decode step.

A fixed pool of ``max_batch`` slots shares one KV cache; requests are admitted
into free slots (prefill writes that slot's cache rows), and one fused
``decode_step`` advances every active slot per tick. Finished slots are
recycled without disturbing the others — the standard continuous-batching
pattern (vLLM-style, static-shape TPU variant with per-slot position masks).

Positions are tracked per slot; the decode attention mask uses each slot's
own length (ragged batches decode correctly because cache rows beyond a
slot's length are masked by its position).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import decode_step, init_cache, prefill


# ---------------------------------------------------------------------------
# KGE candidate ranking service
# ---------------------------------------------------------------------------
class KGECandidateRanker:
    """Serving-side link-prediction: filtered ranks and streaming top-k
    candidates over a trained KGE model.

    Ranking goes through the streaming fused-rank engine
    (``kernels.triple_score.fused_ranks``), candidate retrieval through a
    blockwise ``lax.scan`` top-k merge — in both cases the (B, E) score
    matrix never materializes, so a ranker over a 10⁶-entity table serves
    from O(B·block_e) working memory per step.

    Per-request host work is O(B): the known-true filter is packed once at
    construction into a padded CSR ``FilterPack`` (pow-2 width, so the jits
    see one filter shape) and sliced per batch, and non-finite-row
    validation is a bitmask lookup against the active ``TableVersion``
    (computed once at publish) instead of pulling embedding rows per call.
    ``swap()`` hot-swaps to a newly published version between requests —
    the filter pack carries over (known triples outlive table versions).
    """

    def __init__(self, params, model, known_triples=None, *, block_e: int = 2048,
                 impl: Optional[str] = None, filters=None):
        from repro.serving.tables import FilterPack, TableVersion

        self.model = model
        self.block_e = block_e
        self.impl = impl
        self.filters = (
            filters if filters is not None
            else FilterPack(known_triples, model.num_entities)
        )
        self._hr_t, self._rt_h = self.filters.hr_t, self.filters.rt_h
        self._tv = TableVersion(params, model, self.filters, version=0)

    @property
    def params(self):
        return self._tv.params

    @property
    def version(self) -> int:
        return self._tv.version

    def swap(self, params, *, version: Optional[int] = None):
        """Atomically switch to a new table version (a fresh published
        params snapshot). Requests issued after this serve the new tables;
        the filter pack and compiled programs are reused as-is."""
        from repro.serving.tables import TableVersion

        v = self._tv.version + 1 if version is None else int(version)
        self._tv = TableVersion(
            params, self.model, self.filters, version=v, owner=self._tv.owner
        )
        return self._tv

    # ---- request validation ----------------------------------------------
    def _check_ids(self, name: str, ids: np.ndarray, limit: int) -> np.ndarray:
        from repro.serving.tables import check_id_range

        return check_id_range(name, ids, limit)

    def _check_query(self, h: np.ndarray, r: np.ndarray) -> None:
        """A NaN/Inf row in the tables poisons every rank it touches (it
        compares incomparably against the whole entity table), so a query
        that would serve from one is refused up front with the id named.
        O(B) per request: the per-row verdict was precomputed at publish."""
        self._tv.check_finite("entity", self._tv.ent_bad, h)
        self._tv.check_finite("relation", self._tv.rel_bad, r)

    # ---- filtered ranking ------------------------------------------------
    def rank_filter(self, h: np.ndarray, r: np.ndarray, t: np.ndarray) -> np.ndarray:
        """(B, width+1) int32 filter for rank queries: the gold tail in
        column 0 (duplicates in the known row are harmless — the in-kernel
        exclusion is a membership test) plus the precomputed CSR row slice."""
        return np.concatenate(
            [np.asarray(t, np.int32)[:, None], self.filters.rows_for(h, r)],
            axis=1,
        )

    def rank_tails(self, h, r, t) -> np.ndarray:
        """Filtered rank of each gold tail t among all entities — (B,) int."""
        from repro.kge.eval import streaming_side_counts

        h = self._check_ids("head entity", h, self.model.num_entities)
        t = self._check_ids("tail entity", t, self.model.num_entities)
        r = self._check_ids("relation", r, self.model.num_relations)
        self._check_query(h, r)
        chunk = np.stack([h, r, t], axis=1)
        counts = streaming_side_counts(
            self.params, self.model, chunk, self.rank_filter(h, r, t),
            side="tail", block_e=self.block_e, impl=self.impl,
        )
        return counts + 1

    # ---- streaming top-k candidates --------------------------------------
    def topk_tails(self, h, r, k: int = 10, *, exclude_known: bool = True):
        """Top-k candidate tails for (h, r, ·) queries → (ids, scores), each
        (B, k). Streams the entity table blockwise with a carried top-k."""
        from repro.kge.models import lp_query_tails

        h_np = self._check_ids("head entity", h, self.model.num_entities)
        r_np = self._check_ids("relation", r, self.model.num_relations)
        self._check_query(h_np, r_np)
        h = jnp.asarray(h_np)
        r = jnp.asarray(r_np)
        b = h.shape[0]
        if exclude_known:
            filt = self.filters.rows_for(h_np, r_np)
        else:
            filt = np.full((b, 1), -1, np.int32)

        qd = lp_query_tails(self.params, self.model, h, r)
        if qd is not None:
            q, table, mode = qd
            vals, ids = _streaming_topk_decomposed(
                q, table, jnp.asarray(filt), k=k, block_e=self.block_e, mode=mode
            )
        else:
            vals, ids = _streaming_topk_generic(
                self.params, self.model, h, r, jnp.asarray(filt),
                k=k, block_e=self.block_e,
            )
        return np.asarray(ids), np.asarray(vals)


def _topk_scan(score_block, b, e, filt, *, k, block_e):
    """Shared blockwise top-k merge: carry (vals, ids), fold in one entity
    block per step. ``score_block(ids_block) → (B, Be)`` scores."""
    be = min(block_e, e)
    n_blocks = -(-e // be)
    cols = jnp.arange(n_blocks * be, dtype=jnp.int32).reshape(n_blocks, be)

    def step(carry, cb):
        vals, ids = carry  # (B, k)
        s = score_block(cb)  # (B, Be)
        excl = jnp.any(filt[:, :, None] == cb[None, None, :], axis=1)
        s = jnp.where(excl | (cb >= e)[None, :], -jnp.inf, s)
        allv = jnp.concatenate([vals, s], axis=1)
        alli = jnp.concatenate([ids, jnp.tile(cb[None], (vals.shape[0], 1))], 1)
        nv, sel = jax.lax.top_k(allv, vals.shape[1])
        ni = jnp.take_along_axis(alli, sel, axis=1)
        return (nv, ni), None

    init = (
        jnp.full((b, min(k, e)), -jnp.inf, jnp.float32),
        jnp.full((b, min(k, e)), -1, jnp.int32),
    )
    (vals, ids), _ = jax.lax.scan(step, init, cols)
    return vals, ids


@functools.partial(jax.jit, static_argnames=("k", "block_e", "mode"))
def _streaming_topk_decomposed(q, table, filt, *, k, block_e, mode):
    from repro.kernels.triple_score.triple_score import _tile_scores

    e = table.shape[0]

    def score_block(cb):
        eb = table[jnp.clip(cb, 0, e - 1)]
        return _tile_scores(q.astype(jnp.float32), eb.astype(jnp.float32), mode)

    return _topk_scan(score_block, q.shape[0], e, filt, k=k, block_e=block_e)


@functools.partial(jax.jit, static_argnames=("model", "k", "block_e"))
def _streaming_topk_generic(params, model, h, r, filt, *, k, block_e):
    from repro.kge.models import score_triples

    b = h.shape[0]
    e = model.num_entities

    def score_block(cb):
        ids = jnp.clip(cb, 0, e - 1)
        be = ids.shape[0]
        hh = jnp.repeat(h[:, None], be, axis=1).reshape(-1)
        rr = jnp.repeat(r[:, None], be, axis=1).reshape(-1)
        tt = jnp.tile(ids[None], (b, 1)).reshape(-1)
        return score_triples(params, model, hh, rr, tt).reshape(b, be)

    return _topk_scan(score_block, b, e, filt, k=k, block_e=block_e)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # perf_counter: latency math (finished_at - submitted_at) must be
    # monotonic; time.time() jumps with NTP/clock adjustments
    submitted_at: float = field(default_factory=time.perf_counter)
    finished_at: Optional[float] = None


class ServingEngine:
    def __init__(self, params, cfg, *, max_batch: int = 4, max_len: int = 512,
                 seed: int = 0):
        if cfg.encoder_layers:
            raise NotImplementedError(
                "continuous batching engine supports decoder-only archs; "
                "use launch/serve.py for enc-dec (whisper)"
            )
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len + cfg.num_patches
        self.cache = init_cache(cfg, max_batch, self.max_len)
        self.lengths = np.zeros(max_batch, np.int32)   # tokens in each slot
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._key = jax.random.PRNGKey(seed)
        self._next_rid = 0

        # one-slot prefill: pad batch dim by running a single-row cache merge
        def _prefill_one(params, tokens, cache_slice):
            return prefill(params, cfg, tokens, cache_slice)

        self._prefill = jax.jit(_prefill_one)
        self._decode = jax.jit(
            lambda p, tok, c, positions: self._decode_masked(p, tok, c, positions)
        )

    # --- decode with PER-SLOT positions -----------------------------------
    def _decode_masked(self, params, tok, cache, positions):
        # positions: (B,) current length per slot. decode_step uses one scalar
        # cache_pos; we call it with the max and rely on per-slot rope via the
        # scalar — for exactness with ragged slots we decode each slot at its
        # own position using vmap over single-slot views.
        def one(p, t, c, pos):
            t = t[None]  # (1, 1)
            c = jax.tree.map(lambda x: x[:, None], c)  # restore the batch dim
            logits, new_c = decode_step(p, self.cfg, t, c, pos)
            return logits[0], jax.tree.map(lambda x: x[:, 0], new_c)

        # vmap over the slot axis (dim 1 of the layer-stacked caches)
        cache_axes = jax.tree.map(lambda _: 1, cache)
        return jax.vmap(one, in_axes=(None, 0, cache_axes, 0), out_axes=(0, cache_axes))(
            params, tok, cache, positions
        )

    # --- public API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, *, max_new_tokens: int = 16,
               temperature: float = 0.0) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new_tokens, temperature))
        return rid

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            p = len(req.prompt)
            tokens = jnp.asarray(req.prompt[None, :])
            # prefill into this slot: run a batch-1 prefill on a slot view
            slot_cache = jax.tree.map(lambda x: x[:, slot : slot + 1], self.cache)
            logits, new_slot = self._prefill(self.params, tokens, slot_cache)
            self.cache = jax.tree.map(
                lambda full, piece: jax.lax.dynamic_update_slice_in_dim(
                    full, piece, slot, axis=1
                ),
                self.cache,
                new_slot,
            )
            first = int(jnp.argmax(logits[0, -1]))
            req.generated.append(first)
            self.slot_req[slot] = req
            self.lengths[slot] = p + self.cfg.num_patches
            self.last_token[slot, 0] = first

    def _retire(self) -> None:
        for slot, req in enumerate(self.slot_req):
            if req is not None and len(req.generated) >= req.max_new_tokens:
                req.done = True
                req.finished_at = time.perf_counter()
                self.finished.append(req)
                self.slot_req[slot] = None
                self.lengths[slot] = 0

    def step(self) -> int:
        """One engine tick: admit, decode all active slots, retire. Returns
        the number of active slots decoded."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits, new_cache = self._decode(
            self.params,
            jnp.asarray(self.last_token),
            self.cache,
            jnp.asarray(self.lengths),
        )
        self.cache = new_cache
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.lengths[slot] += 1
            self.last_token[slot, 0] = tok
        self._retire()
        return len(active)

    def run_until_drained(self, *, max_ticks: int = 1000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return self.finished
