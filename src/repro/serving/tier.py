"""Production KGE serving tier: continuous query batching over replicated,
federation-versioned embedding tables.

Three mechanisms, composed:

**Continuous request batching** — ``submit_rank``/``submit_topk`` enqueue
validated requests; ``step()`` coalesces the FIFO head into one query batch
(same kind, same top-k bucket), pads the batch extent to a power-of-two
bucket and slices filters from the precomputed pow-2-width ``FilterPack``,
so steady-state traffic hits a FIXED set of compiled programs — the tick
engine's signature-bucket idiom applied to queries. Batches dispatch
asynchronously (``kge.eval.side_counts_dispatch`` — device out, no host
sync) and results are collected by non-blocking ``jax.Array.is_ready``
polling, so new batches launch while old ones execute.

**Replica routing** — the active ``TableVersion`` is staged onto a ring of
replica devices (``core.distributed.replica_devices``: consecutive mesh
devices from the owner's sticky home, so replica 0 is the device the
federation already keeps the accepted tables resident on). Each batch goes
to the replica with the fewest in-flight batches; per-replica accounting
lives in ``Replica.inflight``/``dispatched``.

**Version hot-swap** — ``publish(params)`` builds an immutable
``TableVersion`` (non-finite bitmask computed once), pre-stages it onto the
replica ring with async ``device_put`` (zero-copy on the device already
holding the committed params), and atomically flips the active pointer
between batches. In-flight batches hold a reference to the version they
were dispatched on and finish there — no traffic pause, no failed
requests. ``attach(scheduler, owner)`` subscribes the tier to the
federation's accept hook so every accepted tick update republishes.
``warm_buckets=`` pre-traces the configured query buckets against the
freshly staged tables on every replica at publish time, so the first
post-swap batch (and the first batch ever) pays no compile: programs
specialize on shape, not version, so each ``(kind, bucket, replica)``
signature warms exactly once per process.

``serve_impl="direct"`` (``REPRO_SERVE_IMPL``) disables coalescing — one
dispatch per request, the baseline ``bench_serving.py`` measures batching
against. ``REPRO_SERVE_REPLICAS`` sizes the replica ring.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.distributed import replica_devices
from repro.kernels.dispatch import resolve_serve_impl, resolve_serve_replicas
from repro.kge.eval import side_counts_dispatch
from repro.kge.models import lp_query_tails
from repro.serving.tables import FilterPack, TableVersion, check_id_range


def _pow2_at_least(n: int, floor: int = 1) -> int:
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


@dataclass
class QueryRequest:
    """One submitted query batch-of-rows; ``result`` lands asynchronously."""

    rid: int
    kind: str                      # "rank" | "topk"
    h: np.ndarray
    r: np.ndarray
    t: Optional[np.ndarray] = None  # rank only
    k: int = 0                      # topk only
    # perf_counter: latency math (finished_at - submitted_at) must be
    # monotonic; time.time() jumps with NTP/clock adjustments
    submitted_at: float = field(default_factory=time.perf_counter)
    finished_at: Optional[float] = None
    version: Optional[int] = None   # table version that served it
    result: object = None
    error: Optional[Exception] = None
    done: bool = False

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class Replica:
    """One device holding the serving tables; load = in-flight batches."""

    def __init__(self, slot: int, device):
        self.slot = slot
        self.device = device
        self.inflight = 0    # currently executing batches
        self.dispatched = 0  # lifetime batch count (routing observability)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Replica({self.slot}, {self.device}, inflight={self.inflight})"


@dataclass
class _InFlight:
    """A dispatched batch: device outputs + how to scatter them back."""

    kind: str
    out: Tuple                      # device arrays
    segs: List[Tuple[QueryRequest, int, int]]  # (request, offset, rows)
    nq: int                         # real (unpadded) query rows
    tv: TableVersion                # version the batch was dispatched on
    replica: Replica

    def ready(self) -> bool:
        return all(x.is_ready() for x in self.out)


class KGEServingTier:
    """Continuously-batched, replicated, hot-swappable KGE query serving.

    The public surface is asynchronous: ``submit_rank(h, r, t)`` /
    ``submit_topk(h, r, k=)`` return a ``QueryRequest`` immediately
    (validation errors raise at submit); ``step()`` advances the admission
    loop one batch; ``run_until_drained()`` pumps until every request is
    done. Results: ``req.result`` is the (B,) rank array, or an
    ``(ids, scores)`` pair for top-k — bit-identical to a per-call
    ``KGECandidateRanker`` on the same table version.
    """

    def __init__(self, params, model, known_triples=None, *, owner: Optional[str] = None,
                 block_e: int = 2048, rank_impl: Optional[str] = None,
                 serve_impl: Optional[str] = None, replicas: Optional[int] = None,
                 home_slot: int = 0, devices=None, max_batch: int = 64,
                 min_bucket: int = 8, max_inflight: Optional[int] = None,
                 filters: Optional[FilterPack] = None,
                 warm_buckets: Optional[List[Tuple]] = None):
        self.model = model
        self.owner = owner
        self.block_e = block_e
        self.rank_impl = rank_impl
        self.serve_impl = resolve_serve_impl(serve_impl)
        self.max_batch = int(max_batch)
        self.min_bucket = int(min_bucket)
        self.filters = (
            filters if filters is not None
            else FilterPack(known_triples, model.num_entities)
        )
        devs = replica_devices(home_slot, resolve_serve_replicas(replicas),
                               devices)
        self.replicas = [Replica(i, d) for i, d in enumerate(devs)]
        #: dispatch-ahead depth: two batches per replica keeps every device
        #: busy while the host assembles the next batch, without unbounded
        #: queue growth on the devices
        self.max_inflight = (
            2 * len(self.replicas) if max_inflight is None else int(max_inflight)
        )
        self.queue: Deque[QueryRequest] = deque()
        self.inflight: Deque[_InFlight] = deque()
        self.stats: Dict[str, int] = {
            "served": 0, "failed": 0, "batches": 0, "published": 0,
            "publish_errors": 0, "padded_rows": 0, "warmed": 0,
        }
        #: bucket specs to pre-trace at publish: ("rank", rows) or
        #: ("topk", rows, k). Rows/k are rounded to the same pow-2 buckets
        #: the admission loop pads to, so a warmed spec covers every real
        #: batch that lands in its bucket.
        self.warm_buckets: List[Tuple] = list(warm_buckets or [])
        for spec in self.warm_buckets:
            if (not spec or spec[0] not in ("rank", "topk")
                    or len(spec) != (2 if spec[0] == "rank" else 3)):
                raise ValueError(
                    f"warm bucket {spec!r}: expected ('rank', rows) or "
                    f"('topk', rows, k)"
                )
        #: (kind, bucket_rows, k_bucket, replica_slot) signatures already
        #: traced — programs specialize on shape not version, so each
        #: signature warms once per process, not once per publish
        self._warmed: set = set()
        self._next_rid = 0
        #: serializes publish() against itself (the federation thread) —
        #: the serving loop only ever READS the active pointer, once per
        #: batch, so the flip is atomic by assignment
        self._publish_lock = threading.Lock()
        self._active: Optional[TableVersion] = None
        self.publish(params, version=0)
        self.stats["published"] = 0  # the constructor's own staging isn't a flip

    # ------------------------------------------------------------ publish
    @property
    def version(self) -> int:
        return self._active.version

    def publish(self, params, *, version: Optional[int] = None) -> TableVersion:
        """Publish a new table version and atomically make it active.

        Builds the immutable ``TableVersion`` (one on-device finiteness
        reduction per table), pre-stages it onto every replica device with
        asynchronous ``device_put`` (zero-copy where the params are already
        committed — the owner's sticky home), then flips the active
        pointer. Batches dispatched before the flip complete on the old
        version; batches dispatched after serve the new one. No pause."""
        with self._publish_lock:
            v = (
                (self._active.version + 1 if self._active is not None else 0)
                if version is None else int(version)
            )
            tv = TableVersion(params, self.model, self.filters,
                              version=v, owner=self.owner)
            for rep in self.replicas:
                tv.on(rep.device)
            self._warm(tv)
            self._active = tv
            self.stats["published"] += 1
            return tv

    def _warm(self, tv: TableVersion) -> None:
        """Pre-trace the configured query buckets against ``tv``'s staged
        tables on every replica, with zero-id dummy queries. Tracing (and
        the compile it triggers) is synchronous, so by the time ``publish``
        flips the active pointer every warmed ``(kind, bucket, replica)``
        program is resident in the jit caches and the first post-swap batch
        dispatches without compiling. Dummy results are dropped on the
        floor — no stats, no inflight accounting."""
        if not self.warm_buckets:
            return
        for rep in self.replicas:
            ptab = tv.on(rep.device)
            for spec in self.warm_buckets:
                kind = spec[0]
                rows = _pow2_at_least(
                    spec[1],
                    self.min_bucket if self.serve_impl == "batched" else 1,
                )
                kb = (
                    min(_pow2_at_least(spec[2]), self.model.num_entities)
                    if kind == "topk" else 0
                )
                sig = (kind, rows, kb, rep.slot)
                if sig in self._warmed:
                    continue
                h = np.zeros(rows, dtype=np.int64)
                r = np.zeros(rows, dtype=np.int64)
                filt = self.filters.rows_for(h, r)
                if kind == "rank":
                    t = np.zeros(rows, dtype=np.int64)
                    filt = np.concatenate(
                        [t[:, None].astype(np.int32), filt], axis=1
                    )
                    dh, dr, dt, df = jax.device_put(
                        (h, r, t, filt), rep.device
                    )
                    side_counts_dispatch(
                        ptab, self.model, dh, dr, dt, df, side="tail",
                        block_e=self.block_e, impl=self.rank_impl,
                    )
                else:
                    from repro.serving.engine import (
                        _streaming_topk_decomposed,
                        _streaming_topk_generic,
                    )

                    dh, dr, df = jax.device_put((h, r, filt), rep.device)
                    qd = lp_query_tails(ptab, self.model, dh, dr)
                    if qd is not None:
                        q, table, mode = qd
                        _streaming_topk_decomposed(
                            q, table, df, k=kb, block_e=self.block_e,
                            mode=mode,
                        )
                    else:
                        _streaming_topk_generic(
                            ptab, self.model, dh, dr, df, k=kb,
                            block_e=self.block_e,
                        )
                self._warmed.add(sig)
                self.stats["warmed"] += 1

    def attach(self, sched, owner: str) -> "KGEServingTier":
        """Subscribe to a ``FederationScheduler``'s accept hook: every
        accepted update for ``owner`` republishes the serving tables (the
        version hot-swap path), starting from the owner's current params.
        Publish failures are counted, never propagated — a serving-side
        problem must not abort a federation tick."""
        if owner not in sched.trainers:
            raise ValueError(f"unknown owner {owner!r}")
        self.owner = owner

        def _on_accept(name, tick, params):
            if name != owner:
                return
            try:
                self.publish(params)
            except Exception:
                self.stats["publish_errors"] += 1

        sched.add_accept_listener(_on_accept)
        self.publish(dict(sched.trainers[owner].params))
        return self

    @classmethod
    def for_owner(cls, sched, owner: str, **kw) -> "KGEServingTier":
        """A tier serving ``owner``'s tables out of a federation: filters
        from the owner's full triple set (train ∪ valid ∪ test — the
        standard Filter-mode universe), tables from the owner's trainer,
        home slot from the scheduler's sticky placement when the batched
        tick engine has one, and the accept hook attached."""
        tr = sched.trainers[owner]
        kg = sched.kgs[owner]
        known = np.concatenate([kg.train, kg.valid, kg.test])
        engine = getattr(sched, "_tick_engine", None)
        if engine is not None and "home_slot" not in kw:
            kw["home_slot"] = engine.placement.slot(owner)
        tier = cls(tr.params, tr.model, known, owner=owner, **kw)
        tier.attach(sched, owner)
        return tier

    # ------------------------------------------------------------- submit
    def _submit(self, req: QueryRequest) -> QueryRequest:
        self.queue.append(req)
        return req

    def submit_rank(self, h, r, t) -> QueryRequest:
        """Queue a filtered-rank query batch; returns immediately."""
        tv = self._active
        h = check_id_range("head entity", h, self.model.num_entities)
        t = check_id_range("tail entity", t, self.model.num_entities)
        r = check_id_range("relation", r, self.model.num_relations)
        tv.check_finite("entity", tv.ent_bad, h)
        tv.check_finite("relation", tv.rel_bad, r)
        rid = self._next_rid
        self._next_rid += 1
        return self._submit(QueryRequest(rid, "rank", h, r, t))

    def submit_topk(self, h, r, *, k: int = 10) -> QueryRequest:
        """Queue a top-k candidate query batch; returns immediately."""
        tv = self._active
        h = check_id_range("head entity", h, self.model.num_entities)
        r = check_id_range("relation", r, self.model.num_relations)
        if not 1 <= k <= self.model.num_entities:
            raise ValueError(
                f"k must be in [1, {self.model.num_entities}], got {k}"
            )
        tv.check_finite("entity", tv.ent_bad, h)
        tv.check_finite("relation", tv.rel_bad, r)
        rid = self._next_rid
        self._next_rid += 1
        return self._submit(QueryRequest(rid, "topk", h, r, k=int(k)))

    # ------------------------------------------------------ admission loop
    def _coalesce(self) -> List[QueryRequest]:
        """Pop the FIFO head's batchable prefix: same kind (and same top-k
        bucket), up to ``max_batch`` query rows. ``direct`` mode takes one
        request — the per-call baseline."""
        head = self.queue[0]
        take = [self.queue.popleft()]
        if self.serve_impl == "direct":
            return take
        rows = len(head.h)
        kb = _pow2_at_least(head.k) if head.kind == "topk" else 0
        while self.queue and rows < self.max_batch:
            nxt = self.queue[0]
            if nxt.kind != head.kind:
                break
            if head.kind == "topk" and _pow2_at_least(nxt.k) != kb:
                break
            if rows + len(nxt.h) > self.max_batch:
                break
            take.append(self.queue.popleft())
            rows += len(nxt.h)
        return take

    def _pad(self, arrs: List[np.ndarray], nq: int) -> List[np.ndarray]:
        """Pad batch extent to a pow-2 bucket by repeating row 0 — padded
        rows compute (and are discarded), keeping the compiled-program set
        fixed across every traffic mix."""
        nb = _pow2_at_least(nq, self.min_bucket if self.serve_impl == "batched"
                            else 1)
        if nb == nq:
            return arrs
        self.stats["padded_rows"] += nb - nq
        return [
            np.concatenate([a, np.repeat(a[:1], nb - nq, axis=0)], axis=0)
            for a in arrs
        ]

    def _pick_replica(self) -> Replica:
        return min(self.replicas, key=lambda rp: (rp.inflight, rp.slot))

    def _dispatch(self, reqs: List[QueryRequest]) -> None:
        tv = self._active  # ONE read: the batch is pinned to this version
        kind = reqs[0].kind
        h = np.concatenate([q.h for q in reqs])
        r = np.concatenate([q.r for q in reqs])
        nq = len(h)
        segs, off = [], 0
        for q in reqs:
            segs.append((q, off, len(q.h)))
            off += len(q.h)
        rep = self._pick_replica()
        ptab = tv.on(rep.device)
        if kind == "rank":
            t = np.concatenate([q.t for q in reqs])
            filt = np.concatenate(
                [t[:, None].astype(np.int32), self.filters.rows_for(h, r)],
                axis=1,
            )
            h, r, t, filt = self._pad([h, r, t, filt], nq)
            dh, dr, dt, df = jax.device_put((h, r, t, filt), rep.device)
            counts = side_counts_dispatch(
                ptab, self.model, dh, dr, dt, df, side="tail",
                block_e=self.block_e, impl=self.rank_impl,
            )
            out: Tuple = (counts,)
        else:
            from repro.serving.engine import (
                _streaming_topk_decomposed,
                _streaming_topk_generic,
            )

            kb = min(_pow2_at_least(reqs[0].k), self.model.num_entities)
            filt = self.filters.rows_for(h, r)
            h, r, filt = self._pad([h, r, filt], nq)
            dh, dr, df = jax.device_put((h, r, filt), rep.device)
            qd = lp_query_tails(ptab, self.model, dh, dr)
            if qd is not None:
                q, table, mode = qd
                vals, ids = _streaming_topk_decomposed(
                    q, table, df, k=kb, block_e=self.block_e, mode=mode
                )
            else:
                vals, ids = _streaming_topk_generic(
                    ptab, self.model, dh, dr, df, k=kb, block_e=self.block_e
                )
            out = (vals, ids)
        rep.inflight += 1
        rep.dispatched += 1
        self.stats["batches"] += 1
        self.inflight.append(_InFlight(kind, out, segs, nq, tv, rep))

    # ------------------------------------------------------------- collect
    def _finish_batch(self, b: _InFlight) -> None:
        b.replica.inflight -= 1
        try:
            host = [np.asarray(x) for x in b.out]
        except Exception as ex:  # device-side failure: isolate to this batch
            now = time.perf_counter()
            for q, _, _ in b.segs:
                q.error, q.done, q.finished_at = ex, True, now
            self.stats["failed"] += len(b.segs)
            return
        now = time.perf_counter()
        for q, off, n in b.segs:
            if b.kind == "rank":
                q.result = host[0][off:off + n] + 1
            else:
                vals, ids = host
                q.result = (ids[off:off + n, :q.k], vals[off:off + n, :q.k])
            q.version = b.tv.version
            q.finished_at = now
            q.done = True
        self.stats["served"] += len(b.segs)

    def _reap(self, *, block: bool = False) -> int:
        """Collect completed batches; with ``block`` wait for the oldest
        (the admission loop calls this when the dispatch-ahead window is
        full), then keep draining whatever else already finished."""
        done = 0
        while self.inflight:
            if not block and not self.inflight[0].ready():
                break
            block = False
            b = self.inflight.popleft()
            self._finish_batch(b)
            done += len(b.segs)
        return done

    # -------------------------------------------------------- driving loop
    def step(self) -> int:
        """One admission-loop tick: collect finished batches, then dispatch
        (at most) one coalesced batch. Returns the query rows dispatched."""
        self._reap()
        if not self.queue:
            return 0
        while len(self.inflight) >= self.max_inflight:
            self._reap(block=True)
        reqs = self._coalesce()
        nq = sum(len(q.h) for q in reqs)
        self._dispatch(reqs)
        return nq

    def run_until_drained(self, *, max_steps: int = 1_000_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.inflight:
                return
            if self.queue:
                self.step()
            else:
                self._reap(block=True)
        raise RuntimeError("serving tier failed to drain")

    # ------------------------------------------------------- observability
    def replica_load(self) -> List[Tuple[int, int]]:
        """[(slot, lifetime batches)] — the routing spread."""
        return [(rp.slot, rp.dispatched) for rp in self.replicas]


def serving_program_cache_size() -> int:
    """Number of compiled serving-program specializations (rank counts +
    both top-k variants). The retrace-pin test asserts this stays flat
    across steady-state traffic of ANY mix of batch sizes within the
    bucket set — continuous batching is only a win if padded buckets
    actually stop recompilation."""
    from repro.kge.eval import _side_counts_jit
    from repro.serving.engine import (
        _streaming_topk_decomposed,
        _streaming_topk_generic,
    )

    return sum(
        p._cache_size()
        for p in (_side_counts_jit, _streaming_topk_decomposed,
                  _streaming_topk_generic)
    )
