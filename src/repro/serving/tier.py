"""Production KGE serving tier: continuous query batching over replicated,
federation-versioned embedding tables.

Four mechanisms, composed:

**Continuous request batching** — ``submit_rank``/``submit_topk`` enqueue
validated requests; ``step()`` coalesces the FIFO head into one query batch
(same kind, same top-k bucket), pads the batch extent to a power-of-two
bucket and slices filters from the precomputed pow-2-width ``FilterPack``,
so steady-state traffic hits a FIXED set of compiled programs — the tick
engine's signature-bucket idiom applied to queries. Batches dispatch
asynchronously (``kge.eval.side_counts_dispatch`` — device out, no host
sync) and results are collected by non-blocking ``jax.Array.is_ready``
polling, so new batches launch while old ones execute.

**Health-aware replica routing** — the active ``TableVersion`` is staged
onto a ring of replica devices (``core.distributed.replica_devices``:
consecutive mesh devices from the owner's sticky home, so replica 0 is the
device the federation already keeps the accepted tables resident on). Each
batch routes to the healthy replica with the fewest in-flight batches,
tie-broken by lifetime dispatch count (so equal-load traffic spreads
instead of piling onto the lowest slot); per-replica accounting lives in
``Replica.inflight``/``dispatched``/``ewma_s``. A batch whose collection
fails (device error, injected crash, poisoned output) does NOT fail its
requests: it re-dispatches up to ``retry_limit`` times to a different
replica, on the SAME pinned ``TableVersion`` — a retried batch is
bit-identical to one that succeeded first try. ``breaker_fails``
consecutive failures open a circuit breaker: the replica leaves the
routing pool and is re-admitted by timed probe (one trial batch every
``probe_after`` tier-wide dispatches — the serving mirror of the
federation's quarantine-with-timed-release). With ``hedge_after=`` set,
the oldest stuck batch is hedged to a second replica; the first result
wins, bit-identical either way since both replicas hold the same
``TableVersion``.

**Admission control and shedding** — ``max_queue=`` bounds the submit
queue with an explicit ``TierOverloadError`` reject at submit; a
per-request ``deadline=`` (seconds of queue budget) sheds expired requests
at coalesce time into a terminal ``shed`` state distinct from ``failed``.
Every submitted request deterministically resolves to exactly one of
served / shed / failed — ``run_until_drained`` asserts
``served + shed + failed == submitted`` at every drain point.

**Version hot-swap** — ``publish(params)`` builds an immutable
``TableVersion`` (non-finite bitmask computed once), pre-stages it onto the
replica ring with async ``device_put`` (zero-copy on the device already
holding the committed params), and atomically flips the active pointer
between batches. In-flight batches hold a reference to the version they
were dispatched on and finish (and retry) there — no traffic pause, no
failed requests. Because a hot-swap can land between submit-time
validation and dispatch, ``_dispatch`` re-checks every request against the
non-finite bitmask of the version the batch is actually pinned to.
``attach(scheduler, owner)`` subscribes the tier to the federation's
accept hook so every accepted tick update republishes. ``warm_buckets=``
pre-traces the configured query buckets against the freshly staged tables
on every replica at publish time, so the first post-swap batch (and the
first batch ever) pays no compile: programs specialize on shape, not
version, so each ``(kind, bucket, replica)`` signature warms exactly once
per process.

``serve_impl="direct"`` (``REPRO_SERVE_IMPL``) disables coalescing — one
dispatch per request, the baseline ``bench_serving.py`` measures batching
against. ``REPRO_SERVE_REPLICAS`` sizes the replica ring.
``serve_faults=`` / ``REPRO_SERVE_FAULTS`` arm the seeded chaos layer
(``core.faults.ServeFaultPlan``) — off by default, keeping the query fast
path bit-identical to the faults-free tier.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.distributed import replica_devices
from repro.core.faults import ServeFault, ServeFaultError, ServeFaultPlan
from repro.kernels.dispatch import (
    resolve_serve_faults,
    resolve_serve_impl,
    resolve_serve_replicas,
)
from repro.kge.eval import side_counts_dispatch
from repro.kge.models import lp_query_tails
from repro.serving.tables import FilterPack, TableVersion, check_id_range


def _pow2_at_least(n: int, floor: int = 1) -> int:
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


class TierOverloadError(RuntimeError):
    """Submit-time admission reject: the tier's queue is at ``max_queue``.
    Raised BEFORE the request enters the system — rejected requests are
    counted in ``stats["rejected"]`` and never become ``QueryRequest``s,
    so they do not participate in the served/shed/failed accounting."""


@dataclass
class QueryRequest:
    """One submitted query batch-of-rows; ``result`` lands asynchronously."""

    rid: int
    kind: str                      # "rank" | "topk"
    h: np.ndarray
    r: np.ndarray
    t: Optional[np.ndarray] = None  # rank only
    k: int = 0                      # topk only
    #: seconds of queue budget from submit; expired requests are shed at
    #: coalesce time (never dispatched). ``None`` = wait forever.
    deadline: Optional[float] = None
    # perf_counter: latency math (finished_at - submitted_at) must be
    # monotonic; time.time() jumps with NTP/clock adjustments
    submitted_at: float = field(default_factory=time.perf_counter)
    finished_at: Optional[float] = None
    version: Optional[int] = None   # table version that served it
    result: object = None
    error: Optional[Exception] = None
    shed: bool = False
    done: bool = False

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def state(self) -> str:
        """``pending`` | ``served`` | ``shed`` | ``failed`` — every request
        terminates in exactly one of the last three."""
        if not self.done:
            return "pending"
        if self.shed:
            return "shed"
        return "failed" if self.error is not None else "served"


class Replica:
    """One device holding the serving tables; load = in-flight batches.

    Health state drives the circuit breaker: ``fails`` counts CONSECUTIVE
    batch failures (any success resets it), ``healthy=False`` removes the
    replica from the routing pool, and ``probe_at`` is the tier-wide launch
    sequence number at which it earns one probe batch (re-admission on
    probe success — the federation quarantine's timed release, with the
    launch counter as the clock so tests are scheduling-deterministic).
    ``ewma_s`` tracks smoothed batch latency for observability and hedging
    diagnostics."""

    def __init__(self, slot: int, device):
        self.slot = slot
        self.device = device
        self.inflight = 0    # currently executing batches
        self.dispatched = 0  # lifetime batch count (routing observability)
        self.fails = 0       # consecutive failures (breaker input)
        self.healthy = True
        self.probe_at: Optional[int] = None  # launch seq of next probe
        self.ewma_s: Optional[float] = None  # smoothed batch latency

    def __repr__(self):  # pragma: no cover - debugging aid
        return (
            f"Replica({self.slot}, {self.device}, inflight={self.inflight}, "
            f"{'healthy' if self.healthy else 'UNHEALTHY'})"
        )


@dataclass
class _InFlight:
    """A dispatched batch: device outputs + how to scatter them back."""

    kind: str
    out: Tuple                      # device arrays
    segs: List[Tuple[QueryRequest, int, int]]  # (request, offset, rows)
    nq: int                         # real (unpadded) query rows
    tv: TableVersion                # version the batch was dispatched on
    replica: Replica
    host_in: Tuple = ()             # padded host arrays (retry/hedge re-launch)
    kb: int = 0                     # topk k bucket
    seq: int = 0                    # tier-wide launch sequence number
    attempts: int = 0               # re-dispatches already consumed
    fault: Optional[ServeFault] = None
    dispatched_at: float = 0.0
    hedge: Optional["_InFlight"] = None

    def ready(self) -> bool:
        # an injected straggle suppresses readiness for its simulated delay
        # (the device results exist — polling just pretends they don't)
        if (self.fault is not None and self.fault.kind == "straggle"
                and time.perf_counter() - self.dispatched_at
                < self.fault.delay):
            return False
        return all(x.is_ready() for x in self.out)


class KGEServingTier:
    """Continuously-batched, replicated, hot-swappable KGE query serving.

    The public surface is asynchronous: ``submit_rank(h, r, t)`` /
    ``submit_topk(h, r, k=)`` return a ``QueryRequest`` immediately
    (validation errors raise at submit; ``TierOverloadError`` rejects at
    ``max_queue``); ``step()`` advances the admission loop one batch;
    ``run_until_drained()`` pumps until every request is done. Results:
    ``req.result`` is the (B,) rank array, or an ``(ids, scores)`` pair for
    top-k — bit-identical to a per-call ``KGECandidateRanker`` on the same
    table version, regardless of retries or hedging.
    """

    def __init__(self, params, model, known_triples=None, *, owner: Optional[str] = None,
                 block_e: int = 2048, rank_impl: Optional[str] = None,
                 serve_impl: Optional[str] = None, replicas: Optional[int] = None,
                 home_slot: int = 0, devices=None, max_batch: int = 64,
                 min_bucket: int = 8, max_inflight: Optional[int] = None,
                 filters: Optional[FilterPack] = None,
                 warm_buckets: Optional[List[Tuple]] = None,
                 serve_faults=None, retry_limit: int = 1,
                 breaker_fails: int = 3, probe_after: int = 8,
                 hedge_after: Optional[float] = None,
                 max_queue: Optional[int] = None):
        self.model = model
        self.owner = owner
        self.block_e = block_e
        self.rank_impl = rank_impl
        self.serve_impl = resolve_serve_impl(serve_impl)
        self.max_batch = int(max_batch)
        self.min_bucket = int(min_bucket)
        self.filters = (
            filters if filters is not None
            else FilterPack(known_triples, model.num_entities)
        )
        devs = replica_devices(home_slot, resolve_serve_replicas(replicas),
                               devices)
        self.replicas = [Replica(i, d) for i, d in enumerate(devs)]
        #: dispatch-ahead depth: two batches per replica keeps every device
        #: busy while the host assembles the next batch, without unbounded
        #: queue growth on the devices
        self.max_inflight = (
            2 * len(self.replicas) if max_inflight is None else int(max_inflight)
        )
        #: resilience knobs — all inert on the failure-free fast path
        plan = resolve_serve_faults(serve_faults)
        if isinstance(plan, str):
            plan = ServeFaultPlan.parse(plan)
        self.fault_plan: Optional[ServeFaultPlan] = plan
        self.fault_counts: Dict[str, int] = {}
        self.retry_limit = int(retry_limit)
        self.breaker_fails = int(breaker_fails)
        self.probe_after = int(probe_after)
        self.hedge_after = hedge_after
        self.max_queue = None if max_queue is None else int(max_queue)
        self.queue: Deque[QueryRequest] = deque()
        self.inflight: Deque[_InFlight] = deque()
        #: hedge/primary losers still executing on device: reaped only to
        #: release their replica's in-flight slot, outputs discarded
        self._zombies: List[_InFlight] = []
        self.stats: Dict[str, int] = {
            "submitted": 0, "served": 0, "failed": 0, "shed": 0,
            "rejected": 0, "retried": 0, "hedged": 0,
            "breaker_open": 0, "breaker_close": 0,
            "batches": 0, "published": 0, "publish_errors": 0,
            "padded_rows": 0, "warmed": 0,
        }
        #: bucket specs to pre-trace at publish: ("rank", rows) or
        #: ("topk", rows, k). Rows/k are rounded to the same pow-2 buckets
        #: the admission loop pads to, so a warmed spec covers every real
        #: batch that lands in its bucket.
        self.warm_buckets: List[Tuple] = list(warm_buckets or [])
        for spec in self.warm_buckets:
            if (not spec or spec[0] not in ("rank", "topk")
                    or len(spec) != (2 if spec[0] == "rank" else 3)):
                raise ValueError(
                    f"warm bucket {spec!r}: expected ('rank', rows) or "
                    f"('topk', rows, k)"
                )
        #: (kind, bucket_rows, k_bucket, replica_slot) signatures already
        #: traced — programs specialize on shape not version, so each
        #: signature warms once per process, not once per publish
        self._warmed: set = set()
        self._next_rid = 0
        #: monotone launch sequence number: one per device dispatch
        #: (primary, retry, or hedge) — the fault plan's draw clock and the
        #: breaker's probe clock
        self._seq = 0
        #: serializes publish() against itself (the federation thread) —
        #: the serving loop only ever READS the active pointer, once per
        #: batch, so the flip is atomic by assignment
        self._publish_lock = threading.Lock()
        self._active: Optional[TableVersion] = None
        self.publish(params, version=0)
        self.stats["published"] = 0  # the constructor's own staging isn't a flip

    # ------------------------------------------------------------ publish
    @property
    def version(self) -> int:
        return self._active.version

    def publish(self, params, *, version: Optional[int] = None) -> TableVersion:
        """Publish a new table version and atomically make it active.

        Builds the immutable ``TableVersion`` (one on-device finiteness
        reduction per table), pre-stages it onto every replica device with
        asynchronous ``device_put`` (zero-copy where the params are already
        committed — the owner's sticky home), then flips the active
        pointer. Batches dispatched before the flip complete on the old
        version; batches dispatched after serve the new one. No pause."""
        with self._publish_lock:
            v = (
                (self._active.version + 1 if self._active is not None else 0)
                if version is None else int(version)
            )
            tv = TableVersion(params, self.model, self.filters,
                              version=v, owner=self.owner)
            for rep in self.replicas:
                tv.on(rep.device)
            self._warm(tv)
            self._active = tv
            self.stats["published"] += 1
            return tv

    def _warm(self, tv: TableVersion) -> None:
        """Pre-trace the configured query buckets against ``tv``'s staged
        tables on every replica, with zero-id dummy queries. Tracing (and
        the compile it triggers) is synchronous, so by the time ``publish``
        flips the active pointer every warmed ``(kind, bucket, replica)``
        program is resident in the jit caches and the first post-swap batch
        dispatches without compiling. Dummy results are dropped on the
        floor — no stats, no inflight accounting."""
        if not self.warm_buckets:
            return
        for rep in self.replicas:
            ptab = tv.on(rep.device)
            for spec in self.warm_buckets:
                kind = spec[0]
                rows = _pow2_at_least(
                    spec[1],
                    self.min_bucket if self.serve_impl == "batched" else 1,
                )
                kb = (
                    min(_pow2_at_least(spec[2]), self.model.num_entities)
                    if kind == "topk" else 0
                )
                sig = (kind, rows, kb, rep.slot)
                if sig in self._warmed:
                    continue
                h = np.zeros(rows, dtype=np.int64)
                r = np.zeros(rows, dtype=np.int64)
                filt = self.filters.rows_for(h, r)
                if kind == "rank":
                    t = np.zeros(rows, dtype=np.int64)
                    filt = np.concatenate(
                        [t[:, None].astype(np.int32), filt], axis=1
                    )
                    dh, dr, dt, df = jax.device_put(
                        (h, r, t, filt), rep.device
                    )
                    side_counts_dispatch(
                        ptab, self.model, dh, dr, dt, df, side="tail",
                        block_e=self.block_e, impl=self.rank_impl,
                    )
                else:
                    from repro.serving.engine import (
                        _streaming_topk_decomposed,
                        _streaming_topk_generic,
                    )

                    dh, dr, df = jax.device_put((h, r, filt), rep.device)
                    qd = lp_query_tails(ptab, self.model, dh, dr)
                    if qd is not None:
                        q, table, mode = qd
                        _streaming_topk_decomposed(
                            q, table, df, k=kb, block_e=self.block_e,
                            mode=mode,
                        )
                    else:
                        _streaming_topk_generic(
                            ptab, self.model, dh, dr, df, k=kb,
                            block_e=self.block_e,
                        )
                self._warmed.add(sig)
                self.stats["warmed"] += 1

    def attach(self, sched, owner: str) -> "KGEServingTier":
        """Subscribe to a ``FederationScheduler``'s accept hook: every
        accepted update for ``owner`` republishes the serving tables (the
        version hot-swap path), starting from the owner's current params.
        Publish failures are counted, never propagated — a serving-side
        problem must not abort a federation tick."""
        if owner not in sched.trainers:
            raise ValueError(f"unknown owner {owner!r}")
        self.owner = owner

        def _on_accept(name, tick, params):
            if name != owner:
                return
            try:
                self.publish(params)
            except Exception:
                self.stats["publish_errors"] += 1

        sched.add_accept_listener(_on_accept)
        self.publish(dict(sched.trainers[owner].params))
        return self

    @classmethod
    def for_owner(cls, sched, owner: str, **kw) -> "KGEServingTier":
        """A tier serving ``owner``'s tables out of a federation: filters
        from the owner's full triple set (train ∪ valid ∪ test — the
        standard Filter-mode universe), tables from the owner's trainer,
        home slot from the scheduler's sticky placement when the batched
        tick engine has one, and the accept hook attached."""
        tr = sched.trainers[owner]
        kg = sched.kgs[owner]
        known = np.concatenate([kg.train, kg.valid, kg.test])
        engine = getattr(sched, "_tick_engine", None)
        if engine is not None and "home_slot" not in kw:
            kw["home_slot"] = engine.placement.slot(owner)
        tier = cls(tr.params, tr.model, known, owner=owner, **kw)
        tier.attach(sched, owner)
        return tier

    # ------------------------------------------------------------- submit
    def _admit(self) -> None:
        """Admission control, cheapest check first: a full queue rejects at
        submit, explicitly, before any validation work is spent."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.stats["rejected"] += 1
            raise TierOverloadError(
                f"queue at max_queue={self.max_queue}; request rejected "
                f"at submit"
            )

    def _submit(self, req: QueryRequest) -> QueryRequest:
        self.stats["submitted"] += 1
        self.queue.append(req)
        return req

    def submit_rank(self, h, r, t, *, deadline: Optional[float] = None
                    ) -> QueryRequest:
        """Queue a filtered-rank query batch; returns immediately.
        ``deadline`` is this request's queue budget in seconds — expired
        requests are shed at coalesce time instead of dispatched."""
        self._admit()
        tv = self._active
        h = check_id_range("head entity", h, self.model.num_entities)
        t = check_id_range("tail entity", t, self.model.num_entities)
        r = check_id_range("relation", r, self.model.num_relations)
        tv.check_finite("entity", tv.ent_bad, h)
        tv.check_finite("relation", tv.rel_bad, r)
        rid = self._next_rid
        self._next_rid += 1
        return self._submit(
            QueryRequest(rid, "rank", h, r, t, deadline=deadline)
        )

    def submit_topk(self, h, r, *, k: int = 10,
                    deadline: Optional[float] = None) -> QueryRequest:
        """Queue a top-k candidate query batch; returns immediately."""
        self._admit()
        tv = self._active
        h = check_id_range("head entity", h, self.model.num_entities)
        r = check_id_range("relation", r, self.model.num_relations)
        if not 1 <= k <= self.model.num_entities:
            raise ValueError(
                f"k must be in [1, {self.model.num_entities}], got {k}"
            )
        tv.check_finite("entity", tv.ent_bad, h)
        tv.check_finite("relation", tv.rel_bad, r)
        rid = self._next_rid
        self._next_rid += 1
        return self._submit(
            QueryRequest(rid, "topk", h, r, k=int(k), deadline=deadline)
        )

    # ------------------------------------------------------ admission loop
    def _shed(self, req: QueryRequest, now: float) -> None:
        """Terminal ``shed`` state: the deadline expired while queued. The
        request was never dispatched — distinct from ``failed`` (dispatched
        but unservable) by contract."""
        req.shed = True
        req.done = True
        req.finished_at = now
        self.stats["shed"] += 1

    @staticmethod
    def _expired(req: QueryRequest, now: float) -> bool:
        return (req.deadline is not None
                and now - req.submitted_at > req.deadline)

    def _coalesce(self) -> List[QueryRequest]:
        """Pop the FIFO head's batchable prefix: same kind (and same top-k
        bucket), up to ``max_batch`` query rows. Deadline-expired requests
        are shed (popped, never dispatched) as they surface. ``direct``
        mode takes one request — the per-call baseline."""
        now = time.perf_counter()
        while self.queue and self._expired(self.queue[0], now):
            self._shed(self.queue.popleft(), now)
        if not self.queue:
            return []
        head = self.queue[0]
        take = [self.queue.popleft()]
        if self.serve_impl == "direct":
            return take
        rows = len(head.h)
        kb = _pow2_at_least(head.k) if head.kind == "topk" else 0
        while self.queue and rows < self.max_batch:
            nxt = self.queue[0]
            if self._expired(nxt, now):
                self._shed(self.queue.popleft(), now)
                continue
            if nxt.kind != head.kind:
                break
            if head.kind == "topk" and _pow2_at_least(nxt.k) != kb:
                break
            if rows + len(nxt.h) > self.max_batch:
                break
            take.append(self.queue.popleft())
            rows += len(nxt.h)
        return take

    def _pad(self, arrs: List[np.ndarray], nq: int) -> List[np.ndarray]:
        """Pad batch extent to a pow-2 bucket by repeating row 0 — padded
        rows compute (and are discarded), keeping the compiled-program set
        fixed across every traffic mix."""
        nb = _pow2_at_least(nq, self.min_bucket if self.serve_impl == "batched"
                            else 1)
        if nb == nq:
            return arrs
        self.stats["padded_rows"] += nb - nq
        return [
            np.concatenate([a, np.repeat(a[:1], nb - nq, axis=0)], axis=0)
            for a in arrs
        ]

    # ------------------------------------------------------------- routing
    def _eligible(self) -> List[Replica]:
        """The routing pool: healthy replicas, plus UNHEALTHY replicas whose
        probe is due (the breaker's half-open state). If the breaker has
        opened on EVERY replica and no probe is due, the whole ring is the
        pool — the tier must keep serving with whatever it has."""
        pool = [rp for rp in self.replicas if rp.healthy]
        pool += [
            rp for rp in self.replicas
            if not rp.healthy and rp.probe_at is not None
            and self._seq >= rp.probe_at
        ]
        return pool or list(self.replicas)

    def _pick_replica(self, exclude: Tuple[Replica, ...] = ()) -> Replica:
        """Least-loaded healthy replica, tie-broken by lifetime dispatch
        count BEFORE slot — at equal in-flight load, traffic alternates
        across the ring instead of skewing onto the lowest slot. ``exclude``
        steers retries/hedges away from the replica that just failed (the
        exclusion is dropped if it would empty the pool — a single-replica
        tier still retries, on the only device it has)."""
        pool = [rp for rp in self._eligible() if rp not in exclude]
        if not pool:
            # honoring the exclusion beats honoring the breaker: a retry or
            # hedge steered off a bad replica may land on an unhealthy one
            # (a forced probe) rather than go back where it just failed
            pool = [rp for rp in self.replicas if rp not in exclude]
        if not pool:
            pool = self._eligible()
        rp = min(pool, key=lambda rp: (rp.inflight, rp.dispatched, rp.slot))
        if not rp.healthy:
            # half-open: this pick IS the probe — push the next probe out so
            # exactly one trial batch is in flight per probe window
            rp.probe_at = self._seq + self.probe_after
        return rp

    def _note_failure(self, rep: Replica) -> None:
        rep.fails += 1
        if rep.healthy and rep.fails >= self.breaker_fails:
            rep.healthy = False
            rep.probe_at = self._seq + self.probe_after
            self.stats["breaker_open"] += 1
        elif not rep.healthy:
            rep.probe_at = self._seq + self.probe_after

    def _note_success(self, rep: Replica, latency_s: float) -> None:
        rep.fails = 0
        if not rep.healthy:
            rep.healthy = True
            rep.probe_at = None
            self.stats["breaker_close"] += 1
        rep.ewma_s = (
            latency_s if rep.ewma_s is None
            else 0.8 * rep.ewma_s + 0.2 * latency_s
        )

    # ------------------------------------------------------------ dispatch
    def _revalidate(self, reqs: List[QueryRequest], tv: TableVersion
                    ) -> List[QueryRequest]:
        """Re-check finiteness against the version the batch is actually
        pinned to: submit-time validation ran against ``_active`` as of
        submit, and a hot-swap in between could otherwise serve rows that
        are non-finite in the dispatch version. O(B) bitmask lookups —
        requests touching bad rows fail here (terminal, with the same
        refusal semantics as submit) instead of serving garbage."""
        ok: List[QueryRequest] = []
        now: Optional[float] = None
        for q in reqs:
            bad = bool(tv.ent_bad[q.h].any()) or bool(tv.rel_bad[q.r].any())
            if not bad and q.kind == "rank":
                bad = bool(tv.ent_bad[q.t].any())
            if bad:
                if now is None:
                    now = time.perf_counter()
                q.error = ValueError(
                    f"non-finite query embedding in dispatch version "
                    f"{tv.version} (hot-swap between submit and dispatch)"
                )
                q.done = True
                q.finished_at = now
                self.stats["failed"] += 1
            else:
                ok.append(q)
        return ok

    def _dispatch(self, reqs: List[QueryRequest]) -> int:
        tv = self._active  # ONE read: the batch is pinned to this version
        reqs = self._revalidate(reqs, tv)
        if not reqs:
            return 0
        kind = reqs[0].kind
        h = np.concatenate([q.h for q in reqs])
        r = np.concatenate([q.r for q in reqs])
        nq = len(h)
        segs, off = [], 0
        for q in reqs:
            segs.append((q, off, len(q.h)))
            off += len(q.h)
        if kind == "rank":
            t = np.concatenate([q.t for q in reqs])
            filt = np.concatenate(
                [t[:, None].astype(np.int32), self.filters.rows_for(h, r)],
                axis=1,
            )
            host_in = tuple(self._pad([h, r, t, filt], nq))
            kb = 0
        else:
            kb = min(_pow2_at_least(reqs[0].k), self.model.num_entities)
            filt = self.filters.rows_for(h, r)
            host_in = tuple(self._pad([h, r, filt], nq))
        self.stats["batches"] += 1
        self._launch(kind, host_in, segs, nq, tv, kb)
        return nq

    def _launch(self, kind: str, host_in: Tuple, segs, nq: int,
                tv: TableVersion, kb: int, *, attempts: int = 0,
                exclude: Tuple[Replica, ...] = (),
                hedge_of: Optional[_InFlight] = None) -> _InFlight:
        """One device dispatch of an assembled batch (primary, retry, or
        hedge — each consumes a fresh launch sequence number, so the fault
        plan draws independently per attempt)."""
        rep = self._pick_replica(exclude=exclude)
        seq = self._seq
        self._seq += 1
        fault = None
        if self.fault_plan is not None:
            fault = self.fault_plan.draw(seq, rep.slot)
            if fault is not None:
                self.fault_counts[fault.kind] = (
                    self.fault_counts.get(fault.kind, 0) + 1
                )
        ptab = tv.on(rep.device)
        if kind == "rank":
            dh, dr, dt, df = jax.device_put(host_in, rep.device)
            counts = side_counts_dispatch(
                ptab, self.model, dh, dr, dt, df, side="tail",
                block_e=self.block_e, impl=self.rank_impl,
            )
            out: Tuple = (counts,)
        else:
            from repro.serving.engine import (
                _streaming_topk_decomposed,
                _streaming_topk_generic,
            )

            dh, dr, df = jax.device_put(host_in, rep.device)
            qd = lp_query_tails(ptab, self.model, dh, dr)
            if qd is not None:
                q, table, mode = qd
                vals, ids = _streaming_topk_decomposed(
                    q, table, df, k=kb, block_e=self.block_e, mode=mode
                )
            else:
                vals, ids = _streaming_topk_generic(
                    ptab, self.model, dh, dr, df, k=kb, block_e=self.block_e
                )
            out = (vals, ids)
        rep.inflight += 1
        rep.dispatched += 1
        fl = _InFlight(
            kind, out, segs, nq, tv, rep, host_in=host_in, kb=kb, seq=seq,
            attempts=attempts, fault=fault,
            dispatched_at=time.perf_counter(),
        )
        if hedge_of is None:
            self.inflight.append(fl)
        return fl

    def _maybe_hedge(self) -> None:
        """Hedged dispatch of the oldest stuck batch: if the FIFO head has
        been in flight longer than ``hedge_after`` seconds, launch a
        duplicate on a DIFFERENT replica and let the first result win —
        bit-identical either way, since both replicas hold the batch's
        pinned ``TableVersion``."""
        if self.hedge_after is None or not self.inflight:
            return
        b = self.inflight[0]
        if b.hedge is not None or b.ready():
            return
        if time.perf_counter() - b.dispatched_at < self.hedge_after:
            return
        if all(rp is b.replica for rp in self.replicas):
            return  # no second replica to hedge onto
        b.hedge = self._launch(
            b.kind, b.host_in, b.segs, b.nq, b.tv, b.kb,
            attempts=b.attempts, exclude=(b.replica,), hedge_of=b,
        )
        self.stats["hedged"] += 1

    # ------------------------------------------------------------- collect
    def _output_bad(self, kind: str, host: List[np.ndarray]) -> bool:
        """Armed-only output screen: a sane rank batch has finite,
        non-negative counts; a sane top-k batch has finite scores. Anything
        else is a poisoned (or genuinely broken) replica output and must
        route through the retry path, not reach a caller."""
        if kind == "rank":
            c = host[0]
            if c.dtype.kind == "f" and not np.isfinite(c).all():
                return True
            return bool((c < 0).any())
        # top-k scores: finite, or -inf where a filtered slot padded the
        # candidate set — NaN/+inf means a damaged replica output
        vals = host[0]
        return not bool(np.all(np.isfinite(vals) | np.isneginf(vals)))

    def _poison(self, kind: str, host: List[np.ndarray], fault: ServeFault
                ) -> List[np.ndarray]:
        """Apply an injected ``poison`` to collected outputs: rank counts go
        impossibly negative, top-k scores go NaN — damage the armed screen
        is specified to catch."""
        host = [np.array(x, copy=True) for x in host]
        n = min(max(1, fault.rows), host[0].shape[0])
        if kind == "rank":
            host[0][:n] = -(10 ** 6)
        else:
            host[0][:n] = np.nan
        return host

    def _collect(self, src: _InFlight, kind: str) -> List[np.ndarray]:
        """Materialize one launch's outputs on host, surfacing injected
        crashes, applying injected poison, and screening the result when
        the fault layer is armed. Raises on anything unservable."""
        if src.fault is not None and src.fault.kind == "crash":
            raise ServeFaultError("crash", src.seq, src.replica.slot)
        host = [np.asarray(x) for x in src.out]
        if src.fault is not None and src.fault.kind == "poison":
            host = self._poison(kind, host, src.fault)
        if self.fault_plan is not None and self._output_bad(kind, host):
            raise ServeFaultError("poison", src.seq, src.replica.slot)
        return host

    def _finish_batch(self, b: _InFlight) -> None:
        """Resolve one batch: consume the first usable result (primary or
        hedge), zombie the loser, and on total failure either re-dispatch
        to a different replica (failure isolation — the batch's requests
        survive) or, past ``retry_limit``, fail its requests."""
        sources = (
            [b] if b.hedge is None
            else ([b, b.hedge] if b.ready() else [b.hedge, b])
        )
        host = None
        used = None
        err: Optional[Exception] = None
        spent: List[_InFlight] = []
        for src in sources:
            try:
                host = self._collect(src, b.kind)
                used = src
                break
            except Exception as ex:  # device-side failure: isolate to batch
                err = ex
                src.replica.inflight -= 1
                self._note_failure(src.replica)
                spent.append(src)
        if host is None:
            failed = tuple(s.replica for s in spent)
            if b.attempts < self.retry_limit:
                self.stats["retried"] += 1
                self._launch(b.kind, b.host_in, b.segs, b.nq, b.tv, b.kb,
                             attempts=b.attempts + 1, exclude=failed)
                return
            now = time.perf_counter()
            for q, _, _ in b.segs:
                q.error, q.done, q.finished_at = err, True, now
            self.stats["failed"] += len(b.segs)
            return
        now = time.perf_counter()
        used.replica.inflight -= 1
        self._note_success(used.replica, now - used.dispatched_at)
        for src in sources:
            if src is not used and src not in spent:
                self._zombies.append(src)  # race loser: reaped for its slot
        for q, off, n in b.segs:
            if b.kind == "rank":
                q.result = host[0][off:off + n] + 1
            else:
                vals, ids = host
                q.result = (ids[off:off + n, :q.k], vals[off:off + n, :q.k])
            q.version = b.tv.version
            q.finished_at = now
            q.done = True
        self.stats["served"] += len(b.segs)

    def _reap_zombies(self) -> None:
        if not self._zombies:
            return
        keep = []
        for z in self._zombies:
            # raw readiness — a zombie's simulated straggle delay is moot,
            # only its replica's in-flight slot matters now
            if all(x.is_ready() for x in z.out):
                z.replica.inflight -= 1
            else:
                keep.append(z)
        self._zombies = keep

    def _batch_ready(self, b: _InFlight) -> bool:
        return b.ready() or (b.hedge is not None and b.hedge.ready())

    def _reap(self, *, block: bool = False) -> int:
        """Collect completed batches; with ``block`` wait for the oldest
        (the admission loop calls this when the dispatch-ahead window is
        full), then keep draining whatever else already finished. The
        blocking wait polls (instead of blocking inside ``np.asarray``) so
        simulated straggles are honored and the hedge trigger keeps
        firing."""
        done = 0
        self._reap_zombies()
        while self.inflight:
            head = self.inflight[0]
            if not self._batch_ready(head):
                if not block:
                    break
                self._maybe_hedge()
                time.sleep(2e-4)
                continue
            block = False
            b = self.inflight.popleft()
            self._finish_batch(b)
            self._reap_zombies()
            done += len(b.segs)
        return done

    # -------------------------------------------------------- driving loop
    def step(self) -> int:
        """One admission-loop tick: collect finished batches, hedge the
        oldest stuck one, then dispatch (at most) one coalesced batch.
        Returns the query rows dispatched."""
        self._reap()
        self._maybe_hedge()
        if not self.queue:
            return 0
        while len(self.inflight) >= self.max_inflight:
            self._reap(block=True)
        reqs = self._coalesce()
        if not reqs:
            return 0  # everything at the head was shed
        return self._dispatch(reqs)

    def run_until_drained(self, *, max_steps: int = 1_000_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.inflight:
                if self._zombies:
                    self._reap_zombies()
                    if self._zombies:
                        time.sleep(2e-4)
                    continue
                self._check_accounting()
                return
            if self.queue:
                self.step()
            else:
                self._reap(block=True)
        raise RuntimeError("serving tier failed to drain")

    def _check_accounting(self) -> None:
        """The resolution invariant, asserted at every drain point: every
        submitted request terminates in exactly one of served/shed/failed
        (rejected requests never entered)."""
        s = self.stats
        if s["served"] + s["shed"] + s["failed"] != s["submitted"]:
            raise RuntimeError(
                f"serving accounting broken: served={s['served']} + "
                f"shed={s['shed']} + failed={s['failed']} != "
                f"submitted={s['submitted']}"
            )

    # ------------------------------------------------------- observability
    def replica_load(self) -> List[Tuple[int, int]]:
        """[(slot, lifetime batches)] — the routing spread."""
        return [(rp.slot, rp.dispatched) for rp in self.replicas]

    def health(self) -> List[Dict]:
        """Per-replica health snapshot: breaker state, consecutive-failure
        count, smoothed latency, and routing counters."""
        return [
            {
                "slot": rp.slot, "healthy": rp.healthy, "fails": rp.fails,
                "inflight": rp.inflight, "dispatched": rp.dispatched,
                "ewma_ms": None if rp.ewma_s is None else rp.ewma_s * 1e3,
                "probe_at": rp.probe_at,
            }
            for rp in self.replicas
        ]


def serving_program_cache_size() -> int:
    """Number of compiled serving-program specializations (rank counts +
    both top-k variants). The retrace-pin test asserts this stays flat
    across steady-state traffic of ANY mix of batch sizes within the
    bucket set — continuous batching is only a win if padded buckets
    actually stop recompilation."""
    from repro.kge.eval import _side_counts_jit
    from repro.serving.engine import (
        _streaming_topk_decomposed,
        _streaming_topk_generic,
    )

    return sum(
        p._cache_size()
        for p in (_side_counts_jit, _streaming_topk_decomposed,
                  _streaming_topk_generic)
    )
