from repro.serving.engine import (  # noqa: F401
    KGECandidateRanker,
    Request,
    ServingEngine,
)
