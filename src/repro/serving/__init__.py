from repro.serving.engine import (  # noqa: F401
    KGECandidateRanker,
    Request,
    ServingEngine,
)
from repro.serving.tables import (  # noqa: F401
    FilterPack,
    TableVersion,
)
from repro.serving.tier import (  # noqa: F401
    KGEServingTier,
    QueryRequest,
    TierOverloadError,
    serving_program_cache_size,
)
