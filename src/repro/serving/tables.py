"""Versioned serving tables: what a KGE serving process actually holds.

Two pieces, split by lifetime:

``FilterPack`` — the padded CSR known-true filter over (h, r) keys, built
ONCE from the owner's known triples. The pad width is a power-of-two bucket
over the longest row, so every batch sliced from it has the same trailing
extent and the rank/top-k jits never retrace on filter width (the seed
ranker recomputed ``max(len(v) for v in hr_t.values())`` and rebuilt Python
row lists per request). Known triples outlive table versions — the same
pack serves every published version.

``TableVersion`` — one immutable published snapshot of an owner's embedding
tables: the params dict, a per-version non-finite-row bitmask (computed once
at publish with one on-device reduction per table; request validation is an
O(B) host lookup instead of pulling embedding rows per call), and a
per-device committed-copy cache in the tick engine's ``_resident_on`` idiom.
Because the owner-sticky federation keeps accepted params committed to the
owner's home device, staging a fresh version onto replica 0 is zero-copy —
``on(device)`` returns the params dict itself when it is already committed
there.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import committed_device
from repro.kge.eval import _filter_mask, pack_padded_filters


def _pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


class FilterPack:
    """Padded CSR filter rows for tail queries, one row per known (h, r) key
    plus a trailing all(−1) sentinel row for unknown keys."""

    def __init__(self, known_triples, num_entities: int):
        known = (
            np.zeros((0, 3), np.int64) if known_triples is None
            else np.asarray(known_triples)
        )
        self.num_entities = int(num_entities)
        self.hr_t, self.rt_h = _filter_mask(known, num_entities)
        rows: List[List[int]] = [sorted(v) for v in self.hr_t.values()]
        self._row_of: Dict[Tuple[int, int], int] = {
            k: i for i, k in enumerate(self.hr_t)
        }
        maxw = max((len(x) for x in rows), default=1)
        self.width = _pow2(maxw)
        # sentinel row (all −1) appended so unknown keys index real storage
        self.rows = pack_padded_filters(rows + [[]], width=self.width)

    def row_index(self, h: np.ndarray, r: np.ndarray) -> np.ndarray:
        sentinel = len(self.rows) - 1
        get = self._row_of.get
        return np.fromiter(
            (get((int(hh), int(rr)), sentinel) for hh, rr in zip(h, r)),
            np.int64, count=len(h),
        )

    def rows_for(self, h: np.ndarray, r: np.ndarray) -> np.ndarray:
        """(B, width) int32 known-tail filter rows for (h, r) queries — one
        fancy-index slice, no per-request Python row building."""
        return self.rows[self.row_index(h, r)]


def check_id_range(name: str, ids, limit: int) -> np.ndarray:
    """Serving boundary: ids arrive from untrusted callers, and an
    out-of-range id would otherwise gather from the wrong row (negative
    wraps) or crash deep inside a jitted kernel with a shape error."""
    ids = np.asarray(ids, np.int64).reshape(-1)
    bad = ids[(ids < 0) | (ids >= limit)]
    if bad.size:
        raise ValueError(
            f"{name} ids must be in [0, {limit}); got "
            f"{bad[:5].tolist()}{'…' if bad.size > 5 else ''}"
        )
    return ids


def _bad_row_mask(params, keys, n: int) -> np.ndarray:
    """(n,) bool: rows with any NaN/Inf in any of the named tables. The
    finiteness reduction runs on device; only the boolean vector lands on
    host. Tables longer than ``n`` (virtual-entity extensions) only
    contribute their first ``n`` rows — ids beyond ``n`` are rejected by
    range validation before this mask is ever consulted."""
    bad = np.zeros(n, np.bool_)
    for k in keys:
        tab = params.get(k)
        if tab is None:
            continue
        m = np.asarray(jnp.logical_not(jnp.isfinite(tab).all(axis=-1)))
        bad[: m.shape[0]] |= m[:n]
    return bad


class TableVersion:
    """One immutable published (owner, version) snapshot of serving tables."""

    def __init__(self, params, model, filters: FilterPack, *,
                 version: int = 0, owner: Optional[str] = None):
        self.params = dict(params)
        self.model = model
        self.filters = filters
        self.version = int(version)
        self.owner = owner
        self.ent_bad = _bad_row_mask(self.params, ("ent", "ent_im"),
                                     model.num_entities)
        self.rel_bad = _bad_row_mask(self.params, ("rel", "rel_im"),
                                     model.num_relations)
        #: committed-per-device copies (the tick engine's ``_resident_on``
        #: idiom); populated lazily by ``on()`` / eagerly by tier publish
        self._ondev: Dict = {}
        #: explicit cross-device copies made for this version — stays 0 for
        #: the device the params are already committed to (zero-copy flip)
        self.transfers = 0

    def on(self, device) -> Dict[str, jnp.ndarray]:
        """The committed-to-``device`` copy of the tables, built (one
        explicit transfer) on first use and referenced in place afterwards."""
        got = self._ondev.get(device)
        if got is None:
            if committed_device(self.params) == device:
                got = self.params  # already resident — zero-copy
            else:
                got = jax.device_put(self.params, device)
                self.transfers += 1
            self._ondev[device] = got
        return got

    def check_finite(self, name: str, bad_mask: np.ndarray,
                     ids: np.ndarray) -> None:
        """O(B) bitmask lookup replacing the per-request host pull of
        embedding rows; same refusal semantics, id named."""
        bad = ids[bad_mask[ids]]
        if bad.size:
            raise ValueError(
                f"non-finite query embedding: {name} ids "
                f"{bad[:5].tolist()}{'…' if bad.size > 5 else ''} "
                f"have NaN/Inf rows in this table version"
            )
