"""whisper-medium — enc-dec audio model; conv/mel frontend is a STUB.

The transformer backbone only: 24 encoder + 24 decoder layers, d=1024, 16H
(MHA: kv=16), d_ff=4096, learned positions, GELU. ``input_specs`` supplies
precomputed 1500-frame embeddings in place of the mel+conv frontend.
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="encdec",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    encoder_seq=1500,        # 30s audio → 1500 frames after conv frontend (stubbed)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    act="gelu",
    norm="layernorm",
    learned_pos_emb=4096,    # learned absolute positions (decoder side)
    rope_theta=0.0,
    tie_embeddings=True,
    source="arXiv:2212.04356 (Whisper medium)",
)
