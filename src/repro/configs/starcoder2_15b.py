"""starcoder2-15b — dense code model, 40L, GQA 48H/4KV, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    arch_type="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=100_000.0,
    qkv_bias=True,           # StarCoder2 uses bias on attention/MLP projections
    act="gelu",
    norm="layernorm",
    source="arXiv:2402.19173 (StarCoder2-15B)",
)
