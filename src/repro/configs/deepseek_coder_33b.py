"""deepseek-coder-33b — dense llama-arch, 62L, GQA 56H/8KV. [arXiv:2401.14196]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19_200,
    vocab_size=32_256,
    rope_theta=100_000.0,
    act="silu",
    norm="rmsnorm",
    source="arXiv:2401.14196 (DeepSeek-Coder 33B)",
)
