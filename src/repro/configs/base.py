"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the builder in
``repro.models.model`` dispatches on ``arch_type``. Configs are plain frozen
dataclasses so they hash, print, and diff cleanly — no framework magic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int = 0
    experts_per_token: int = 0
    d_ff: int = 0                  # per-expert hidden dim
    num_shared_experts: int = 0    # always-on experts (DeepSeek/Kimi style)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01  # load-balance loss weight
    every_k_layers: int = 1        # MoE FFN on layers where (i % k == k-1)
    impl: str = "gather"           # "gather" (pjit) | "alltoall" (shard_map EP)
    route_groups: int = 0          # >0: DeepSeek/K2-style node-limited routing —
                                   # each token may only use experts from its
                                   # top-G data shards; dispatch dedups to one
                                   # send per (token, group) (§Perf)

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD — state space duality) block configuration."""

    d_state: int = 0
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def enabled(self) -> bool:
        return self.d_state > 0

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture card.

    ``arch_type`` ∈ {dense, moe, ssm, hybrid, encdec, vlm}. ``source`` cites
    the paper / model card the numbers come from.
    """

    name: str
    arch_type: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""

    head_dim: int = 0                   # 0 → d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0             # 0 → full attention
    norm_eps: float = 1e-6
    act: str = "silu"                   # silu (SwiGLU) | gelu (plain MLP)
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    tie_embeddings: bool = False
    learned_pos_emb: int = 0            # >0 → learned absolute positions (whisper)

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # hybrid (jamba): within each period of ``hybrid_period`` layers, the layer
    # at index ``hybrid_attn_index`` is attention, the rest are Mamba2.
    hybrid_period: int = 0
    hybrid_attn_index: int = 4

    # encoder-decoder (whisper): encoder consumes stubbed frame embeddings.
    encoder_layers: int = 0
    encoder_seq: int = 0

    # VLM: stubbed vision frontend supplies ``num_patches`` patch embeddings
    # that are prepended to the token embeddings.
    num_patches: int = 0

    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    # "full" recomputes the whole layer in bwd; "dots" saves matmul outputs
    # (skips re-running the tensor-parallel collectives during recompute —
    # §Perf iteration 3)
    remat_policy: str = "full"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived sizes ------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the vocab axis shards
        evenly over the 16-way 'model' mesh axis (MaxText-style padding).
        Padded rows are never produced by the tokenizer; their logits are
        valid softmax entries that simply never win."""
        return -(-self.vocab_size // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.hybrid_period:
            return (i % self.hybrid_period) == self.hybrid_attn_index
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe.enabled:
            return False
        k = self.moe.every_k_layers
        return (i % k) == (k - 1)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for 6ND roofline."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # input embedding
        if not self.tie_embeddings:
            n += v * d
        for i in range(self.num_layers):
            if self.is_attn_layer(i):
                n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif self.ssm.enabled:
                di = self.ssm.d_inner(d)
                nh = self.ssm.num_heads(d)
                g, s = self.ssm.n_groups, self.ssm.d_state
                n += d * (2 * di + 2 * g * s + nh)       # in_proj
                n += di * d                              # out_proj
                n += (di + 2 * g * s) * self.ssm.conv_width + 2 * nh + di
            if self.is_moe_layer(i):
                e = self.moe.num_experts + self.moe.num_shared_experts
                n += e * 3 * d * self.moe.d_ff + d * self.moe.num_experts
            elif self.d_ff:
                mult = 3 if self.act == "silu" else 2
                n += mult * d * self.d_ff
        for _ in range(self.encoder_layers):
            n += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            mult = 3 if self.act == "silu" else 2
            n += mult * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.moe.enabled:
            return self.param_count()
        full = self.param_count()
        e_all = self.moe.num_experts + self.moe.num_shared_experts
        e_act = self.moe.experts_per_token + self.moe.num_shared_experts
        per_expert = 3 * self.d_model * self.moe.d_ff
        n_moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        return full - n_moe_layers * (e_all - e_act) * per_expert

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TrainConfig:
    """Training-step hyperparameters (used by launch/train.py and dryrun)."""

    global_batch: int = 256
    seq_len: int = 4096
    microbatches: int = 1             # gradient-accumulation steps
    ce_chunk: int = 0                 # 0 → whole-sequence logits; else chunked CE
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    z_loss: float = 0.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM (§Perf)


@dataclass(frozen=True)
class ServeConfig:
    """Decode / prefill step configuration."""

    batch: int = 128
    cache_len: int = 32_768
    prefill_chunk: int = 0


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned (shape-id → workload) rows."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

INPUT_SHAPE_BY_NAME = {s.name: s for s in INPUT_SHAPES}
