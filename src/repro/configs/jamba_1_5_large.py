"""jamba-1.5-large-398b — hybrid Mamba+attention (1:7) with MoE 16e top-2.

72L, d=8192, 64H/8KV attention at 1 of every 8 layers; MoE FFN every other
layer (16 experts, top-2, d_ff=24576). [arXiv:2403.19887]
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    hybrid_period=8,
    hybrid_attn_index=4,
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_ff=24_576,
                  every_k_layers=2, impl="alltoall"),  # §Perf: EP all-to-all
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    rope_theta=0.0,          # Jamba attention layers use no positional encoding
    norm="rmsnorm",
    source="arXiv:2403.19887 (Jamba-1.5-Large)",
)
