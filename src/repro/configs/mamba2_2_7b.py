"""mamba2-2.7b — attention-free SSM (SSD), 64L, d=2560, state=128. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,                # no separate FFN: the Mamba2 block is the whole layer
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1, chunk_size=256),
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2 2.7B)",
)
