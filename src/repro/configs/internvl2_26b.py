"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

The vision encoder + projector are stubbed per the assignment: ``input_specs``
supplies precomputed patch embeddings (num_patches × d_model) which the LM
prepends to token embeddings. Backbone: 48L, d=6144, GQA 48H/8KV.
[arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_553,
    num_patches=256,         # one image tile → 256 visual tokens after projector
    rope_theta=1_000_000.0,
    act="silu",
    norm="rmsnorm",
    source="arXiv:2404.16821 (InternVL2-26B, InternLM2 backbone)",
)
