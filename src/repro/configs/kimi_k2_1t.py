"""kimi-k2-1t-a32b — trillion-param MoE: 61L, d=7168, 384 experts top-8.

Per the assignment card: GQA 64H/8KV, per-expert d_ff=2048, vocab=163840,
1 shared expert (DeepSeek-V3-style), 32B active parameters. [arXiv:2501.kimi2]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,            # 7168 / 64
    d_ff=0,                  # all FFNs are MoE
    vocab_size=163_840,
    moe=MoEConfig(num_experts=384, experts_per_token=8, d_ff=2048,
                  num_shared_experts=1,
                  # production layout (§Perf): shard_map expert-parallel
                  # all-to-all + K2's node-limited routing (4 groups)
                  impl="alltoall", route_groups=4),
    rope_theta=50_000.0,
    act="silu",
    norm="rmsnorm",
    source="arXiv:2501.kimi2 (Kimi K2 paper-table)",
)
