from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    INPUT_SHAPE_BY_NAME,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ServeConfig,
    TrainConfig,
)
from repro.configs.registry import ARCHS, get_config, reduced  # noqa: F401
