"""mixtral-8x22b — MoE 8 experts top-2, GQA 48H/8KV, sliding-window attention.

56L, d=6144, per-expert d_ff=16384, vocab=32768, SWA window 4096 — the SWA is
what qualifies this card for the long_500k decode shape. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32_768,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_ff=16_384),
    rope_theta=1_000_000.0,
    act="silu",
    norm="rmsnorm",
    source="arXiv:2401.04088 (Mixtral 8x22B)",
)
