"""Architecture registry: ``--arch <id>`` → ModelConfig, plus reduced variants.

``get_config(arch_id)`` returns the full assigned card. ``reduced(cfg)``
returns the smoke-test variant of the same family (≤2 layers, d_model ≤ 512,
≤4 experts) used by CPU tests; the full cards are only ever lowered abstractly
via the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig

from repro.configs.qwen3_0_6b import CONFIG as QWEN3_0_6B
from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM
from repro.configs.mamba2_2_7b import CONFIG as MAMBA2_2_7B
from repro.configs.jamba_1_5_large import CONFIG as JAMBA_1_5_LARGE
from repro.configs.deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from repro.configs.qwen2_5_3b import CONFIG as QWEN2_5_3B
from repro.configs.internvl2_26b import CONFIG as INTERNVL2_26B
from repro.configs.starcoder2_15b import CONFIG as STARCODER2_15B
from repro.configs.kimi_k2_1t import CONFIG as KIMI_K2_1T
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        QWEN3_0_6B,
        WHISPER_MEDIUM,
        MAMBA2_2_7B,
        JAMBA_1_5_LARGE,
        DEEPSEEK_CODER_33B,
        QWEN2_5_3B,
        INTERNVL2_26B,
        STARCODER2_15B,
        KIMI_K2_1T,
        MIXTRAL_8X22B,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def reduced(cfg: ModelConfig, *, vocab: int = 512) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (2L, d≤512, ≤4 experts)."""
    d_model = min(cfg.d_model, 256)
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, 2))
    head_dim = max(8, d_model // heads)
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=heads if cfg.num_heads else 0,
        num_kv_heads=kv if cfg.num_kv_heads else 0,
        head_dim=head_dim if cfg.num_heads else 1,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=vocab,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        learned_pos_emb=min(cfg.learned_pos_emb, 512) if cfg.learned_pos_emb else 0,
    )
    if cfg.moe.enabled:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            d_ff=min(cfg.moe.d_ff, 256),
        )
    if cfg.ssm.enabled:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 32), head_dim=32, chunk_size=32
        )
    if cfg.hybrid_period:
        # keep the interleave property at 2 layers: 1 mamba + 1 attn
        kw["hybrid_period"] = 2
        kw["hybrid_attn_index"] = 1
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = min(cfg.encoder_seq, 64)
    if cfg.num_patches:
        kw["num_patches"] = min(cfg.num_patches, 16)
    return cfg.replace(**kw)
