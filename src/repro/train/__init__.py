from repro.train.step import make_train_step, make_decode_step, make_prefill_step  # noqa: F401
from repro.train.loss import lm_loss  # noqa: F401
