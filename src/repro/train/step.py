"""jit-able step functions: train (with gradient accumulation), prefill, decode.

These are what the launcher and the multi-pod dry-run lower: a single
``train_step(state, batch) -> (state, metrics)`` per optimizer step, a
``prefill_step`` and a one-token ``decode_step`` for serving shapes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import decode_step as _decode
from repro.models.model import init_params, prefill as _prefill
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.train.loss import lm_loss


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState


def init_train_state(key, cfg, *, moment_dtype=jnp.float32) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt=adamw_init(params, moment_dtype=moment_dtype))


def make_train_step(cfg, tcfg):
    """Returns train_step(state, batch) — batch: {tokens, labels[, frames, patches]}.

    Gradient accumulation: the global batch is split into ``tcfg.microbatches``
    slices scanned sequentially; grads are averaged before one AdamW update.
    """

    def loss_fn(params, mb):
        return lm_loss(
            params,
            cfg,
            mb["tokens"],
            mb["labels"],
            frames=mb.get("frames"),
            patches=mb.get("patches"),
            ce_chunk=tcfg.ce_chunk,
            z_loss=tcfg.z_loss,
        )

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        n_mb = tcfg.microbatches
        if n_mb > 1:
            def split(x):
                # STRIDED microbatch split: microbatch j takes rows {i·n_mb+j}.
                # A contiguous reshape(n_mb, B/n_mb, …) would place the mesh-
                # sharded batch axis under the scan axis, forcing GSPMD to
                # replicate every microbatch across the 'data' axis (§Perf
                # iteration 1: this was worth ~450 GiB/device/step of
                # all-reduce traffic on qwen3 × train_4k). The strided split
                # keeps each microbatch's batch dim data-sharded with zero
                # resharding.
                return x.reshape(x.shape[0] // n_mb, n_mb, *x.shape[1:]).swapaxes(0, 1)

            mbs = {k: split(v) for k, v in batch.items() if v is not None}

            def acc(carry, mb):
                gsum, lsum = carry
                (loss, metrics), grads = grad_fn(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), metrics = jax.lax.scan(acc, (zero, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, gsum)
            loss = lsum / n_mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        lr = cosine_schedule(
            state.opt.step + 1,
            base_lr=tcfg.learning_rate,
            warmup=tcfg.warmup_steps,
            total=tcfg.total_steps,
        )
        new_params, new_opt = adamw_update(
            grads,
            state.opt,
            state.params,
            lr=lr,
            b1=tcfg.b1,
            b2=tcfg.b2,
            weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip,
        )
        metrics = dict(metrics, loss=loss, lr=lr)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, tokens, cache, frames=None, patches=None):
        return _prefill(params, cfg, tokens, cache, frames=frames, patches=patches)

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, token, cache, cache_pos):
        return _decode(params, cfg, token, cache, cache_pos)

    return decode_step
