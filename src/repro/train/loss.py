"""Cross-entropy loss with optional sequence-chunked logits.

``ce_chunk > 0`` never materialises the full (B, S, V) logits tensor: the
final hidden states are scanned in sequence chunks and each chunk's logits are
rematerialised in the backward pass (``jax.checkpoint``). For the assigned
``train_4k`` shape (1M tokens × 152k vocab ≈ 300 TB of fp32 logits) this is
the difference between impossible and cheap — it is one of the §Perf levers.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear, unembed
from repro.models.model import forward


def _ce_from_logits(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray, z_loss: float):
    """logits (N, V) fp32, labels (N,), mask (N,) → (sum_nll, sum_z)."""
    m = mask.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.sum((lse - picked) * m)
    z = jnp.sum(jnp.square(lse) * m) * z_loss if z_loss else jnp.zeros(())
    return nll, z


def _project(params, cfg, h):
    if cfg.tie_embeddings:
        return unembed(params["embed"], h)
    return apply_linear(params["unembed"], h).astype(jnp.float32)


def lm_loss(
    params: dict,
    cfg,
    tokens: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    frames: Optional[jnp.ndarray] = None,
    patches: Optional[jnp.ndarray] = None,
    ce_chunk: int = 0,
    z_loss: float = 0.0,
) -> Tuple[jnp.ndarray, dict]:
    """Mean next-token CE (+ MoE aux + z-loss). labels==-1 positions masked."""
    h, aux = forward(
        params, cfg, tokens, frames=frames, patches=patches, return_hidden=True
    )
    if cfg.num_patches:  # VLM: loss only on the token positions
        h = h[:, cfg.num_patches :, :]
    b, s, d = h.shape
    mask2 = labels >= 0
    labels2 = jnp.maximum(labels, 0)
    denom = jnp.maximum(1.0, jnp.sum(mask2.astype(jnp.float32)))

    if ce_chunk and s % ce_chunk == 0 and s > ce_chunk:
        # Chunk along the SEQUENCE axis only: the batch axis stays mesh-
        # sharded through the scan (§Perf iteration 2 — a flat (b·s) chunking
        # merges the sharded batch dim into the scan axis and forces GSPMD to
        # re-gather activations every chunk).
        nchunk = s // ce_chunk

        @jax.checkpoint
        def chunk_fn(carry, xs):
            hc, lc, mc = xs  # (b, ce_chunk, d) / (b, ce_chunk)
            logits = _project(params, cfg, hc.reshape(b * ce_chunk, d))
            nll, z = _ce_from_logits(
                logits, lc.reshape(-1), mc.reshape(-1), z_loss
            )
            return (carry[0] + nll, carry[1] + z), None

        xs = (
            h.reshape(b, nchunk, ce_chunk, d).swapaxes(0, 1),
            labels2.reshape(b, nchunk, ce_chunk).swapaxes(0, 1),
            mask2.reshape(b, nchunk, ce_chunk).swapaxes(0, 1),
        )
        (nll, z), _ = jax.lax.scan(chunk_fn, (jnp.zeros(()), jnp.zeros(())), xs)
    else:
        logits = _project(params, cfg, h.reshape(b * s, d))
        nll, z = _ce_from_logits(
            logits, labels2.reshape(-1), mask2.reshape(-1), z_loss
        )

    loss = nll / denom + z / denom + aux
    metrics = {"nll": nll / denom, "aux": aux, "z": z / denom}
    return loss, metrics
