"""Mamba2 block — SSD (state-space duality) chunked scan, TPU-adapted.

Per arXiv:2405.21060. The chunked algorithm splits the sequence into chunks of
``Q`` tokens; within a chunk the recurrence is computed as a (masked, decayed)
attention-like quadratic form that maps onto the MXU; across chunks a small
(H, P, N) state is carried by ``lax.scan``. Decode is a single O(1) state
update — this is why the ``long_500k`` shape is trivially sub-quadratic for
SSM/hybrid architectures.

Layout: heads H shard over the mesh 'model' axis, batch over 'data'.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of


def init_ssm(key, cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.num_heads(d)
    g, n, w = s.n_groups, s.d_state, s.conv_width
    conv_ch = di + 2 * g * n
    dt = dtype_of(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    dt_init = jnp.exp(
        jax.random.uniform(k3, (h,), jnp.float32)
        * (math.log(s.dt_max) - math.log(s.dt_min))
        + math.log(s.dt_min)
    )
    return {
        "in_proj": (jax.random.normal(k1, (d, in_dim), jnp.float32) / math.sqrt(d)).astype(dt),
        "conv_w": (jax.random.normal(k2, (w, conv_ch), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt_init)),  # softplus^-1(dt_init)
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": (jax.random.normal(k4, (di, d), jnp.float32) / math.sqrt(di)).astype(dt),
    }


def _segsum_matrix(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q) log-decays → L (..., Q, Q) with L[s,t]=exp(Σ_{t<τ≤s} a_τ), lower-tri."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)  # inclusive
    diff = cum[..., :, None] - cum[..., None, :]  # (.., s, t) = Σ up to s minus up to t
    si = jnp.arange(q)[:, None]
    ti = jnp.arange(q)[None, :]
    return jnp.where(ti <= si, jnp.exp(diff), 0.0)


def ssd_chunk(
    x: jnp.ndarray,  # (B, Q, H, P)
    dt: jnp.ndarray,  # (B, Q, H) post-softplus
    A: jnp.ndarray,  # (H,) negative
    Bm: jnp.ndarray,  # (B, Q, G, N)
    Cm: jnp.ndarray,  # (B, Q, G, N)
    state: jnp.ndarray,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One chunk of the SSD scan → (y (B,Q,H,P), new_state)."""
    b, q, h, p = x.shape
    g = Bm.shape[2]
    rep = h // g
    a = dt * A[None, None, :]  # (B,Q,H) log-decay
    a_t = a.transpose(0, 2, 1)  # (B,H,Q)
    cum = jnp.cumsum(a_t, axis=-1)  # (B,H,Q) inclusive

    # intra-chunk: scores[s,t] = C_s·B_t (shared across heads in a group)
    scores = jnp.einsum("bsgn,btgn->bgst", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    scores = jnp.repeat(scores, rep, axis=1)  # (B,H,Q,Q)
    L = _segsum_matrix(a_t)  # (B,H,Q,Q)
    w = scores * L * dt.transpose(0, 2, 1)[:, :, None, :]  # weight on x_t
    y = jnp.einsum("bhst,bthp->bshp", w.astype(x.dtype), x)

    # inter-chunk: contribution of incoming state
    decay_out = jnp.exp(cum).transpose(0, 2, 1)  # (B,Q,H)
    c_rep = jnp.repeat(Cm, rep, axis=2)  # (B,Q,H,N)
    y_inter = jnp.einsum("bqhn,bhpn->bqhp", c_rep.astype(jnp.float32), state.astype(jnp.float32))
    y = y + (y_inter * decay_out[..., None]).astype(x.dtype)

    # new state
    decay_to_end = jnp.exp(cum[..., -1:] - cum).transpose(0, 2, 1)  # (B,Q,H)
    b_rep = jnp.repeat(Bm, rep, axis=2)  # (B,Q,H,N)
    dx = x.astype(jnp.float32) * (dt * decay_to_end)[..., None]  # (B,Q,H,P)
    chunk_state = jnp.einsum("bqhp,bqhn->bhpn", dx, b_rep.astype(jnp.float32))
    total_decay = jnp.exp(cum[..., -1])  # (B,H)
    new_state = state * total_decay[..., None, None] + chunk_state
    return y, new_state


def ssd(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    A: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD over a full sequence (scan over chunks)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    if state is None:
        state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        xc, dtc, bc, cc = inp
        y, new_state = ssd_chunk(xc, dtc, A, bc, cc, carry)
        return new_state, y

    xs = (
        x.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4),
        dt.reshape(b, nc, q, h).transpose(1, 0, 2, 3),
        Bm.reshape(b, nc, q, Bm.shape[2], n).transpose(1, 0, 2, 3, 4),
        Cm.reshape(b, nc, q, Cm.shape[2], n).transpose(1, 0, 2, 3, 4),
    )
    final_state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final_state


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B,S,C), w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _split_proj(cfg, proj: jnp.ndarray):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    g, n = s.n_groups, s.d_state
    h = s.num_heads(cfg.d_model)
    z, xbc, dt = jnp.split(proj, [di, di + di + 2 * g * n], axis=-1)
    return z, xbc, dt, di, g, n, h


def ssm_block(
    params: dict, cfg, u: jnp.ndarray, state: Optional[jnp.ndarray] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full Mamba2 block over a sequence. u: (B, S, d) → (y, final_ssd_state)."""
    s_cfg = cfg.ssm
    b, s, d = u.shape
    proj = u @ params["in_proj"]
    z, xbc, dtp, di, g, n, h = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xh, bm, cm = jnp.split(xbc, [di, di + g * n], axis=-1)
    p = s_cfg.head_dim
    xh = xh.reshape(b, s, h, p)
    bm = bm.reshape(b, s, g, n)
    cm = cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, final_state = ssd(xh, dt, A, bm, cm, s_cfg.chunk_size, state)
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(b, s, di)
    # gated RMSNorm (Mamba2 style): norm(y * silu(z))
    yz = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    ms = jnp.mean(jnp.square(yz.astype(jnp.float32)), axis=-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(ms + cfg.norm_eps)).astype(u.dtype)
    yz = yz * params["norm_scale"]
    return yz @ params["out_proj"], final_state


def ssm_prefill(params: dict, cfg, u: jnp.ndarray, cache: dict) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence pass that also fills the decode cache (SSD state +
    conv history tail). u: (B, S, d)."""
    s_cfg = cfg.ssm
    b, s, d = u.shape
    proj = u @ params["in_proj"]
    z, xbc_raw, dtp, di, g, n, h = _split_proj(cfg, proj)
    w = s_cfg.conv_width
    # conv history the decoder needs: the last (W-1) *pre-conv* xbc rows
    tail = xbc_raw[:, -(w - 1):, :] if s >= w - 1 else jnp.concatenate(
        [jnp.zeros((b, w - 1 - s, xbc_raw.shape[-1]), xbc_raw.dtype), xbc_raw], axis=1
    )
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xh, bm, cm = jnp.split(xbc, [di, di + g * n], axis=-1)
    p = s_cfg.head_dim
    xh = xh.reshape(b, s, h, p)
    bm = bm.reshape(b, s, g, n)
    cm = cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    q = s_cfg.chunk_size
    if s % min(q, s):  # pad sequence to a chunk multiple for the scan
        pad = min(q, s) - s % min(q, s)
    else:
        pad = 0
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, final_state = ssd(xh, dt, A, bm, cm, q, None)
    y = y[:, :s] + xh[:, :s] * params["D"][None, None, :, None]
    y = y.reshape(b, s, di)
    yz = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    ms = jnp.mean(jnp.square(yz.astype(jnp.float32)), axis=-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(ms + cfg.norm_eps)).astype(u.dtype)
    yz = yz * params["norm_scale"]
    out = yz @ params["out_proj"]
    new_cache = {"state": final_state, "conv": tail.astype(cache["conv"].dtype)}
    return out, new_cache


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.num_heads(d)
    g, n = s.n_groups, s.d_state
    conv_ch = di + 2 * g * n
    return {
        "state": jnp.zeros((batch, h, s.head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def ssm_decode_step(
    params: dict, cfg, u: jnp.ndarray, cache: dict
) -> Tuple[jnp.ndarray, dict]:
    """Single-token decode. u: (B, 1, d)."""
    s_cfg = cfg.ssm
    b = u.shape[0]
    proj = u[:, 0] @ params["in_proj"]  # (B, in_dim)
    z, xbc, dtp, di, g, n, h = _split_proj(cfg, proj)
    # conv with cached history
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B, W, C)
    w = params["conv_w"]
    conv_out = jnp.sum(hist.astype(jnp.float32) * w.astype(jnp.float32), axis=1)
    xbc_t = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)).astype(u.dtype)
    new_conv = hist[:, 1:]
    xh, bm, cm = jnp.split(xbc_t, [di, di + g * n], axis=-1)
    p = s_cfg.head_dim
    xh = xh.reshape(b, h, p)
    bm = bm.reshape(b, g, n)
    cm = cm.reshape(b, g, n)
    rep = h // g
    bmr = jnp.repeat(bm, rep, axis=1)  # (B, H, N)
    cmr = jnp.repeat(cm, rep, axis=1)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A[None, :])  # (B, H)
    upd = (dt[..., None] * xh.astype(jnp.float32))[..., None] * bmr[:, :, None, :].astype(jnp.float32)
    state = cache["state"] * decay[..., None, None] + upd  # (B,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", state, cmr.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(b, di)
    yz = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yz), axis=-1, keepdims=True)
    yz = yz * jax.lax.rsqrt(ms + cfg.norm_eps)
    yz = (yz * params["norm_scale"].astype(jnp.float32)).astype(u.dtype)
    out = (yz @ params["out_proj"])[:, None, :]
    return out, {"state": state, "conv": new_conv}
