"""Decoder/encoder blocks and the layer-kind layout machinery.

Every layer of an architecture is described by a ``LayerKind`` (mixer × ffn).
Architectures with repeating structure (all of the assigned ten) are laid out
as ``repeats × period``: parameters are stacked over the repeat axis per
period-position, and the forward pass is a ``lax.scan`` over repeats with the
(short, heterogeneous) period unrolled inside. This keeps HLO size and compile
time O(period), not O(num_layers) — essential for the 61–72-layer cards.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    dtype_of,
    init_mlp,
    init_norm,
)


@dataclass(frozen=True)
class LayerKind:
    mixer: str  # "attn" | "ssm"
    ffn: str    # "dense" | "moe" | "none"
    cross: bool = False  # enc-dec decoder layers carry a cross-attention


def layer_kinds(cfg) -> List[LayerKind]:
    kinds = []
    for i in range(cfg.num_layers):
        mixer = "attn" if cfg.is_attn_layer(i) else "ssm"
        if cfg.is_moe_layer(i):
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        kinds.append(LayerKind(mixer, ffn, cross=cfg.arch_type == "encdec"))
    return kinds


def layout(cfg) -> Tuple[int, int, List[LayerKind]]:
    """→ (repeats, period, kinds-of-one-period)."""
    kinds = layer_kinds(cfg)
    n = len(kinds)
    for period in range(1, n + 1):
        if n % period:
            continue
        if all(kinds[i] == kinds[i % period] for i in range(n)):
            return n // period, period, kinds[:period]
    return 1, n, kinds


# ------------------------------------------------------------------ init
def init_layer(key, cfg, kind: LayerKind) -> dict:
    dt = dtype_of(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    p = {"norm_mixer": init_norm(d, cfg.norm, dt)}
    if kind.mixer == "attn":
        p["attn"] = attn.init_attention(keys[0], cfg)
    else:
        p["ssm"] = ssm_mod.init_ssm(keys[1], cfg)
    if kind.cross:
        p["norm_cross"] = init_norm(d, cfg.norm, dt)
        p["cross_attn"] = attn.init_attention(keys[2], cfg, cross=True)
    if kind.ffn != "none":
        p["norm_ffn"] = init_norm(d, cfg.norm, dt)
    if kind.ffn == "dense":
        p["mlp"] = init_mlp(keys[3], d, cfg.d_ff, cfg.act, dt, bias=cfg.qkv_bias)
    elif kind.ffn == "moe":
        p["moe"] = moe_mod.init_moe(keys[4], cfg, d)
    return p


# ------------------------------------------------------------------ apply
def apply_layer(
    p: dict,
    cfg,
    kind: LayerKind,
    h: jnp.ndarray,
    *,
    positions=None,
    encoder_out: Optional[jnp.ndarray] = None,
    causal: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence layer (train / prefill / encoder). → (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = apply_norm(p["norm_mixer"], h, cfg.norm_eps)
    if kind.mixer == "attn":
        y = attn.attention(p["attn"], cfg, x, positions=positions, causal=causal)
    else:
        y, _ = ssm_mod.ssm_block(p["ssm"], cfg, x)
    h = h + y
    if kind.cross and encoder_out is not None:
        x = apply_norm(p["norm_cross"], h, cfg.norm_eps)
        y = attn.attention(p["cross_attn"], cfg, x, kv_x=encoder_out, causal=False)
        h = h + y
    if kind.ffn == "dense":
        x = apply_norm(p["norm_ffn"], h, cfg.norm_eps)
        h = h + apply_mlp(p["mlp"], x, cfg.act)
    elif kind.ffn == "moe":
        x = apply_norm(p["norm_ffn"], h, cfg.norm_eps)
        y, aux = moe_mod.apply_moe(p["moe"], x, cfg)
        h = h + y
    return h, aux


def init_layer_cache(cfg, kind: LayerKind, batch: int, cache_len: int, dtype) -> dict:
    c = {}
    if kind.mixer == "attn":
        c["kv"] = attn.init_kv_cache(cfg, batch, cache_len, dtype)
    else:
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if kind.cross:
        shape = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
        c["cross_kv"] = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return c


def decode_layer(
    p: dict,
    cfg,
    kind: LayerKind,
    h: jnp.ndarray,
    cache: dict,
    cache_pos,
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode through a layer. h: (B, 1, d)."""
    new_cache = {}
    x = apply_norm(p["norm_mixer"], h, cfg.norm_eps)
    if kind.mixer == "attn":
        y, new_cache["kv"] = attn.decode_attention(
            p["attn"], cfg, x, cache["kv"], cache_pos
        )
    else:
        y, new_cache["ssm"] = ssm_mod.ssm_decode_step(p["ssm"], cfg, x, cache["ssm"])
    h = h + y
    if kind.cross:
        x = apply_norm(p["norm_cross"], h, cfg.norm_eps)
        y, _ = attn.decode_attention(
            p["cross_attn"], cfg, x, {}, cache_pos, kv_memory=cache["cross_kv"]
        )
        h = h + y
        new_cache["cross_kv"] = cache["cross_kv"]
    if kind.ffn == "dense":
        x = apply_norm(p["norm_ffn"], h, cfg.norm_eps)
        h = h + apply_mlp(p["mlp"], x, cfg.act)
    elif kind.ffn == "moe":
        x = apply_norm(p["norm_ffn"], h, cfg.norm_eps)
        y, _ = moe_mod.apply_moe(p["moe"], x, cfg)
        h = h + y
    return h, new_cache
