from repro.models.model import (  # noqa: F401
    build_model,
    init_params,
    forward,
    init_cache,
)
