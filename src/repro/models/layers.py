"""Primitive layers: norms, linear, embedding, RoPE, MLP.

Pure-functional convention used across the substrate:
  ``init_<layer>(key, ...) -> params``  (nested dict of jnp arrays)
  ``<layer>(params, x, ...) -> y``
Params are stored in ``cfg.dtype`` (bf16 in production); norms and softmax
accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- norms
def init_norm(d: int, norm: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype=dtype)}
    if norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=dtype)
    return p


def apply_norm(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- linear
def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False) -> dict:
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
    w = (w / jnp.sqrt(d_in)).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def apply_linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    tbl = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"table": tbl.astype(dtype)}


def apply_embedding(p: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: x @ table.T → logits (accumulated in fp32)."""
    return jnp.einsum(
        "...d,vd->...v", x, p["table"], preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # (head_dim//2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    freqs = rope_freqs(x.shape[-1], theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)  # (S, d)


# ---------------------------------------------------------------- MLP
def init_mlp(key, d: int, d_ff: int, act: str, dtype, *, bias: bool = False) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": init_linear(k1, d, d_ff, dtype, bias=bias),
        "down": init_linear(k2, d_ff, d, dtype, bias=bias),
    }
    if act == "silu":  # SwiGLU
        p["gate"] = init_linear(k3, d, d_ff, dtype, bias=bias)
    return p


def apply_mlp(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = apply_linear(p["up"], x)
    if act == "silu":
        h = jax.nn.silu(apply_linear(p["gate"], x)) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown act {act!r}")
    return apply_linear(p["down"], h)
