"""Model builder: init / forward / prefill / decode for every arch family.

Layers are stacked ``repeats × period`` (see blocks.layout) and executed with
``lax.scan`` over the repeat axis; the heterogeneous period (e.g. Jamba's
7 mamba + 1 attention) is unrolled inside the scan body. ``cfg.remat`` wraps
the scan body in ``jax.checkpoint`` for training.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.layers import (
    apply_embedding,
    apply_linear,
    apply_norm,
    dtype_of,
    init_embedding,
    init_linear,
    init_norm,
    sinusoidal_positions,
    unembed,
)

ENCODER_KIND = blocks.LayerKind("attn", "dense", cross=False)


# ------------------------------------------------------------------ init
def _init_layer_stacks(key, cfg, kinds, repeats):
    stacks = []
    for p, kind in enumerate(kinds):
        keys = jax.random.split(jax.random.fold_in(key, p), repeats)
        stacks.append(jax.vmap(lambda k, kd=kind: blocks.init_layer(k, cfg, kd))(keys))
    return stacks


def init_params(key, cfg) -> dict:
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 8)
    repeats, period, kinds = blocks.layout(cfg)
    params = {
        "embed": init_embedding(keys[0], cfg.padded_vocab, cfg.d_model, dt),
        "layers": _init_layer_stacks(keys[1], cfg, kinds, repeats),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_linear(keys[2], cfg.d_model, cfg.padded_vocab, dt)
    if cfg.learned_pos_emb:
        params["pos_emb"] = (
            jax.random.normal(keys[3], (cfg.learned_pos_emb, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(dt)
    if cfg.encoder_layers:
        ekeys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = {
            "layers": [
                jax.vmap(lambda k: blocks.init_layer(k, cfg, ENCODER_KIND))(ekeys)
            ],
            "final_norm": init_norm(cfg.d_model, cfg.norm, dt),
            "frame_proj": init_linear(keys[5], cfg.d_model, cfg.d_model, dt),
        }
    if cfg.num_patches:
        params["patch_proj"] = init_linear(keys[6], cfg.d_model, cfg.d_model, dt)
    return params


def build_model(cfg):
    """Convenience: returns (init_fn, forward_fn) closed over cfg."""
    return (lambda key: init_params(key, cfg)), (
        lambda params, tokens, **kw: forward(params, cfg, tokens, **kw)
    )


# ------------------------------------------------------------------ scan body
def _run_layers(
    params_stacks,
    cfg,
    kinds,
    h,
    *,
    positions=None,
    encoder_out=None,
    causal=True,
):
    """scan over repeats, unrolled period inside. → (h, aux_sum)."""

    def body(carry, xs):
        hh, aux = carry
        for p, kind in enumerate(kinds):
            hh, a = blocks.apply_layer(
                xs[p],
                cfg,
                kind,
                hh,
                positions=positions,
                encoder_out=encoder_out,
                causal=causal,
            )
            aux = aux + a
        return (hh, aux), None

    if cfg.remat:
        policy = None
        if cfg.remat_policy == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), tuple(params_stacks))
    return h, aux


def _encode(params, cfg, frames):
    """Whisper-style encoder over stubbed frame embeddings (B, Senc, d)."""
    enc = params["encoder"]
    h = apply_linear(enc["frame_proj"], frames)
    h = h + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(h.dtype)[None]
    h, _ = _run_layers(enc["layers"], cfg, [ENCODER_KIND], h, causal=False)
    return apply_norm(enc["final_norm"], h, cfg.norm_eps)


def _embed_inputs(params, cfg, tokens, patches, positions):
    h = apply_embedding(params["embed"], tokens)
    if cfg.learned_pos_emb:
        if positions is None:
            positions = jnp.arange(tokens.shape[1])[None, :]
        # clamp: serving shapes can exceed the card's learned-position table
        positions = jnp.minimum(positions, cfg.learned_pos_emb - 1)
        pe = jnp.take(params["pos_emb"], positions, axis=0)  # (B|1, S, d)
        h = h + jnp.broadcast_to(pe, h.shape)
    if cfg.num_patches and patches is not None:
        vis = apply_linear(params["patch_proj"], patches.astype(h.dtype))
        h = jnp.concatenate([vis, h], axis=1)
    return h


def _logits(params, cfg, h):
    h = apply_norm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"], h)
    return apply_linear(params["unembed"], h).astype(jnp.float32)


def forward(
    params: dict,
    cfg,
    tokens: jnp.ndarray,
    *,
    frames: Optional[jnp.ndarray] = None,
    patches: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
    return_hidden: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward. tokens: (B, S) → (logits (B, S', V) fp32, moe_aux)."""
    repeats, period, kinds = blocks.layout(cfg)
    encoder_out = _encode(params, cfg, frames) if cfg.encoder_layers else None
    h = _embed_inputs(params, cfg, tokens, patches, positions)
    h, aux = _run_layers(
        params["layers"], cfg, kinds, h, positions=positions, encoder_out=encoder_out
    )
    if return_hidden:
        h = apply_norm(params["final_norm"], h, cfg.norm_eps)
        return h, aux
    return _logits(params, cfg, h), aux


# ------------------------------------------------------------------ cache
def init_cache(cfg, batch: int, cache_len: int, dtype=None) -> dict:
    dtype = dtype or dtype_of(cfg)
    repeats, period, kinds = blocks.layout(cfg)

    def per_pos(kind):
        one = blocks.init_layer_cache(cfg, kind, batch, cache_len, dtype)
        return jax.tree.map(
            lambda x: jnp.zeros((repeats,) + x.shape, x.dtype), one
        )

    return {"layers": [per_pos(kind) for kind in kinds]}


def decode_step(
    params: dict,
    cfg,
    token: jnp.ndarray,  # (B, 1) int32
    cache: dict,
    cache_pos: jnp.ndarray,  # scalar int32: next write position
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode against the cache. → (logits (B, 1, V), new cache)."""
    repeats, period, kinds = blocks.layout(cfg)
    positions = None
    if cfg.learned_pos_emb:
        positions = jnp.full((token.shape[0], 1), cache_pos)
    h = _embed_inputs(params, cfg, token, None, positions)

    def body(hh, xs):
        new_slices = []
        for p, kind in enumerate(kinds):
            hh, nc = blocks.decode_layer(xs[0][p], cfg, kind, hh, xs[1][p], cache_pos)
            new_slices.append(nc)
        return hh, tuple(new_slices)

    h, new_caches = jax.lax.scan(
        body, h, (tuple(params["layers"]), tuple(cache["layers"]))
    )
    return _logits(params, cfg, h), {"layers": list(new_caches)}


def prefill(
    params: dict,
    cfg,
    tokens: jnp.ndarray,
    cache: dict,
    *,
    frames: Optional[jnp.ndarray] = None,
    patches: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, dict]:
    """Prefill: full forward that populates the cache prefix.

    Implemented as full attention plus cache writes; SSM layers write their
    final scan state. Returns last-position logits and the filled cache.
    """
    repeats, period, kinds = blocks.layout(cfg)
    encoder_out = _encode(params, cfg, frames) if cfg.encoder_layers else None
    h = _embed_inputs(params, cfg, tokens, patches, None)

    from repro.models import attention as attn_mod
    from repro.models import ssm as ssm_mod
    from repro.models.layers import apply_mlp

    def body(hh, xs):
        pstacks, cstacks = xs
        new_slices = []
        for p, kind in enumerate(kinds):
            lp, lc = pstacks[p], cstacks[p]
            nc = {}
            x = apply_norm(lp["norm_mixer"], hh, cfg.norm_eps)
            if kind.mixer == "attn":
                y, nc["kv"] = attn_mod.prefill_attention(lp["attn"], cfg, x, lc["kv"])
            else:
                y, nc["ssm"] = ssm_mod.ssm_prefill(lp["ssm"], cfg, x, lc["ssm"])
            hh = hh + y
            if kind.cross and encoder_out is not None:
                x = apply_norm(lp["norm_cross"], hh, cfg.norm_eps)
                y = attn_mod.attention(
                    lp["cross_attn"], cfg, x, kv_x=encoder_out, causal=False
                )
                hh = hh + y
                # precompute encoder K/V once for all later decode steps
                ck = attn_mod._split_heads(
                    attn_mod.apply_linear(lp["cross_attn"]["wk"], encoder_out),
                    cfg.num_kv_heads, cfg.head_dim,
                )
                cv = attn_mod._split_heads(
                    attn_mod.apply_linear(lp["cross_attn"]["wv"], encoder_out),
                    cfg.num_kv_heads, cfg.head_dim,
                )
                nc["cross_kv"] = {
                    "k": ck.astype(lc["cross_kv"]["k"].dtype),
                    "v": cv.astype(lc["cross_kv"]["v"].dtype),
                }
            if kind.ffn == "dense":
                x = apply_norm(lp["norm_ffn"], hh, cfg.norm_eps)
                hh = hh + apply_mlp(lp["mlp"], x, cfg.act)
            elif kind.ffn == "moe":
                from repro.models import moe as moe_mod

                x = apply_norm(lp["norm_ffn"], hh, cfg.norm_eps)
                y, _ = moe_mod.apply_moe(lp["moe"], x, cfg)
                hh = hh + y
            new_slices.append(nc)
        return hh, tuple(new_slices)

    h, new_caches = jax.lax.scan(
        body, h, (tuple(params["layers"]), tuple(cache["layers"]))
    )
    logits = _logits(params, cfg, h[:, -1:, :])
    return logits, {"layers": list(new_caches)}
