"""Grouped-query attention with qk-norm, QKV-bias, RoPE, sliding window,
cross-attention (enc-dec), and single-token decode against a KV cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (
    apply_linear,
    apply_norm,
    apply_rope,
    dtype_of,
    init_linear,
    init_norm,
)

NEG_INF = -1e30


def init_attention(key, cfg, *, cross: bool = False) -> dict:
    d, dt = cfg.d_model, dtype_of(cfg)
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_linear(kq, d, cfg.q_dim, dt, bias=cfg.qkv_bias),
        "wk": init_linear(kk, d, cfg.kv_dim, dt, bias=cfg.qkv_bias),
        "wv": init_linear(kv, d, cfg.kv_dim, dt, bias=cfg.qkv_bias),
        "wo": init_linear(ko, cfg.q_dim, d, dt, bias=cfg.qkv_bias),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = init_norm(cfg.head_dim, "rmsnorm", dt)
        p["k_norm"] = init_norm(cfg.head_dim, "rmsnorm", dt)
    return p


def _split_heads(x: jnp.ndarray, n: int, dh: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], n, dh)


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """q: (B,S,H,Dh), k: (B,T,KV,Dh) → scores (B,KV,G,S,T) fp32."""
    b, s, h, dh = q.shape
    g = h // n_kv
    qg = q.reshape(b, s, n_kv, g, dh)
    return jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    )


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs: (B,KV,G,S,T), v: (B,T,KV,Dh) → (B,S,H*Dh)."""
    b, kv, g, s, t = probs.shape
    o = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return o.reshape(b, s, kv * g * v.shape[-1])


# Above this sequence length the dense (S×T) score tensor is replaced by the
# flash-style two-level scan below (identical math, O(block²) live memory).
CHUNKED_ATTN_THRESHOLD = 8192


def _chunked_gqa_attention(
    q: jnp.ndarray,  # (B, S, H, Dh)
    k: jnp.ndarray,  # (B, T, KV, Dh)
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int,
    scale: float,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    """XLA flash attention: scan over q chunks; inner scan over k chunks with
    running (max, denom, acc). Live memory per step is O(q_chunk·k_chunk) per
    head — this is what lets prefill_32k lower within HBM."""
    import math as _math

    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    # largest chunk ≤ requested that divides the sequence (VLM prefix shifts
    # the length off the power-of-two grid, e.g. 32768 + 256 patches); short
    # axes (e.g. a 1500-frame cross-attention memory) stay single-chunk.
    def _pick(n, want):
        if n <= want:
            return n
        for c in range(want, 0, -1):  # largest divisor of n that is ≤ want
            if n % c == 0:
                return c

    qc = _pick(s, q_chunk)
    kc = _pick(t, k_chunk)
    assert qc * 8 >= min(s, q_chunk), (s, qc)
    assert kc * 8 >= min(t, k_chunk), (t, kc)
    nq, nk = s // qc, t // kc
    qg = q.reshape(b, nq, qc, kv, g, dh).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,KV,G,qc,Dh)
    kg = k.reshape(b, nk, kc, kv, dh).transpose(1, 0, 3, 2, 4)  # (nk,B,KV,kc,Dh)
    vg = v.reshape(b, nk, kc, kv, dh).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block  # qblk (B,KV,G,qc,Dh)

        def k_step(carry, ki_and_kv):
            m_prev, l_prev, acc = carry
            ki, kblk, vblk = ki_and_kv
            sc = (
                jnp.einsum(
                    "bkgqd,bktd->bkgqt", qblk.astype(jnp.float32),
                    kblk.astype(jnp.float32),
                )
                * scale
            )
            qpos = qi * qc + jnp.arange(qc)[:, None]
            kpos = ki * kc + jnp.arange(kc)[None, :]
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kpos <= qpos
            if window > 0:
                mask &= (qpos - kpos) < window
            sc = jnp.where(mask[None, None, None], sc, NEG_INF)
            m_cur = jnp.max(sc, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(sc - m_new[..., None])
            p = jnp.where((m_new == NEG_INF)[..., None], 0.0, p)
            alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
            l_new = alpha * l_prev + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kg, vg)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # outs: (nq, B, KV, G, qc, Dh) → (B, S, H*Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h * dh)
    return out


def attention(
    params: dict,
    cfg,
    x: jnp.ndarray,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    kv_x: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, _ = x.shape
    src = kv_x if kv_x is not None else x
    t = src.shape[1]
    q = _split_heads(apply_linear(params["wq"], x), cfg.num_heads, cfg.head_dim)
    k = _split_heads(apply_linear(params["wk"], src), cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(apply_linear(params["wv"], src), cfg.num_kv_heads, cfg.head_dim)
    if "q_norm" in params:
        q = apply_norm(params["q_norm"], q, cfg.norm_eps)
        k = apply_norm(params["k_norm"], k, cfg.norm_eps)
    if kv_x is None and cfg.rope_theta > 0:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if max(s, t) > CHUNKED_ATTN_THRESHOLD:
        ctx = _chunked_gqa_attention(
            q, k, v,
            causal=causal and kv_x is None,
            window=cfg.sliding_window if kv_x is None else 0,
            scale=1.0 / float(cfg.head_dim) ** 0.5,
        ).astype(x.dtype)
        return apply_linear(params["wo"], ctx)
    scores = _gqa_scores(q, k, cfg.num_kv_heads) / jnp.sqrt(cfg.head_dim)
    if causal and kv_x is None:
        si = jnp.arange(s)[:, None]
        ti = jnp.arange(t)[None, :]
        mask = ti <= si
        if cfg.sliding_window:
            mask &= (si - ti) < cfg.sliding_window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return apply_linear(params["wo"], _gqa_out(probs, v))


def init_kv_cache(cfg, batch: int, cache_len: int, dtype) -> dict:
    shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill_attention(
    params: dict, cfg, x: jnp.ndarray, cache: dict, *, positions=None
) -> Tuple[jnp.ndarray, dict]:
    """Full attention that also writes K/V into the cache prefix."""
    b, s, _ = x.shape
    src = x
    q = _split_heads(apply_linear(params["wq"], x), cfg.num_heads, cfg.head_dim)
    k = _split_heads(apply_linear(params["wk"], src), cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(apply_linear(params["wv"], src), cfg.num_kv_heads, cfg.head_dim)
    if "q_norm" in params:
        q = apply_norm(params["q_norm"], q, cfg.norm_eps)
        k = apply_norm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope_theta > 0:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if s > CHUNKED_ATTN_THRESHOLD:
        ctx = _chunked_gqa_attention(
            q, k, v, causal=True, window=cfg.sliding_window,
            scale=1.0 / float(cfg.head_dim) ** 0.5,
        ).astype(x.dtype)
        y = apply_linear(params["wo"], ctx)
    else:
        scores = _gqa_scores(q, k, cfg.num_kv_heads) / jnp.sqrt(cfg.head_dim)
        si = jnp.arange(s)[:, None]
        ti = jnp.arange(s)[None, :]
        mask = ti <= si
        if cfg.sliding_window:
            mask &= (si - ti) < cfg.sliding_window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        y = apply_linear(params["wo"], _gqa_out(probs, v))
    new_cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=1
        ),
        "v": jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=1
        ),
    }
    return y, new_cache


def decode_attention(
    params: dict,
    cfg,
    x: jnp.ndarray,
    cache: dict,
    cache_pos: jnp.ndarray,
    *,
    kv_memory: Optional[dict] = None,
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode: x (B,1,d); cache k/v (B,T,KV,Dh); cache_pos scalar.

    ``kv_memory`` (cross-attention): precomputed encoder K/V — cache untouched.
    """
    b = x.shape[0]
    q = _split_heads(apply_linear(params["wq"], x), cfg.num_heads, cfg.head_dim)
    if kv_memory is not None:
        k, v = kv_memory["k"], kv_memory["v"]
        new_cache = cache
        t = k.shape[1]
        valid = jnp.ones((t,), dtype=bool)
    else:
        k1 = _split_heads(apply_linear(params["wk"], x), cfg.num_kv_heads, cfg.head_dim)
        v1 = _split_heads(apply_linear(params["wv"], x), cfg.num_kv_heads, cfg.head_dim)
        if "q_norm" in params:
            q = apply_norm(params["q_norm"], q, cfg.norm_eps)
            k1 = apply_norm(params["k_norm"], k1, cfg.norm_eps)
        if cfg.rope_theta > 0:
            pos = jnp.full((b, 1), cache_pos)
            q = apply_rope(q, pos, cfg.rope_theta)
            k1 = apply_rope(k1, pos, cfg.rope_theta)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k1.astype(cache["k"].dtype), cache_pos, axis=1
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v1.astype(cache["v"].dtype), cache_pos, axis=1
        )
        new_cache = {"k": k, "v": v}
        t = k.shape[1]
        ti = jnp.arange(t)
        valid = ti <= cache_pos
        if cfg.sliding_window:
            valid &= (cache_pos - ti) < cfg.sliding_window
    scores = _gqa_scores(q, k, cfg.num_kv_heads) / jnp.sqrt(cfg.head_dim)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    y = apply_linear(params["wo"], _gqa_out(probs, v))
    return y, new_cache
