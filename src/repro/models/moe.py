"""Top-k mixture-of-experts with capacity-based dispatch.

Expert-parallel layout: the expert axis of the stacked expert weights is
sharded over the mesh 'data' axis (expert parallelism) and the per-expert
hidden dim over 'model'; token→expert resharding then lowers to all-to-all /
collective traffic, which the roofline pass measures.

Dispatch is scatter-based (Megablocks-style), not one-hot-matmul-based, so it
scales to 384-expert configs: positions-in-expert come from a cumsum over the
(tokens·k, E) assignment one-hot, and tokens are scattered into an (E, C, d)
buffer. Tokens over capacity C are dropped (standard capacity-factor
semantics); the residual path keeps them intact.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of
from repro.sharding.context import shard_map_compat


def init_moe(key, cfg, d: int) -> dict:
    m = cfg.moe
    dt = dtype_of(cfg)
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, f = m.num_experts, m.d_ff
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(kr, (d, e), jnp.float32) * scale_in).astype(
            jnp.float32
        ),
        "w_gate": (jax.random.normal(kg, (e, d, f), jnp.float32) * scale_in).astype(dt),
        "w_up": (jax.random.normal(ku, (e, d, f), jnp.float32) * scale_in).astype(dt),
        "w_down": (jax.random.normal(kd, (e, f, d), jnp.float32) * scale_out).astype(dt),
    }
    if m.num_shared_experts:
        se = m.num_shared_experts
        p["shared_gate"] = (
            jax.random.normal(ks, (se, d, f), jnp.float32) * scale_in
        ).astype(dt)
        k2, k3 = jax.random.split(ks)
        p["shared_up"] = (
            jax.random.normal(k2, (se, d, f), jnp.float32) * scale_in
        ).astype(dt)
        p["shared_down"] = (
            jax.random.normal(k3, (se, f, d), jnp.float32) * scale_out
        ).astype(dt)
    return p


def capacity(num_tokens: int, cfg) -> int:
    m = cfg.moe
    c = math.ceil(m.experts_per_token * num_tokens / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def apply_moe(params: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch on ``cfg.moe.impl``: 'gather' (pure pjit, baseline) or
    'alltoall' (shard_map expert parallelism — §Perf). Falls back to gather
    when no mesh is set (CPU tests) or experts don't divide the data axis."""
    from repro.sharding import context as shard_ctx

    if getattr(cfg.moe, "impl", "gather") == "alltoall":
        mesh = shard_ctx.get_mesh()
        if mesh is not None and cfg.moe.num_experts % mesh.shape["data"] == 0:
            shards = 1
            for ax in shard_ctx.batch_axes():
                shards *= mesh.shape[ax]
            # shard_map needs the batch dim to divide the batch mesh axes
            # (fails for decode B=1 or small microbatches on multi-pod)
            if x.shape[0] % shards == 0:
                return apply_moe_alltoall(params, x, cfg, mesh)
    return apply_moe_gather(params, x, cfg)


def apply_moe_gather(params: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (y, aux_loss). Routing in fp32."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.experts_per_token
    e = m.num_experts
    cap = capacity(t, cfg)

    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0
    )  # fraction routed per expert
    aux = e * jnp.sum(me * ce) * m.aux_loss_weight

    # ---- dispatch: position of each routed assignment within its expert ----
    flat_idx = idx.reshape(t * k)  # token-major
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (T·k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    pos_in_expert = jnp.take_along_axis(pos, flat_idx[:, None], axis=1)[:, 0]
    keep = pos_in_expert < cap
    dest = jnp.where(keep, flat_idx * cap + pos_in_expert, e * cap)  # drop row

    tok_of = jnp.arange(t * k) // k
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    buf = buf.at[dest].set(xf[tok_of], mode="drop")
    hidden_in = buf[: e * cap].reshape(e, cap, d)

    # ---- expert compute (E, C, d) × (E, d, f) ----
    h_gate = jnp.einsum("ecd,edf->ecf", hidden_in, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", hidden_in, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(e * cap, d)

    # ---- combine ----
    y_routed = out[jnp.clip(dest, 0, e * cap - 1)]
    w = (gate.reshape(t * k) * keep).astype(x.dtype)
    y = jnp.zeros((t, d), dtype=x.dtype).at[tok_of].add(y_routed * w[:, None])

    if m.num_shared_experts:
        hg = jnp.einsum("td,edf->tef", xf, params["shared_gate"])
        hu = jnp.einsum("td,edf->tef", xf, params["shared_up"])
        hs = jax.nn.silu(hg) * hu
        y = y + jnp.einsum("tef,efd->td", hs, params["shared_down"])

    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------------
# shard_map expert-parallel implementation (§Perf): tokens are routed LOCALLY
# per data shard and exchanged with the expert-owning shard via exactly one
# all_to_all each way (plus the transposed pair in backward). Under pure pjit
# the scatter/gather dispatch above lowers to full-activation all-reduces and
# collective-permutes per layer (measured 22.8 TB/device/step on
# kimi-k2 × train_4k); this implementation moves only the routed token
# payloads: tokens·top_k·d bytes per layer.
# --------------------------------------------------------------------------
def _dispatch_positions(ids: jnp.ndarray, n_buckets: int, cap: int):
    """ids (N,) → (keep, dest) packing each id's rows into per-bucket slots
    of ``cap``; dest == n_buckets*cap is the drop row."""
    onehot = jax.nn.one_hot(ids, n_buckets, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos, ids[:, None], axis=1)[:, 0]
    keep = (pos < cap) & (ids >= 0)
    dest = jnp.where(keep, ids * cap + pos, n_buckets * cap)
    return keep, dest


def apply_moe_alltoall(
    params: dict, x: jnp.ndarray, cfg, mesh
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from jax.sharding import PartitionSpec as P

    from repro.sharding import context as shard_ctx

    m = cfg.moe
    b, s, d = x.shape
    bx = shard_ctx.batch_axes()  # ("data",) or ("pod", "data")
    dsize = mesh.shape["data"]
    e_local = m.num_experts // dsize
    k = m.experts_per_token

    route_groups = m.route_groups if 0 < m.route_groups < dsize else 0

    def local_fn(router, w_gate, w_up, w_down, shared, xl):
        # xl: (b_l, s, d); w_gate/w_up: (E_l, d, f_l); w_down: (E_l, f_l, d)
        bl = xl.shape[0]
        tl = bl * s
        xf = xl.reshape(tl, d)
        logits = xf.astype(jnp.float32) @ router  # (T_l, E) — router replicated
        probs = jax.nn.softmax(logits, axis=-1)
        if route_groups:
            # node-limited routing (DeepSeek-V3 / K2): only experts on the
            # token's top-G data shards are eligible.
            gscore = jnp.max(probs.reshape(tl, dsize, e_local), axis=-1)
            _, gsel = jax.lax.top_k(gscore, route_groups)  # (T_l, G)
            allowed = jnp.zeros((tl, dsize), bool).at[
                jnp.arange(tl)[:, None], gsel
            ].set(True)
            probs = jnp.where(
                jnp.repeat(allowed, e_local, axis=1), probs, 0.0
            )
        gate, idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

        # load-balance aux, averaged over the batch axes
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(
            jnp.sum(jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32), axis=1),
            axis=0,
        )
        aux = m.num_experts * jnp.sum(me * ce) * m.aux_loss_weight
        for ax in bx:
            aux = jax.lax.pmean(aux, ax)

        if shared is not None:
            sg, su, sd = shared
            hsg = jnp.einsum("td,edf->tef", xf, sg)
            hsu = jnp.einsum("td,edf->tef", xf, su)
            y_shared = jnp.einsum(
                "tef,efd->td", jax.nn.silu(hsg) * hsu, sd
            )  # partial over f_l

        if route_groups:
            # ---- deduplicated dispatch: ONE send per (token, group) --------
            # gates for the token's experts, laid out per (group, local expert)
            gmat = jnp.zeros((tl, m.num_experts), jnp.float32)
            gmat = gmat.at[jnp.arange(tl)[:, None], idx].set(gate)
            gm = jnp.take_along_axis(
                gmat.reshape(tl, dsize, e_local), gsel[..., None], axis=1
            ).reshape(tl * route_groups, e_local)  # (T_l·G, E_l)
            ids1 = gsel.reshape(tl * route_groups)
            tok_of1 = jnp.arange(tl * route_groups) // route_groups
            cap1 = max(8, -(-int(tl * route_groups / dsize * m.capacity_factor) // 8) * 8)
            keep1, dest1 = _dispatch_positions(ids1, dsize, cap1)
            payload = jnp.concatenate([xf[tok_of1], gm.astype(xf.dtype)], axis=1)
            buf = jnp.zeros((dsize * cap1 + 1, d + e_local), xf.dtype)
            buf = buf.at[dest1].set(jnp.where(keep1[:, None], payload, 0), mode="drop")
            send = buf[: dsize * cap1].reshape(dsize, cap1, d + e_local)
            recv = jax.lax.all_to_all(send, "data", split_axis=0, concat_axis=0)

            rx = recv.reshape(dsize * cap1, d + e_local)
            x_r, g_r = rx[:, :d], rx[:, d:].astype(jnp.float32)  # (T2, E_l)
            t2 = dsize * cap1
            # (recv slot, local expert) pairs with nonzero gate
            ids2 = jnp.where(
                g_r > 0, jnp.arange(e_local)[None, :], -1
            ).reshape(t2 * e_local)
            pair_tok = jnp.arange(t2 * e_local) // e_local
            cap2 = max(
                8,
                -(-int(t2 * min(k, e_local) / (route_groups * e_local)
                       * m.capacity_factor) // 8) * 8,
            )
            keep2, dest2 = _dispatch_positions(ids2, e_local, cap2)
            buf2 = jnp.zeros((e_local * cap2 + 1, d), x_r.dtype)
            buf2 = buf2.at[dest2].set(
                jnp.where(keep2[:, None], x_r[pair_tok], 0), mode="drop"
            )
            hidden = buf2[: e_local * cap2].reshape(e_local, cap2, d)
            hg = jnp.einsum("ecd,edf->ecf", hidden, w_gate)
            hu = jnp.einsum("ecd,edf->ecf", hidden, w_up)
            h = jax.nn.silu(hg) * hu
            # NOTE: the model-axis reduction of the f_l partial sums is
            # DELAYED to the very end (§Perf kimi v6): gather/scale/scatter/
            # all_to_all are all linear, so the psum commutes — reducing the
            # (T_l, d) token outputs instead of the (E_l, cap2, d) expert
            # buffer cuts the psum payload ~26×.
            out_flat = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(
                e_local * cap2, d
            )  # partial over f_l
            y_pairs = out_flat[jnp.clip(dest2, 0, e_local * cap2 - 1)]
            wts = (g_r.reshape(-1) * keep2).astype(x_r.dtype)
            y_slot = jnp.zeros((t2, d), x_r.dtype).at[pair_tok].add(
                y_pairs * wts[:, None]
            )
            y_ret = jax.lax.all_to_all(
                y_slot.reshape(dsize, cap1, d), "data", split_axis=0, concat_axis=0
            ).reshape(dsize * cap1, d)
            y_routed = y_ret[jnp.clip(dest1, 0, dsize * cap1 - 1)] * keep1[:, None]
            y = jnp.zeros((tl, d), xl.dtype).at[tok_of1].add(y_routed.astype(xl.dtype))
        else:
            # ---- stage 1: one send per (token, expert), exchange ------------
            flat_idx = idx.reshape(tl * k)
            group = flat_idx // e_local           # destination data shard
            e_loc = flat_idx % e_local            # expert id on that shard
            tok_of = jnp.arange(tl * k) // k
            cap1 = max(8, -(-int(tl * k / dsize * m.capacity_factor) // 8) * 8)
            keep1, dest1 = _dispatch_positions(group, dsize, cap1)

            payload = jnp.concatenate(
                [xf[tok_of], (e_loc + 1).astype(xf.dtype)[:, None]], axis=1
            )  # channel d carries the local-expert id (+1; 0 = pad)
            buf = jnp.zeros((dsize * cap1 + 1, d + 1), xf.dtype)
            buf = buf.at[dest1].set(jnp.where(keep1[:, None], payload, 0), mode="drop")
            send = buf[: dsize * cap1].reshape(dsize, cap1, d + 1)
            recv = jax.lax.all_to_all(send, "data", split_axis=0, concat_axis=0)

            # ---- stage 2: local expert compute ------------------------------
            rx = recv.reshape(dsize * cap1, d + 1)
            x_r = rx[:, :d]
            e_r = jnp.round(rx[:, d].astype(jnp.float32)).astype(jnp.int32) - 1
            t2 = dsize * cap1
            cap2 = max(8, -(-int(t2 / e_local * m.capacity_factor) // 8) * 8)
            keep2, dest2 = _dispatch_positions(e_r, e_local, cap2)
            buf2 = jnp.zeros((e_local * cap2 + 1, d), x_r.dtype)
            buf2 = buf2.at[dest2].set(jnp.where(keep2[:, None], x_r, 0), mode="drop")
            hidden = buf2[: e_local * cap2].reshape(e_local, cap2, d)

            hg = jnp.einsum("ecd,edf->ecf", hidden, w_gate)
            hu = jnp.einsum("ecd,edf->ecf", hidden, w_up)
            h = jax.nn.silu(hg) * hu
            # f_l partial sums carried through the (linear) combine; reduced
            # once on the (T_l, d) outputs at the end — see grouped branch.
            out_flat = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(
                e_local * cap2, d
            )

            y_r = out_flat[jnp.clip(dest2, 0, e_local * cap2 - 1)] * keep2[:, None]
            y_back = y_r.reshape(dsize, cap1, d)
            y_ret = jax.lax.all_to_all(y_back, "data", split_axis=0, concat_axis=0)
            y_flat = y_ret.reshape(dsize * cap1, d)
            y_routed = y_flat[jnp.clip(dest1, 0, dsize * cap1 - 1)]
            w = (gate.reshape(tl * k) * keep1).astype(xl.dtype)
            y = jnp.zeros((tl, d), xl.dtype).at[tok_of].add(y_routed * w[:, None])
        if shared is not None:
            y = y + y_shared.astype(y.dtype)  # also partial over f_l
        y = jax.lax.psum(y, "model")  # single fused model-axis reduction
        return y.reshape(bl, s, d), aux

    batch_spec = P(bx if len(bx) > 1 else bx[0], None, None)
    shared = ()
    shared_spec = ()
    if m.num_shared_experts:
        shared = (params["shared_gate"], params["shared_up"], params["shared_down"])
        shared_spec = (
            P(None, None, "model"),
            P(None, None, "model"),
            P(None, "model", None),
        )

    def wrapper(router, w_gate, w_up, w_down, shared_tuple, xl):
        return local_fn(
            router, w_gate, w_up, w_down, shared_tuple if shared_tuple else None, xl
        )

    fn = shard_map_compat(
        wrapper,
        mesh=mesh,
        in_specs=(
            P(),                          # router (replicated fp32)
            P("data", None, "model"),     # w_gate
            P("data", None, "model"),     # w_up
            P("data", "model", None),     # w_down
            shared_spec,
            batch_spec,                   # x
        ),
        out_specs=(batch_spec, P()),
        check=False,
    )
    return fn(
        params["router"], params["w_gate"], params["w_up"], params["w_down"],
        shared, x,
    )
