"""Pure-jnp oracle for pairwise translational scores."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_scores_ref(q: jnp.ndarray, ent: jnp.ndarray, *, ord_: int = 1) -> jnp.ndarray:
    """(B, d) × (E, d) → (B, E); score = −‖q_i − e_j‖_ord."""
    diff = q[:, None, :].astype(jnp.float32) - ent[None, :, :].astype(jnp.float32)
    if ord_ == 2:
        return -jnp.sqrt(jnp.sum(jnp.square(diff), axis=-1) + 1e-12)
    return -jnp.sum(jnp.abs(diff), axis=-1)
