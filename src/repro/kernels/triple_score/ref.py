"""Pure-jnp oracles for pairwise translational scores and fused ranks."""
from __future__ import annotations

import jax.numpy as jnp


def _scores_ref(q: jnp.ndarray, ent: jnp.ndarray, mode: str) -> jnp.ndarray:
    q = q.astype(jnp.float32)
    ent = ent.astype(jnp.float32)
    if mode == "dot":
        return q @ ent.T
    diff = q[:, None, :] - ent[None, :, :]
    if mode == "l2":
        return -jnp.sqrt(jnp.sum(jnp.square(diff), axis=-1) + 1e-12)
    if mode == "cl1":
        d2 = q.shape[1] // 2
        dr, di = diff[..., :d2], diff[..., d2:]
        return -jnp.sum(jnp.sqrt(dr * dr + di * di + 1e-12), axis=-1)
    return -jnp.sum(jnp.abs(diff), axis=-1)


def pairwise_scores_ref(
    q: jnp.ndarray, ent: jnp.ndarray, *, ord_: int = 1, mode: str | None = None
) -> jnp.ndarray:
    """(B, d) × (E, d) → (B, E); score = −‖q_i − e_j‖_ord (or q·e for dot)."""
    return _scores_ref(q, ent, mode or ("l2" if ord_ == 2 else "l1"))


def fused_ranks_ref(
    q: jnp.ndarray,     # (B, d)
    ent: jnp.ndarray,   # (E, d)
    gold: jnp.ndarray,  # (B,) gold scores
    filt: jnp.ndarray,  # (B, F) int32 known-true ids, pad −1
    *,
    mode: str = "l1",
) -> jnp.ndarray:
    """Oracle for the streaming kernel — materializes (B, E); tests only."""
    s = _scores_ref(q, ent, mode)  # (B, E)
    ids = jnp.arange(ent.shape[0], dtype=jnp.int32)
    excl = jnp.any(filt[:, :, None] == ids[None, None, :], axis=1)  # (B, E)
    beats = (s > gold[:, None]) & jnp.logical_not(excl)
    return jnp.sum(beats.astype(jnp.int32), axis=1)
