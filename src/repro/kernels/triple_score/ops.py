"""jit'd wrapper: pads the entity axis to a block multiple and dispatches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.triple_score.triple_score import pairwise_scores_fwd


@functools.partial(
    jax.jit, static_argnames=("ord_", "block_q", "block_e", "interpret")
)
def pairwise_scores(
    q: jnp.ndarray,
    ent: jnp.ndarray,
    *,
    ord_: int = 1,
    block_q: int = 8,
    block_e: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    b, d = q.shape
    e = ent.shape[0]
    be = min(block_e, e)
    bq = min(block_q, b)
    pad_e = (-e) % be
    pad_b = (-b) % bq
    if pad_e:
        ent = jnp.pad(ent, ((0, pad_e), (0, 0)))
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0)))
    out = pairwise_scores_fwd(
        q, ent, ord_=ord_, block_q=bq, block_e=be, interpret=interpret
    )
    return out[:b, :e]
