"""Public wrappers: pad to block multiples, resolve the backend, dispatch.

``pairwise_scores`` keeps the seed API (full (B, E) matrix — training-scale
uses). ``fused_ranks`` is the streaming rank engine: it returns per-query
filtered rank *counts* without ever materializing (B, E). Two interchangeable
implementations sit behind ``kernels.dispatch.resolve_rank_impl``:

  * ``pallas`` — the fused accumulation-grid kernel (TPU/GPU);
  * ``xla``    — a ``lax.scan`` over entity blocks with identical tile math
    (CPU CI: one compiled loop instead of interpret-mode Pallas).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret, resolve_rank_impl
from repro.kernels.triple_score.triple_score import (
    SCORE_MODES,
    _tile_scores,
    fused_rank_fwd,
    pairwise_scores_fwd,
)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_q", "block_e", "interpret")
)
def _pairwise_scores_jit(
    q: jnp.ndarray,
    ent: jnp.ndarray,
    *,
    mode: str,
    block_q: int,
    block_e: int,
    interpret: bool,
) -> jnp.ndarray:
    b, d = q.shape
    e = ent.shape[0]
    be = min(block_e, e)
    bq = min(block_q, b)
    pad_e = (-e) % be
    pad_b = (-b) % bq
    if pad_e:
        ent = jnp.pad(ent, ((0, pad_e), (0, 0)))
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0)))
    out = pairwise_scores_fwd(
        q, ent, mode=mode, block_q=bq, block_e=be, interpret=interpret
    )
    return out[:b, :e]


def pairwise_scores(
    q: jnp.ndarray,
    ent: jnp.ndarray,
    *,
    ord_: int = 1,
    mode: Optional[str] = None,
    block_q: int = 8,
    block_e: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(B, d) × (E, d) → (B, E) scores. ``mode`` (l1|l2|dot) wins over ``ord_``."""
    mode = mode or ("l2" if ord_ == 2 else "l1")
    assert mode in SCORE_MODES, mode
    return _pairwise_scores_jit(
        q, ent, mode=mode, block_q=block_q, block_e=block_e,
        interpret=resolve_interpret(interpret),
    )


# ------------------------------------------------------------- fused ranks
def fused_ranks_pallas_graph(
    q: jnp.ndarray,
    ent: jnp.ndarray,
    gold: jnp.ndarray,
    filt: jnp.ndarray,
    *,
    mode: str,
    block_q: int,
    block_e: int,
    interpret: bool,
) -> jnp.ndarray:
    b, d = q.shape
    e = ent.shape[0]
    be = min(block_e, e)
    bq = min(block_q, b)
    pad_e = (-e) % be
    pad_b = (-b) % bq
    if pad_e:
        ent = jnp.pad(ent, ((0, pad_e), (0, 0)))
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0)))
        gold = jnp.pad(gold, (0, pad_b))
        filt = jnp.pad(filt, ((0, pad_b), (0, 0)), constant_values=-1)
    out = fused_rank_fwd(
        q, ent, gold[:, None].astype(jnp.float32), filt.astype(jnp.int32),
        mode=mode, num_entities=e, block_q=bq, block_e=be, interpret=interpret,
    )
    return out[:b, 0]


_fused_ranks_pallas = functools.partial(
    jax.jit, static_argnames=("mode", "block_q", "block_e", "interpret")
)(fused_ranks_pallas_graph)


def fused_ranks_xla_graph(
    q: jnp.ndarray,
    ent: jnp.ndarray,
    gold: jnp.ndarray,
    filt: jnp.ndarray,
    *,
    mode: str,
    block_e: int,
) -> jnp.ndarray:
    b, d = q.shape
    e = ent.shape[0]
    be = min(block_e, e)
    pad_e = (-e) % be
    if pad_e:
        ent = jnp.pad(ent, ((0, pad_e), (0, 0)))
    blocks = ent.reshape(-1, be, d)
    cols = jnp.arange(blocks.shape[0] * be, dtype=jnp.int32).reshape(-1, be)
    q = q.astype(jnp.float32)
    gold = gold.astype(jnp.float32)[:, None]  # (B, 1)
    filt = filt.astype(jnp.int32)

    def step(acc, inp):
        eb, cb = inp  # (Be, d), (Be,)
        s = _tile_scores(q, eb.astype(jnp.float32), mode)  # (B, Be)
        excl = jnp.any(filt[:, :, None] == cb[None, None, :], axis=1)
        beats = (s > gold) & (cb < e)[None, :] & jnp.logical_not(excl)
        return acc + jnp.sum(beats.astype(jnp.int32), axis=1), None

    counts, _ = jax.lax.scan(step, jnp.zeros((b,), jnp.int32), (blocks, cols))
    return counts


_fused_ranks_xla = functools.partial(
    jax.jit, static_argnames=("mode", "block_e")
)(fused_ranks_xla_graph)


def fused_ranks_graph(
    q: jnp.ndarray,
    ent: jnp.ndarray,
    gold: jnp.ndarray,
    filt: jnp.ndarray,
    *,
    mode: str = "l1",
    block_q: int = 8,
    block_e: int = 512,
    impl: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """``fused_ranks`` as a pure graph (no jit boundary) — for callers that
    embed rank counting inside a larger compiled program (the federation tick
    engine). Resolves the implementation exactly like ``fused_ranks``."""
    assert mode in SCORE_MODES, mode
    impl = resolve_rank_impl(impl)
    if impl == "pallas":
        return fused_ranks_pallas_graph(
            q, ent, gold, filt, mode=mode, block_q=block_q, block_e=block_e,
            interpret=resolve_interpret(interpret),
        )
    return fused_ranks_xla_graph(q, ent, gold, filt, mode=mode, block_e=block_e)


def fused_ranks(
    q: jnp.ndarray,     # (B, d) queries
    ent: jnp.ndarray,   # (E, d) entity table
    gold: jnp.ndarray,  # (B,) gold score per query
    filt: jnp.ndarray,  # (B, F) int32 known-true entity ids, pad −1
    *,
    mode: str = "l1",
    block_q: int = 8,
    block_e: int = 512,
    impl: Optional[str] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Streaming filtered rank counts; filtered rank = ``fused_ranks(...) + 1``.

    The gold entity id should appear in its own filter row: exclusion makes
    the count invariant to fp noise between the gathered gold score and the
    tile-computed score of the same entity.
    """
    assert mode in SCORE_MODES, mode
    impl = resolve_rank_impl(impl)
    if impl == "pallas":
        return _fused_ranks_pallas(
            q, ent, gold, filt, mode=mode, block_q=block_q, block_e=block_e,
            interpret=resolve_interpret(interpret),
        )
    return _fused_ranks_xla(q, ent, gold, filt, mode=mode, block_e=block_e)
