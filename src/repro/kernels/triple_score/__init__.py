from repro.kernels.triple_score.ops import pairwise_scores  # noqa: F401
from repro.kernels.triple_score.ref import pairwise_scores_ref  # noqa: F401
