from repro.kernels.triple_score.ops import fused_ranks, pairwise_scores  # noqa: F401
from repro.kernels.triple_score.ref import (  # noqa: F401
    fused_ranks_ref,
    pairwise_scores_ref,
)
