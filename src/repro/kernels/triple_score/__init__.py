from repro.kernels.triple_score.ops import (  # noqa: F401
    fused_ranks,
    fused_ranks_graph,
    pairwise_scores,
)
from repro.kernels.triple_score.ref import (  # noqa: F401
    fused_ranks_ref,
    pairwise_scores_ref,
)
