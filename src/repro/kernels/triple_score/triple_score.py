"""Blocked pairwise translational scoring — the link-prediction hot spot.

Link prediction scores every test query q = h + r against EVERY entity
embedding: (B, E) Minkowski distances with E up to millions. The kernel tiles
(B, E) into (block_q × block_e) VMEM blocks; the query block and entity block
are resident in VMEM and the (Bq, Be, d) broadcast-difference never
materializes in HBM.

VMEM per step: Bq·d + Be·d + Bq·Be·d (intermediate) fp32. Defaults
(8, 256, d≤256) → ~2 MB. For L2 the expansion ||q−e||² = |q|²−2q·e+|e|² routes
the dominant term through the MXU.

Two kernels share the tile math:

  * ``pairwise_scores_fwd`` — writes the (B, E) score matrix (training-time
    uses, small E);
  * ``fused_rank_fwd`` — the streaming rank engine: each grid step compares
    its tile against the per-query gold score and accumulates
    ``rank_j += Σ 1[score > gold]`` into a (B, 1) int32 output that is
    revisited across the entity grid axis (index_map ignores j), with filter
    exclusion applied in-kernel from a padded known-true index tensor. The
    (B, E) matrix never exists anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: score tile modes: L1/L2 Minkowski (negated distance), plain dot product,
#: or complex-L1 ("cl1": rows are [re | im] halves, per-component modulus —
#: the RotatE distance)
SCORE_MODES = ("l1", "l2", "dot", "cl1")


def _tile_scores(q: jnp.ndarray, e: jnp.ndarray, mode: str) -> jnp.ndarray:
    """(Bq, d) × (Be, d) → (Bq, Be) scores, higher = better."""
    if mode == "dot":
        return jax.lax.dot_general(
            q, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    if mode == "l2":
        qq = jnp.sum(q * q, axis=1)[:, None]
        ee = jnp.sum(e * e, axis=1)[None, :]
        qe = jax.lax.dot_general(
            q, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        d2 = jnp.maximum(qq - 2.0 * qe + ee, 0.0)
        return -jnp.sqrt(d2 + 1e-12)
    if mode == "cl1":
        d2 = q.shape[1] // 2
        dr = q[:, None, :d2] - e[None, :, :d2]  # (Bq, Be, d/2)
        di = q[:, None, d2:] - e[None, :, d2:]
        return -jnp.sum(jnp.sqrt(dr * dr + di * di + 1e-12), axis=-1)
    diff = jnp.abs(q[:, None, :] - e[None, :, :])  # (Bq, Be, d)
    return -jnp.sum(diff, axis=-1)


def _score_kernel(q_ref, e_ref, o_ref, *, mode: str):
    q = q_ref[...].astype(jnp.float32)  # (Bq, d)
    e = e_ref[...].astype(jnp.float32)  # (Be, d)
    o_ref[...] = _tile_scores(q, e, mode).astype(o_ref.dtype)


def pairwise_scores_fwd(
    q: jnp.ndarray,  # (B, d) queries (h + r)
    ent: jnp.ndarray,  # (E, d) entity table
    *,
    mode: str = "l1",
    block_q: int = 8,
    block_e: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    b, d = q.shape
    e, _ = ent.shape
    assert mode in SCORE_MODES, mode
    block_q = min(block_q, b)
    block_e = min(block_e, e)
    assert b % block_q == 0 and e % block_e == 0, (b, e, block_q, block_e)
    kernel = functools.partial(_score_kernel, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(b // block_q, e // block_e),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_e), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, e), jnp.float32),
        interpret=interpret,
    )(q, ent)


# --------------------------------------------------------------------------
# streaming fused-rank kernel
# --------------------------------------------------------------------------
def _fused_rank_kernel(
    q_ref,      # (Bq, d) query block
    g_ref,      # (Bq, 1) gold score per query
    f_ref,      # (Bq, F) known-true entity ids (pad −1; gold always present)
    e_ref,      # (Be, d) entity block
    o_ref,      # (Bq, 1) int32 rank counts — revisited across j
    *,
    mode: str,
    block_e: int,
    num_entities: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    s = _tile_scores(q, e, mode)  # (Bq, Be)
    bq, be = s.shape

    # global entity ids of this tile's columns; ids ≥ num_entities are padding
    col = j * block_e + jax.lax.broadcasted_iota(jnp.int32, (bq, be), 1)
    valid = col < num_entities
    # in-kernel filter: exclude every known-true id listed for each query
    filt = f_ref[...]  # (Bq, F) int32
    excl = jnp.any(filt[:, :, None] == col[:, None, :], axis=1)  # (Bq, Be)

    beats = (s > g_ref[...]) & valid & jnp.logical_not(excl)
    o_ref[...] += jnp.sum(beats.astype(jnp.int32), axis=1, keepdims=True)


def fused_rank_fwd(
    q: jnp.ndarray,     # (B, d)
    ent: jnp.ndarray,   # (E_pad, d) entity table (rows ≥ num_entities ignored)
    gold: jnp.ndarray,  # (B, 1) float32 gold scores
    filt: jnp.ndarray,  # (B, F) int32 known-true ids, pad −1
    *,
    mode: str = "l1",
    num_entities: int,
    block_q: int = 8,
    block_e: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Filtered rank counts: out[i] = Σ_e 1[score(q_i, e) > gold_i], with
    entities listed in ``filt[i]`` and padding rows excluded. Rank = out + 1.
    """
    b, d = q.shape
    e, _ = ent.shape
    assert mode in SCORE_MODES, mode
    block_q = min(block_q, b)
    block_e = min(block_e, e)
    assert b % block_q == 0 and e % block_e == 0, (b, e, block_q, block_e)
    f = filt.shape[1]
    kernel = functools.partial(
        _fused_rank_kernel, mode=mode, block_e=block_e, num_entities=num_entities
    )
    return pl.pallas_call(
        kernel,
        # j (entity axis) is the minormost grid dim → the (i, 0) output block
        # is revisited across consecutive steps: the accumulation grid.
        grid=(b // block_q, e // block_e),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(q, gold, filt, ent)
