"""Blocked pairwise translational scoring — the link-prediction hot spot.

Link prediction scores every test query q = h + r against EVERY entity
embedding: (B, E) Minkowski distances with E up to millions. The kernel tiles
(B, E) into (block_q × block_e) VMEM blocks; the query block and entity block
are resident in VMEM and the (Bq, Be, d) broadcast-difference never
materializes in HBM.

VMEM per step: Bq·d + Be·d + Bq·Be·d (intermediate) fp32. Defaults
(8, 256, d≤256) → ~2 MB. For L2 the expansion ||q−e||² = |q|²−2q·e+|e|² routes
the dominant term through the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(q_ref, e_ref, o_ref, *, ord_: int):
    q = q_ref[...].astype(jnp.float32)  # (Bq, d)
    e = e_ref[...].astype(jnp.float32)  # (Be, d)
    if ord_ == 2:
        qq = jnp.sum(q * q, axis=1)[:, None]
        ee = jnp.sum(e * e, axis=1)[None, :]
        qe = jax.lax.dot_general(
            q, e, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        d2 = jnp.maximum(qq - 2.0 * qe + ee, 0.0)
        o_ref[...] = (-jnp.sqrt(d2 + 1e-12)).astype(o_ref.dtype)
    else:
        diff = jnp.abs(q[:, None, :] - e[None, :, :])  # (Bq, Be, d)
        o_ref[...] = (-jnp.sum(diff, axis=-1)).astype(o_ref.dtype)


def pairwise_scores_fwd(
    q: jnp.ndarray,  # (B, d) queries (h + r)
    ent: jnp.ndarray,  # (E, d) entity table
    *,
    ord_: int = 1,
    block_q: int = 8,
    block_e: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    b, d = q.shape
    e, _ = ent.shape
    block_q = min(block_q, b)
    block_e = min(block_e, e)
    assert b % block_q == 0 and e % block_e == 0, (b, e, block_q, block_e)
    kernel = functools.partial(_score_kernel, ord_=ord_)
    return pl.pallas_call(
        kernel,
        grid=(b // block_q, e // block_e),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_e), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, e), jnp.float32),
        interpret=interpret,
    )(q, ent)
