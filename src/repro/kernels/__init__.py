# Pallas TPU kernels for the framework's compute hot spots.
#
# Each kernel package has: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
# ops.py (jit'd public wrapper), ref.py (pure-jnp oracle the tests assert
# against). dispatch.py resolves interpret-vs-compiled per backend
# (REPRO_PALLAS_INTERPRET / REPRO_RANK_IMPL override) so the same call sites
# run fast on TPU/GPU and still pass on CPU CI.
#
#   flash_attention — blocked causal/sliding-window GQA attention
#   triple_score    — blocked pairwise TransE scoring + the streaming
#                     fused-rank link-prediction engine (in-kernel rank
#                     accumulation with CSR-style filter exclusion)
#   csls            — fused-normalization cosine-similarity matmul for CSLS
#   ssd_scan        — Mamba2 SSD intra-chunk kernel
