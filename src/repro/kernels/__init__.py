# Pallas TPU kernels for the framework's compute hot spots.
#
# Each kernel package has: <name>.py (pl.pallas_call + BlockSpec VMEM tiling),
# ops.py (jit'd public wrapper, interpret-mode switch), ref.py (pure-jnp
# oracle the tests assert against).
#
#   flash_attention — blocked causal/sliding-window GQA attention
#   triple_score    — blocked pairwise TransE scoring (link-prediction eval)
#   csls            — fused-normalization cosine-similarity matmul for CSLS
#   ssd_scan        — Mamba2 SSD intra-chunk kernel
