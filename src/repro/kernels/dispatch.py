"""Backend auto-dispatch for the Pallas kernel families.

Every kernel wrapper takes ``interpret=None`` by default and resolves it here:

  1. an explicit ``True``/``False`` from the caller always wins;
  2. else the ``REPRO_PALLAS_INTERPRET`` env var (``1/true/on`` or ``0/false/off``)
     overrides the backend heuristic — useful to force-compile on CPU or debug
     on TPU without touching call sites;
  3. else resolve from ``jax.default_backend()``: compiled Pallas on TPU/GPU,
     interpreter on CPU (the CI container), so the same call sites run fast on
     accelerators and still pass on CPU CI.

The streaming rank engine additionally picks an *implementation*: the Pallas
fused-rank kernel on TPU (its accumulation grid relies on sequential grid
execution), or a jnp ``lax.scan`` streaming equivalent everywhere else — on
GPU the Triton grid runs in parallel (the revisited output block would race),
and on CPU interpret-mode Pallas re-traces the kernel body per grid step,
far slower than one compiled XLA loop. ``REPRO_RANK_IMPL`` overrides
(``pallas`` | ``xla``).

The training engine picks its step implementation the same way:
``REPRO_TRAIN_IMPL`` (``pallas`` | ``xla`` | ``reference``) overrides the
backend heuristic in ``resolve_train_impl``.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

#: backends with a real Mosaic/Triton Pallas lowering
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def _env_flag(name: str) -> Optional[bool]:
    raw = os.environ.get(name, "").strip().lower()
    if raw in _TRUTHY:
        return True
    if raw in _FALSY:
        return False
    return None


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve the ``interpret=`` flag for a ``pl.pallas_call``."""
    if interpret is not None:
        return bool(interpret)
    env = _env_flag("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env
    return jax.default_backend() not in COMPILED_BACKENDS


#: families whose margin-SGD step the fused sparse_update kernel covers
SPARSE_KERNEL_FAMILIES = ("transe", "distmult")


def resolve_train_impl(impl: Optional[str] = None, family: str = "transe") -> str:
    """Pick the training-engine step implementation.

    ``pallas`` — the fused gather→score→scatter sparse_update kernel
    (TransE/DistMult only; its serial in-kernel scatter relies on the single
    grid step executing sequentially, which holds everywhere, but the
    dynamic-slice row loop only lowers well on TPU); ``xla`` — the autodiff
    sparse step (every family, every backend; one compiled scan on CPU CI);
    ``reference`` — the seed dense host-loop path, kept as the parity oracle.
    ``REPRO_TRAIN_IMPL`` overrides."""
    if impl is None:
        impl = os.environ.get("REPRO_TRAIN_IMPL", "").strip().lower() or None
    if impl is None:
        impl = (
            "pallas"
            if jax.default_backend() == "tpu" and family in SPARSE_KERNEL_FAMILIES
            else "xla"
        )
    if impl not in ("pallas", "xla", "reference"):
        raise ValueError(f"unknown train impl {impl!r} (pallas|xla|reference)")
    if impl == "pallas" and family not in SPARSE_KERNEL_FAMILIES:
        impl = "xla"  # kernel does not cover this family's score math
    return impl


def resolve_tick_impl(impl: Optional[str] = None, family: str = "transe") -> str:
    """Pick the federation tick execution engine: ``batched`` or ``reference``.

    ``batched`` — the tick engine plans every Ready owner's pending work at
    tick start and executes the whole tick (PPAT, aggregation, retrain,
    backtrack scoring) as ONE compiled program of independent per-owner
    subgraphs; ``reference`` — the serial per-owner loop (the seed protocol
    driver), kept as the parity oracle. ``REPRO_TICK_IMPL`` overrides.

    The batched engine embeds the device-resident training scan per owner,
    so when the training step resolves to the host-loop ``reference`` impl
    (``REPRO_TRAIN_IMPL=reference``) ticks fall back to ``reference`` too.
    """
    if impl is None:
        impl = os.environ.get("REPRO_TICK_IMPL", "").strip().lower() or None
    if impl is None:
        impl = (
            "reference"
            if resolve_train_impl(None, family) == "reference"
            else "batched"
        )
    if impl not in ("batched", "reference"):
        raise ValueError(f"unknown tick impl {impl!r} (batched|reference)")
    return impl


def resolve_tick_placement(placement: Optional[str] = None) -> str:
    """Pick where the batched tick engine places its entry programs:
    ``single`` (every entry on the default device) or ``sharded`` (signature
    buckets shard_map'ed across ``jax.devices()``, singletons placed by a
    stable signature hash).

    ``auto`` (the default) resolves to ``sharded`` exactly when more than one
    device is visible — on CPU CI that means
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` turns sharding on
    without touching call sites. ``REPRO_TICK_PLACEMENT`` overrides.
    """
    if placement is None:
        placement = (
            os.environ.get("REPRO_TICK_PLACEMENT", "").strip().lower() or None
        )
    if placement is None:
        placement = "auto"
    if placement == "auto":
        placement = "sharded" if len(jax.devices()) > 1 else "single"
    if placement not in ("single", "sharded"):
        raise ValueError(
            f"unknown tick placement {placement!r} (auto|single|sharded)"
        )
    return placement


def resolve_tick_sync(sync: Optional[str] = None) -> str:
    """Pick the federation scheduling discipline: ``barrier`` or ``stream``.

    ``barrier`` (the default) — the lockstep scheduler: one plan per tick,
    every owner blocks on the slowest entry, accepts take effect next tick.
    Kept as the parity oracle for the streamed path. ``stream`` — the
    dependency-level streaming scheduler: each pass's plan is cut into
    dependency levels (entries whose host/client sets overlap serialize,
    disjoint entries stream), levels dispatch as they clear, client views
    are versioned, and a bounded-staleness gate (``staleness_bound=``)
    triggers re-offer handshakes instead of blind accepts on too-stale
    views. ``streamed`` is accepted as an alias. ``REPRO_TICK_SYNC``
    overrides.
    """
    if sync is None:
        sync = os.environ.get("REPRO_TICK_SYNC", "").strip().lower() or None
    if sync is None or sync == "auto":
        sync = "barrier"
    if sync == "streamed":
        sync = "stream"
    if sync not in ("barrier", "stream"):
        raise ValueError(
            f"unknown tick sync {sync!r} (auto|barrier|stream)"
        )
    return sync


def resolve_tick_residency(residency: Optional[str] = None) -> str:
    """Pick what happens to tick-entry outputs after a batched tick:
    ``resident`` (the default) leaves every owner's results committed to the
    device that produced them — an owner's embedding tables stay on its
    sticky home device across ticks, and only the scalar decisions/scores
    sync to host; ``normalize`` restores the pre-residency behavior of
    ``jax.device_put``-ing all results back to the default device each tick
    (an escape hatch for consumers that cannot handle committed arrays).
    ``REPRO_TICK_RESIDENCY`` overrides.
    """
    if residency is None:
        residency = (
            os.environ.get("REPRO_TICK_RESIDENCY", "").strip().lower() or None
        )
    if residency is None or residency == "auto":
        residency = "resident"
    if residency not in ("resident", "normalize"):
        raise ValueError(
            f"unknown tick residency {residency!r} (auto|resident|normalize)"
        )
    return residency


def resolve_tick_faults(spec=None):
    """Resolve the federation fault-injection layer: returns ``None`` (off —
    the default, keeping the tick fast path bit-identical to the pre-fault
    engine) or a fault-plan description the scheduler hands to
    ``core.faults.FaultPlan.parse``.

    ``spec`` may be a spec string, an already-built ``FaultPlan`` /
    ``FaultInjector`` (handed through verbatim — the test harness path), or
    ``None`` to consult ``REPRO_TICK_FAULTS``. Off-values (``off``/``0``/
    ``false``/``none``/empty) resolve to ``None``.
    """
    if spec is not None and not isinstance(spec, str):
        return spec  # FaultPlan / FaultInjector passed programmatically
    if spec is None:
        spec = os.environ.get("REPRO_TICK_FAULTS", "").strip() or None
    if spec is None:
        return None
    if spec.strip().lower() in _FALSY + ("", "none"):
        return None
    return spec


def resolve_tick_adversary(spec=None):
    """Resolve the federation adversarial-peer layer: returns ``None`` (off
    — the default, keeping the tick fast path bit-identical to the
    pre-attack engine) or an adversary description the scheduler hands to
    ``core.adversary.AdversaryPlan.parse``.

    ``spec`` may be a spec string, an already-built ``AdversaryPlan`` /
    ``Adversary`` (handed through verbatim — the test harness path), or
    ``None`` to consult ``REPRO_TICK_ADVERSARY``. Off-values (``off``/
    ``0``/``false``/``none``/empty) resolve to ``None``.
    """
    if spec is not None and not isinstance(spec, str):
        return spec  # AdversaryPlan / Adversary passed programmatically
    if spec is None:
        spec = os.environ.get("REPRO_TICK_ADVERSARY", "").strip() or None
    if spec is None:
        return None
    if spec.strip().lower() in _FALSY + ("", "none"):
        return None
    return spec


def resolve_serve_faults(spec=None):
    """Resolve the serving-tier fault-injection layer: returns ``None`` (off
    — the default, keeping the query fast path bit-identical to the
    pre-fault tier) or a fault-plan description the tier hands to
    ``core.faults.ServeFaultPlan.parse``.

    ``spec`` may be a spec string, an already-built ``ServeFaultPlan``
    (handed through verbatim — the test harness path), or ``None`` to
    consult ``REPRO_SERVE_FAULTS``. Off-values (``off``/``0``/``false``/
    ``none``/empty) resolve to ``None``.
    """
    if spec is not None and not isinstance(spec, str):
        return spec  # ServeFaultPlan passed programmatically
    if spec is None:
        spec = os.environ.get("REPRO_SERVE_FAULTS", "").strip() or None
    if spec is None:
        return None
    if spec.strip().lower() in _FALSY + ("", "none"):
        return None
    return spec


def resolve_serve_impl(impl: Optional[str] = None) -> str:
    """Pick the serving-tier dispatch mode: ``batched`` or ``direct``.

    ``batched`` — the default: the tier coalesces queued requests into
    bucket-padded query batches (continuous batching) so steady-state
    traffic hits a fixed set of compiled programs; ``direct`` — one
    dispatch per request, the per-call baseline the serving bench measures
    batching against. ``REPRO_SERVE_IMPL`` overrides."""
    if impl is None:
        impl = os.environ.get("REPRO_SERVE_IMPL", "").strip().lower() or None
    if impl is None:
        impl = "batched"
    if impl not in ("batched", "direct"):
        raise ValueError(f"unknown serve impl {impl!r} (batched|direct)")
    return impl


def resolve_serve_replicas(n: Optional[int] = None) -> int:
    """Pick how many table replicas the serving tier spreads over the mesh.

    Explicit ``n`` wins, else ``REPRO_SERVE_REPLICAS``, else every visible
    device capped at 4 — a single-device CI run degenerates to one replica
    while ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or a real
    multi-chip mesh) turns replica routing on without touching call sites.
    The tier clamps to the actual device count, so over-asking is safe."""
    if n is None:
        raw = os.environ.get("REPRO_SERVE_REPLICAS", "").strip()
        n = int(raw) if raw else None
    if n is None:
        n = min(4, len(jax.devices()))
    n = int(n)
    if n < 1:
        raise ValueError(f"serve replicas must be >= 1, got {n}")
    return n


def resolve_rank_impl(impl: Optional[str] = None) -> str:
    """Pick the fused-rank engine implementation: ``pallas`` or ``xla``.

    The fused-rank kernel revisits its output block across the entity grid
    axis (``index_map`` ignores j), which is only sound where grid steps run
    sequentially — TPU. On GPU the Triton grid is parallel, so auto picks the
    ``xla`` scan there too; ``REPRO_RANK_IMPL=pallas`` can force it for
    experimentation."""
    if impl is None:
        impl = os.environ.get("REPRO_RANK_IMPL", "").strip().lower() or None
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown rank-engine impl {impl!r} (pallas|xla)")
    return impl
