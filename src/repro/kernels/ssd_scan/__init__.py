from repro.kernels.ssd_scan.ops import ssd_chunk_kernel_apply  # noqa: F401
from repro.kernels.ssd_scan.ref import ssd_chunk_ref  # noqa: F401
