"""Pure-jnp oracle: full SSD over a sequence (and single chunk)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.models.ssm import ssd as _ssd_models, ssd_chunk as _ssd_chunk_models


def ssd_chunk_ref(x, dt, a, bm, cm, state) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B,Q,H,P) layout oracle — delegates to the canonical model impl."""
    return _ssd_chunk_models(x, dt, a, bm, cm, state)


def ssd_ref(x, dt, a, bm, cm, chunk, state=None):
    return _ssd_models(x, dt, a, bm, cm, chunk, state)
