"""jit'd wrapper: Pallas intra-chunk kernel + lax.scan inter-chunk recurrence.

Drop-in equivalent of ``repro.models.ssm.ssd`` (the pure-jnp path): same
(B, S, H, P) interface, same outputs. ``interpret=None`` auto-resolves via
``kernels.dispatch`` (``REPRO_PALLAS_INTERPRET`` overrides).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret
from repro.kernels.ssd_scan.ssd_scan import ssd_chunks_fwd


def ssd_chunk_kernel_apply(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a: jnp.ndarray,
    bm: jnp.ndarray,
    cm: jnp.ndarray,
    *,
    chunk: int = 256,
    state: Optional[jnp.ndarray] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _ssd_chunk_kernel_jit(
        x, dt, a, bm, cm, chunk=chunk, state=state,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_chunk_kernel_jit(
    x: jnp.ndarray,   # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    a: jnp.ndarray,   # (H,)
    bm: jnp.ndarray,  # (B, S, G, N) — G must be 1 for the kernel path
    cm: jnp.ndarray,  # (B, S, G, N)
    *,
    chunk: int = 256,
    state: Optional[jnp.ndarray] = None,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    assert g == 1, "kernel path supports n_groups=1 (broadcast groups upstream)"
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q

    xg = x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)  # (B,H,NC,Q,P)
    dtg = dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2)      # (B,H,NC,Q)
    bg = bm.reshape(b, nc, q, n)
    cg = cm.reshape(b, nc, q, n)

    y_intra, chunk_states, decay_in = ssd_chunks_fwd(
        xg, dtg, a.reshape(h, 1), bg, cg, interpret=interpret
    )
    # inter-chunk recurrence: S_c = D_c · S_{c-1} + chunk_state_c
    total_decay = decay_in[..., -1]  # (B,H,NC) = exp(cum[-1]) per chunk

    def rec(carry, inp):
        cs, td = inp  # (B,H,P,N), (B,H)
        prev = carry
        new = prev * td[..., None, None] + cs
        return new, prev  # emit the state ENTERING this chunk

    s0 = state if state is not None else jnp.zeros((b, h, p, n), jnp.float32)
    cs_seq = chunk_states.transpose(2, 0, 1, 3, 4)  # (NC,B,H,P,N)
    td_seq = total_decay.transpose(2, 0, 1)          # (NC,B,H)
    final_state, entering = jax.lax.scan(rec, s0, (cs_seq, td_seq))

    # y_inter[s] = C_s · S_enter · exp(cum[s])
    ent = entering.transpose(1, 2, 0, 3, 4)  # (B,H,NC,P,N)
    y_inter = jnp.einsum("bcqn,bhcpn->bhcqp", cg, ent)
    y = y_intra + y_inter * decay_in[..., None]
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, s, h, p)
    return y, final_state
