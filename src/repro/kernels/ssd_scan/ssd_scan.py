"""Mamba2 SSD intra-chunk kernel (TPU Pallas).

The SSD chunked algorithm (arXiv:2405.21060) has two parts:
  1. intra-chunk: a (Q × Q) decay-masked attention-like quadratic form plus
     the chunk's contribution to the running state — MXU-heavy, this kernel;
  2. inter-chunk: a tiny (H, P, N) state recurrence — a lax.scan in ops.py.

Grid (batch, heads, chunks); per step the kernel holds x (Q, P), dt (Q,),
B/C (Q, N) and the (Q, Q) decay matrix in VMEM. With Q=256, P=64, N=128 fp32:
x 64 KB + B/C 256 KB + L/scores 512 KB ≈ 0.9 MB — fits VMEM with
double-buffering.

Outputs per chunk: y_intra (Q, P) and chunk_state (P, N); the wrapper adds
the inter-chunk ``C·S_prev·decay_in`` term after the recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, decay_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)   # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)       # () per-head decay rate (negative)
    bm = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)      # (Q, N)
    q = x.shape[0]

    da = dt * a  # (Q,) log-decays
    cum = jnp.cumsum(da)  # inclusive
    # L[s,t] = exp(cum[s] − cum[t]) for t ≤ s  (decay accumulated t→s)
    diff = cum[:, None] - cum[None, :]
    si = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(ti <= si, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_s · B_t
    w = scores * l_mat * dt[None, :]
    y_ref[0, 0, 0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    # chunk state: Σ_t exp(cum[-1] − cum[t]) · dt_t · x_t ⊗ B_t   → (P, N)
    decay_end = jnp.exp(cum[-1] - cum) * dt  # (Q,)
    xw = x * decay_end[:, None]  # (Q, P)
    state_ref[0, 0, 0] = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(state_ref.dtype)

    # per-position inbound decay exp(cum[s]) and total chunk decay exp(cum[-1])
    decay_ref[0, 0, 0] = jnp.exp(cum).astype(decay_ref.dtype)


def ssd_chunks_fwd(
    x: jnp.ndarray,   # (B, H, NC, Q, P)
    dt: jnp.ndarray,  # (B, H, NC, Q)
    a: jnp.ndarray,   # (H, 1)
    bm: jnp.ndarray,  # (B, NC, Q, N) — groups pre-broadcast (G=1)
    cm: jnp.ndarray,  # (B, NC, Q, N)
    *,
    interpret: bool = True,
):
    b, h, nc, q, p = x.shape
    n = bm.shape[-1]
    grid = (b, h, nc)
    y, state, decay = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nc, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nc, q), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, bm, cm)
    return y, state, decay
