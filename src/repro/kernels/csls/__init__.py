from repro.kernels.csls.ops import cosine_matrix, csls_matrix  # noqa: F401
from repro.kernels.csls.ref import cosine_matrix_ref, csls_matrix_ref  # noqa: F401
