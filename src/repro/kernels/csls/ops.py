"""jit'd wrappers: padded cosine tile kernel + top-k CSLS assembly.

``interpret=None`` auto-resolves via ``kernels.dispatch`` (compiled Pallas on
TPU/GPU, interpreter on CPU; ``REPRO_PALLAS_INTERPRET`` overrides).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.csls.csls import cosine_matrix_fwd
from repro.kernels.dispatch import resolve_interpret


@functools.partial(jax.jit, static_argnames=("block_a", "block_b", "interpret"))
def _cosine_matrix_jit(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_a: int,
    block_b: int,
    interpret: bool,
) -> jnp.ndarray:
    n, m = a.shape[0], b.shape[0]
    ba, bb = min(block_a, n), min(block_b, m)
    pa, pb = (-n) % ba, (-m) % bb
    if pa:
        a = jnp.pad(a, ((0, pa), (0, 0)))
    if pb:
        b = jnp.pad(b, ((0, pb), (0, 0)))
    out = cosine_matrix_fwd(a, b, block_a=ba, block_b=bb, interpret=interpret)
    return out[:n, :m]


def cosine_matrix(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_a: int = 128,
    block_b: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    return _cosine_matrix_jit(
        a, b, block_a=block_a, block_b=block_b,
        interpret=resolve_interpret(interpret),
    )


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def _csls_matrix_jit(a: jnp.ndarray, b: jnp.ndarray, *, k: int, interpret: bool):
    """CSLS(a_i, b_j) = 2·cos − r_A − r_B, cosine tiles via the Pallas kernel."""
    sim = _cosine_matrix_jit(a, b, block_a=128, block_b=128, interpret=interpret)
    kk = min(k, sim.shape[1])
    kk2 = min(k, sim.shape[0])
    r_a = jnp.mean(jax.lax.top_k(sim, kk)[0], axis=1)
    r_b = jnp.mean(jax.lax.top_k(sim.T, kk2)[0], axis=1)
    return 2 * sim - r_a[:, None] - r_b[None, :]


def csls_matrix(
    a: jnp.ndarray, b: jnp.ndarray, *, k: int = 10,
    interpret: Optional[bool] = None,
):
    return _csls_matrix_jit(a, b, k=k, interpret=resolve_interpret(interpret))
