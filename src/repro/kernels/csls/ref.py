"""Pure-jnp oracles for the CSLS kernels."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_matrix_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    an = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-9)
    bn = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-9)
    return (an @ bn.T).astype(jnp.float32)


def csls_matrix_ref(a: jnp.ndarray, b: jnp.ndarray, k: int = 10) -> jnp.ndarray:
    sim = cosine_matrix_ref(a, b)
    kk = min(k, sim.shape[1])
    kk2 = min(k, sim.shape[0])
    r_a = jnp.mean(jnp.sort(sim, axis=1)[:, -kk:], axis=1)
    r_b = jnp.mean(jnp.sort(sim, axis=0)[-kk2:, :], axis=0)
    return 2 * sim - r_a[:, None] - r_b[None, :]
