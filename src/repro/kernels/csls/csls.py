"""Fused-normalization cosine-similarity kernel for CSLS (MUSE metric).

CSLS needs the full (n, m) cosine matrix between translated client embeddings
and host embeddings (alignment sets reach 100k+ pairs — Tab. 3). The kernel
tiles it MXU-style and fuses the row L2-normalizations into the tile compute,
so unnormalized embeddings never round-trip to HBM. The top-k neighborhood
means (r_A, r_B) are a cheap row/col reduction done by the wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cos_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)  # (Ba, d)
    b = b_ref[...].astype(jnp.float32)  # (Bb, d)
    an = a * jax.lax.rsqrt(jnp.sum(a * a, axis=1, keepdims=True) + 1e-18)
    bn = b * jax.lax.rsqrt(jnp.sum(b * b, axis=1, keepdims=True) + 1e-18)
    o_ref[...] = jax.lax.dot_general(
        an, bn, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def cosine_matrix_fwd(
    a: jnp.ndarray,  # (n, d)
    b: jnp.ndarray,  # (m, d)
    *,
    block_a: int = 128,
    block_b: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    n, d = a.shape
    m, _ = b.shape
    block_a = min(block_a, n)
    block_b = min(block_b, m)
    assert n % block_a == 0 and m % block_b == 0
    return pl.pallas_call(
        _cos_kernel,
        grid=(n // block_a, m // block_b),
        in_specs=[
            pl.BlockSpec((block_a, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, block_b), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(a, b)
