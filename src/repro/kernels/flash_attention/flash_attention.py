"""Blocked flash attention (TPU Pallas) — causal / sliding-window, GQA-aware.

Tiling: grid (batch, q_head, q_block, k_block) with the k axis innermost;
running max/denominator/accumulator live in VMEM scratch and the output block
is written once on the final k step (the standard TPU flash-attention
schedule). Block shapes are MXU-aligned (multiples of 128 on the seq dims,
head_dim ≤ 128 padded by the wrapper).

VMEM working set per step ≈ (Bq·Dh + 2·Bk·Dh + Bq·Bk + Bq·Dh) · 4B
≈ (128·128·4 + 2·128·128 + 128·128)·4 ≈ 330 KB — comfortably inside the
~16 MB/core VMEM budget, leaving room for double-buffered pipelining.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, block_q: int, block_k: int, causal: bool, window: int,
    num_k_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (Bq, Dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bk, Dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (Bq, Bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # rows with no valid key yet: m_new stays NEG_INF → force p to 0
    p = jnp.where((m_new == NEG_INF)[:, None], 0.0, p)
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, H, S, Dh)
    k: jnp.ndarray,  # (B, KV, T, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, h, s, dh = q.shape
    _, kv, t, _ = k.shape
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0
    nq, nk = s // block_q, t // block_k

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        causal=causal,
        window=window,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda b_, h_, q_, k_: (b_, h_, q_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
