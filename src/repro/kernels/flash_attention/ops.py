"""Public jit'd wrapper for the flash-attention kernel.

``interpret=None`` auto-resolves via ``kernels.dispatch`` (compiled Pallas on
TPU/GPU, interpreter on CPU; ``REPRO_PALLAS_INTERPRET`` overrides).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def _flash_attention_jit(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> jnp.ndarray:
    return flash_attention_fwd(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=interpret,
    )


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """q: (B, H, S, Dh); k/v: (B, KV, T, Dh) with H % KV == 0 → (B, H, S, Dh)."""
    return _flash_attention_jit(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=resolve_interpret(interpret),
    )
