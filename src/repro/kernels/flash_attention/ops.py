"""Public jit'd wrapper for the flash-attention kernel.

``interpret`` defaults to True in this CPU container (the kernel body runs in
Python for correctness validation); on real TPU pass interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q: (B, H, S, Dh); k/v: (B, KV, T, Dh) with H % KV == 0 → (B, H, S, Dh)."""
    return flash_attention_fwd(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
