"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (B, H, S, Dh)
    k: jnp.ndarray,  # (B, KV, T, Dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
) -> jnp.ndarray:
    b, h, s, dh = q.shape
    kv = k.shape[1]
    g = h // kv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) / math.sqrt(dh)
    t = kr.shape[2]
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)
