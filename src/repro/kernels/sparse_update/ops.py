"""Public wrapper: unique/inverse prep, backend resolution, dispatch.

``fused_sparse_step`` applies one margin-ranking SGD step to {ent, rel}
tables touching only the rows named by the minibatch. The unique-index
decomposition happens here (``jnp.unique`` with a static ``size`` — jit-safe)
so duplicate rows within a batch compose into a single update; the kernel
receives conflict-free unique row ids.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import resolve_interpret
from repro.kernels.sparse_update.sparse_update import (
    SPARSE_MODES,
    sparse_sgd_step_fwd,
)


def unique_rows(occ: jnp.ndarray, size: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(unique ids (size,), inverse (len(occ),)). Fill slots alias row 0 —
    always in range for the kernel's read-modify-write loop — and receive no
    occurrences, hence zero gradient: their writes are exact no-ops."""
    u, inv = jnp.unique(occ, return_inverse=True, size=size, fill_value=0)
    return u.astype(jnp.int32), inv.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "margin", "interpret", "unique_e", "unique_r"),
)
def _fused_sparse_step_jit(
    ent, rel, pos, neg, lr, *, mode, margin, interpret, unique_e, unique_r
):
    b = pos.shape[0]
    e_occ = jnp.concatenate([pos[:, 0], pos[:, 2], neg[:, 0], neg[:, 2]])
    r_occ = jnp.concatenate([pos[:, 1], neg[:, 1]])
    ue, inv_e = unique_rows(e_occ, unique_e or 4 * b)
    ur, inv_r = unique_rows(r_occ, unique_r or 2 * b)
    new_ent, new_rel, loss = sparse_sgd_step_fwd(
        ent.astype(jnp.float32), rel.astype(jnp.float32),
        inv_e, inv_r, ue, ur, jnp.reshape(lr, (1, 1)).astype(jnp.float32),
        mode=mode, margin=margin, interpret=interpret,
    )
    return new_ent, new_rel, loss[0, 0]


def fused_sparse_step(
    ent: jnp.ndarray,  # (E, d) entity table
    rel: jnp.ndarray,  # (R, d) relation table
    pos: jnp.ndarray,  # (B, 3) int32 positive triples
    neg: jnp.ndarray,  # (B, 3) int32 corrupted triples
    lr,
    *,
    mode: str = "l1",
    margin: float = 4.0,
    interpret: Optional[bool] = None,
    unique_e: Optional[int] = None,
    unique_r: Optional[int] = None,
):
    """One fused gather→score→scatter SGD step → (new_ent, new_rel, loss).

    ``unique_e``/``unique_r`` cap the unique-row sets (static): 3B/B when
    ``neg`` is a 1:1 corruption of ``pos`` (the training-scan path — the
    uncorrupted side and the relation are shared), 4B/2B for arbitrary
    batches (default).
    """
    assert mode in SPARSE_MODES, mode
    return _fused_sparse_step_jit(
        ent, rel, pos, neg, lr, mode=mode, margin=float(margin),
        interpret=resolve_interpret(interpret),
        unique_e=unique_e, unique_r=unique_r,
    )
