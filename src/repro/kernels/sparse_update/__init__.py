from repro.kernels.sparse_update.ops import (  # noqa: F401
    fused_sparse_step,
    unique_rows,
)
from repro.kernels.sparse_update.ref import sparse_step_ref  # noqa: F401
from repro.kernels.sparse_update.sparse_update import SPARSE_MODES  # noqa: F401
