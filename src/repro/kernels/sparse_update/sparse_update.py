"""Fused gather→score→scatter SGD step — the local-training hot spot.

One margin-ranking SGD step on a (pos, neg) minibatch touches at most 3B
entity rows and B relation rows, yet the dense update writes the full (E, d)
table. This kernel keeps the embedding tables resident (aliased in/out, so
XLA updates them in place) and moves only the touched rows:

  gather   — unique touched rows are pulled out of the table with dynamic
             row slices (``pl.ds``), never materializing the table as a value;
  score    — margin-ranking loss + analytic gradients for the decomposable
             hot-path families (TransE L1/L2, DistMult), vectorized over the
             batch; per-occurrence gradients are segment-summed into unique
             row slots with a one-hot matmul (MXU-friendly, deterministic);
  scatter  — a serial read-modify-write loop applies ``row -= lr·g`` for each
             unique row. Uniqueness makes the writes conflict-free; the fill
             slots of the padded unique set carry zero gradients, so their
             clamped writes are exact no-ops.

The caller supplies the unique/inverse decomposition (``jnp.unique`` with a
static ``size``); duplicate rows within a batch therefore compose exactly once
into the update. Grid is (1,) — one kernel launch per optimizer step — so
there is no cross-step write race on any backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: families the fused kernel handles: score modes of the decomposable hot path
SPARSE_MODES = ("l1", "l2", "dot")


def _margin_grads(he, re, te, nhe, nre, nte, *, mode: str, margin: float):
    """Loss + analytic per-occurrence gradients of the margin ranking loss.

    Matches jax autodiff conventions exactly: ``relu'(0) = 0``, ``d|x|/dx =
    sign(x)`` (0 at 0), and the L2 norm is ``sqrt(Σx² + 1e-12)`` as in
    ``models._norm``.
    """
    b = he.shape[0]

    if mode == "dot":  # distmult: s = Σ h·r·t
        sp = jnp.sum(he * re * te, axis=-1)
        sn = jnp.sum(nhe * nre * nte, axis=-1)
    else:
        dp = he + re - te
        dn = nhe + nre - nte
        if mode == "l1":
            sp = -jnp.sum(jnp.abs(dp), axis=-1)
            sn = -jnp.sum(jnp.abs(dn), axis=-1)
            gp, gn = jnp.sign(dp), jnp.sign(dn)
        else:  # l2
            np_ = jnp.sqrt(jnp.sum(dp * dp, axis=-1) + 1e-12)
            nn_ = jnp.sqrt(jnp.sum(dn * dn, axis=-1) + 1e-12)
            sp, sn = -np_, -nn_
            gp, gn = dp / np_[:, None], dn / nn_[:, None]

    act = margin - sp + sn
    loss = jnp.mean(jnp.maximum(act, 0.0))
    # dL/dsp_i = −a_i, dL/dsn_i = +a_i with a_i = 1[act_i > 0]/B
    a = (act > 0).astype(jnp.float32)[:, None] / b

    if mode == "dot":
        g_he = -a * (re * te)
        g_te = -a * (he * re)
        g_re = -a * (he * te)
        g_nhe = a * (nre * nte)
        g_nte = a * (nhe * nre)
        g_nre = a * (nhe * nte)
    else:
        # sp = −‖he + re − te‖ ⇒ ∂sp/∂he = −g, ∂sp/∂te = +g, ∂sp/∂re = −g
        g_he = a * gp
        g_te = -a * gp
        g_re = a * gp
        g_nhe = -a * gn
        g_nte = a * gn
        g_nre = -a * gn
    return loss, (g_he, g_te, g_nhe, g_nte), (g_re, g_nre)


def _sparse_step_kernel(
    inv_e_ref,  # (4B,) i32 occurrence → unique-entity slot
    inv_r_ref,  # (2B,) i32 occurrence → unique-relation slot
    ue_ref,     # (Ue,) i32 unique entity row ids (fills clamped, zero-grad)
    ur_ref,     # (Ur,) i32 unique relation row ids
    lr_ref,     # (1, 1) f32 learning rate
    ent_ref,    # (E, d) — aliased input (same buffer as ent_out)
    rel_ref,    # (R, d) — aliased input (same buffer as rel_out)
    ent_out,    # (E, d) in-place updated entity table
    rel_out,    # (R, d) in-place updated relation table
    loss_ref,   # (1, 1) f32 minibatch loss
    *,
    mode: str,
    margin: float,
    batch: int,
):
    del ent_ref, rel_ref  # aliased: read/write through the out refs
    d = ent_out.shape[1]
    b = batch
    ue_n = ue_ref.shape[0]
    ur_n = ur_ref.shape[0]

    # ---- gather: unique rows only, via dynamic row slices ----------------
    def g_ent(i, acc):
        return acc.at[i, :].set(ent_out[pl.ds(ue_ref[i], 1), :][0])

    erows = jax.lax.fori_loop(0, ue_n, g_ent, jnp.zeros((ue_n, d), jnp.float32))

    def g_rel(i, acc):
        return acc.at[i, :].set(rel_out[pl.ds(ur_ref[i], 1), :][0])

    rrows = jax.lax.fori_loop(0, ur_n, g_rel, jnp.zeros((ur_n, d), jnp.float32))

    # ---- score + analytic grads, vectorized over the batch ---------------
    inv_e = inv_e_ref[...]
    inv_r = inv_r_ref[...]
    he, te = erows[inv_e[:b]], erows[inv_e[b : 2 * b]]
    nhe, nte = erows[inv_e[2 * b : 3 * b]], erows[inv_e[3 * b :]]
    re, nre = rrows[inv_r[:b]], rrows[inv_r[b:]]
    loss, ent_occ, rel_occ = _margin_grads(
        he, re, te, nhe, nre, nte, mode=mode, margin=margin
    )
    loss_ref[0, 0] = loss

    # ---- segment-sum occurrences → unique slots (one-hot matmul) ----------
    g_eocc = jnp.concatenate(ent_occ, axis=0)  # (4B, d)
    g_rocc = jnp.concatenate(rel_occ, axis=0)  # (2B, d)
    onehot_e = (inv_e[None, :] == jnp.arange(ue_n)[:, None]).astype(jnp.float32)
    onehot_r = (inv_r[None, :] == jnp.arange(ur_n)[:, None]).astype(jnp.float32)
    g_ent = jax.lax.dot_general(  # (Ue, 4B) @ (4B, d)
        onehot_e, g_eocc, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    g_rel = jax.lax.dot_general(
        onehot_r, g_rocc, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # ---- scatter: serial read-modify-write of the unique rows -------------
    lr = lr_ref[0, 0]

    def s_ent(i, _):
        row = ent_out[pl.ds(ue_ref[i], 1), :]
        ent_out[pl.ds(ue_ref[i], 1), :] = row - lr * g_ent[i][None, :]
        return 0

    jax.lax.fori_loop(0, ue_n, s_ent, 0)

    def s_rel(i, _):
        row = rel_out[pl.ds(ur_ref[i], 1), :]
        rel_out[pl.ds(ur_ref[i], 1), :] = row - lr * g_rel[i][None, :]
        return 0

    jax.lax.fori_loop(0, ur_n, s_rel, 0)


def sparse_sgd_step_fwd(
    ent: jnp.ndarray,    # (E, d) f32 entity table (updated in place)
    rel: jnp.ndarray,    # (R, d) f32 relation table (updated in place)
    inv_e: jnp.ndarray,  # (4B,) i32 [pos_h | pos_t | neg_h | neg_t] → slot
    inv_r: jnp.ndarray,  # (2B,) i32 [pos_r | neg_r] → slot
    ue: jnp.ndarray,     # (Ue,) i32 unique entity rows, fills clamped in-range
    ur: jnp.ndarray,     # (Ur,) i32 unique relation rows
    lr: jnp.ndarray,     # (1, 1) f32
    *,
    mode: str,
    margin: float,
    interpret: bool = True,
):
    """One fused sparse SGD step; returns (new_ent, new_rel, loss (1,1))."""
    assert mode in SPARSE_MODES, mode
    batch = inv_e.shape[0] // 4
    kernel = functools.partial(
        _sparse_step_kernel, mode=mode, margin=margin, batch=batch
    )
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct(ent.shape, jnp.float32),
            jax.ShapeDtypeStruct(rel.shape, jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(inv_e, inv_r, ue, ur, lr, ent, rel)
