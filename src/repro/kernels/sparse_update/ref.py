"""Pure-jnp oracle for the fused sparse SGD step — dense autodiff, tests only."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _scores(ent, rel, tri, mode):
    he, re, te = ent[tri[:, 0]], rel[tri[:, 1]], ent[tri[:, 2]]
    if mode == "dot":
        return jnp.sum(he * re * te, axis=-1)
    d = he + re - te
    if mode == "l2":
        return -jnp.sqrt(jnp.sum(d * d, axis=-1) + 1e-12)
    return -jnp.sum(jnp.abs(d), axis=-1)


def sparse_step_ref(ent, rel, pos, neg, lr, *, mode="l1", margin=4.0):
    """Dense margin-ranking SGD step on {ent, rel} — the parity oracle."""

    def loss_fn(p):
        sp = _scores(p["ent"], p["rel"], pos, mode)
        sn = _scores(p["ent"], p["rel"], neg, mode)
        return jnp.mean(jax.nn.relu(margin - sp + sn))

    p = {"ent": ent.astype(jnp.float32), "rel": rel.astype(jnp.float32)}
    loss, g = jax.value_and_grad(loss_fn)(p)
    return p["ent"] - lr * g["ent"], p["rel"] - lr * g["rel"], loss
