"""Roofline terms from dry-run artifacts (TPU v5e constants).

    compute_s    = FLOPs / (chips × peak_FLOP/s)
    memory_s     = HBM bytes / (chips × HBM_bw)
    collective_s = collective bytes / (chips × link_bw)

Two variants are reported:

* **hlo-raw** — straight from ``compiled.cost_analysis()`` and a flat HLO
  text scan, as the assignment formula prescribes. Caveat (verified
  empirically, see EXPERIMENTS.md §Dry-run): XLA's cost analysis counts a
  ``while`` (scan) body ONCE, so programs built on scan-over-layers ×
  grad-accumulation undercount by the product of trip counts.
* **corrected** — FLOPs/HBM from an analytic per-architecture cost model
  (the same 6·N·D-style accounting MFU reports use, plus attention/SSD
  quadratic terms), and collective bytes from the loop-aware HLO walk
  (``utils.hlo.loop_aware_collective_bytes``) that multiplies each
  computation's collectives by its enclosing trip counts.

The bottleneck verdict and the §Perf iterations use the corrected terms.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); useful-FLOPs ratio =
MODEL_FLOPS / corrected executed FLOPs (catches remat/redundancy waste).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~)


def model_flops(cfg, shape) -> float:
    """6·N·D with N = active params, D = tokens processed by the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def _attn_flops_fwd(cfg, b: int, s: int, cache: int = 0) -> float:
    """Score+context matmul FLOPs for ALL attention layers, forward, global."""
    n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.num_layers))
    h, dh = cfg.num_heads, cfg.head_dim
    if cache:  # decode: one query against the cache
        eff = min(cache, cfg.sliding_window) if cfg.sliding_window else cache
        per_layer = 4.0 * b * eff * h * dh
    else:
        eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        per_layer = 2.0 * b * s * eff * h * dh  # causal ≈ half of 4·B·S·eff
    total = n_attn * per_layer
    if cfg.encoder_layers and not cache:
        se = cfg.encoder_seq
        total += cfg.encoder_layers * 4.0 * b * se * se * h * dh  # bidirectional
        total += cfg.num_layers * 4.0 * b * s * se * h * dh  # cross-attn
    return total


def _ssd_flops_fwd(cfg, b: int, s: int) -> float:
    if not cfg.ssm.enabled:
        return 0.0
    n_ssm = sum(
        (not cfg.is_attn_layer(i)) for i in range(cfg.num_layers)
    ) if cfg.arch_type in ("ssm", "hybrid") else 0
    if not n_ssm:
        return 0.0
    q = cfg.ssm.chunk_size
    h = cfg.ssm.num_heads(cfg.d_model)
    p = cfg.ssm.head_dim
    n = cfg.ssm.d_state
    # per chunk: scores 2Q²N + y 2Q²PH + state 2QPNH ; chunks = S/Q
    per_tok = 2.0 * q * n + 2.0 * q * p * h + 2.0 * p * n * h
    return n_ssm * b * s * per_tok


def analytic_cost(cfg, shape) -> Tuple[float, float]:
    """→ (executed FLOPs, HBM bytes) for the whole step, global (all chips)."""
    b, s = shape.global_batch, shape.seq_len
    p_active = cfg.active_param_count()
    p_total = cfg.param_count()
    v_d = cfg.padded_vocab * cfg.d_model
    n_eff = p_active - (0 if cfg.tie_embeddings else v_d)  # input gather ≉ matmul
    dt_bytes = 2  # bf16 params/activations

    if shape.kind == "train":
        tokens = b * s
        fwd = 2.0 * n_eff * tokens + _attn_flops_fwd(cfg, b, s) + _ssd_flops_fwd(cfg, b, s)
        mult = 3.0 + (1.0 if cfg.remat else 0.0)  # fwd + 2×bwd (+ remat refwd)
        flops = fwd * mult
        # HBM: weights re-read every microbatch for fwd/bwd/remat; moments;
        # activation residual traffic ~12·d bytes/token/layer each direction.
        m = 16  # default microbatches (launch/workloads.default_train_config)
        traffic_params = p_total * dt_bytes * m * mult
        opt = p_total * (4 + 4 + 4 + 2) * 2.0  # mu,nu,grad read+write, param rw
        act = tokens * cfg.d_model * cfg.num_layers * 12 * dt_bytes
        return flops, traffic_params + opt + act
    if shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * n_eff * tokens + _attn_flops_fwd(cfg, b, s) + _ssd_flops_fwd(cfg, b, s)
        cache_bytes = _cache_bytes(cfg, b, s, dt_bytes)
        act = tokens * cfg.d_model * cfg.num_layers * 8 * dt_bytes
        return flops, p_total * dt_bytes + cache_bytes + act
    # decode: one token, cache length = shape.seq_len
    flops = 2.0 * n_eff * b + _attn_flops_fwd(cfg, b, 1, cache=s)
    cache_bytes = _cache_bytes(cfg, b, s, dt_bytes)
    return flops, p_total * dt_bytes + cache_bytes


def _cache_bytes(cfg, b: int, s: int, dt_bytes: int) -> float:
    n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.num_layers))
    kv = 2.0 * b * s * cfg.num_kv_heads * cfg.head_dim * dt_bytes * n_attn
    ssm = 0.0
    if cfg.ssm.enabled and cfg.arch_type in ("ssm", "hybrid"):
        n_ssm = cfg.num_layers - n_attn
        h = cfg.ssm.num_heads(cfg.d_model)
        ssm = b * h * cfg.ssm.head_dim * cfg.ssm.d_state * 4.0 * n_ssm
    return kv + ssm


def roofline_terms(
    cfg, shape, dryrun_result: Dict[str, Any], *, chips: int
) -> Dict[str, Any]:
    cost = dryrun_result["cost"]
    coll = dryrun_result["collectives"]
    coll_corr = dryrun_result.get("collectives_corrected", coll)

    # hlo-raw (assignment formula; per-device numbers from the SPMD program)
    raw = {
        "compute_s_raw": cost["flops"] / PEAK_FLOPS,
        "memory_s_raw": cost["bytes_accessed"] / HBM_BW,
        "collective_s_raw": coll.get("total", 0) / ICI_BW,
    }
    # corrected (analytic flops/bytes are GLOBAL → divide by chips)
    flops_g, hbm_g = analytic_cost(cfg, shape)
    terms = {
        "compute_s": flops_g / chips / PEAK_FLOPS,
        "memory_s": hbm_g / chips / HBM_BW,
        "collective_s": coll_corr.get("total", 0) / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(cfg, shape)
    return {
        **terms,
        **raw,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "executed_flops": flops_g,
        "useful_flops_ratio": mf / flops_g if flops_g else 0.0,
        "hbm_bytes": hbm_g,
    }
