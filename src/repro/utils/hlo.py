"""HLO text parsing: collective traffic accounting.

``cost_analysis()`` does not expose collective bytes, so we parse the
compiled HLO and sum the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute. Sizes are computed from the
result shape strings (e.g. ``bf16[16,1024,4096]``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches e.g. "  %x = bf16[2,16,128]{2,1,0} all-gather(...)" and tuple results
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^\s]*\s*,?\s*)+)\s*(?:\))?\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", re.M)
_WHILE_RE = re.compile(
    r"while\(([^)]*)\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """→ {computation_name: body_text} including the ENTRY computation.

    Computation headers sit at column 0 (instructions are indented):
      ``%name (params...) -> type {``  /  ``ENTRY %name (...) -> ... {``
    Params may contain nested tuple parens, so we only key off the leading
    token."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        is_header = (
            line
            and not line[0].isspace()
            and "{" in line
            and ("->" in line)
            and (line.startswith("%") or line.startswith("ENTRY"))
        )
        if is_header:
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            tok = line.split()[1] if line.startswith("ENTRY") else line.split()[0]
            cur_name = tok.lstrip("%")
            cur_lines = [line]
        elif cur_name:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count(cond_text: str) -> int:
    """Loop bound = the largest integer constant in the condition computation
    (the induction-variable compare); 1 if none found (conservative)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def loop_aware_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Collective bytes with while-loop trip multipliers.

    XLA's cost_analysis (and a naive text scan) count a scan body once; this
    walks ENTRY → while bodies, multiplying each computation's collectives by
    the product of enclosing trip counts. Needed because every per-layer
    collective sits inside the layer scan × microbatch scan.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None and comps:
        entry = list(comps)[-1]

    totals: Dict[str, float] = defaultdict(float)
    count = 0
    seen = set()

    def visit(name: str, mult: float):
        nonlocal count
        if name not in comps or (name, mult) in seen:
            return
        seen.add((name, mult))
        text = comps[name]
        for m in _OP_RE.finditer(text):
            shapes, kind = m.group(1), m.group(2)
            if f"{kind}-done" in m.group(0):
                continue
            totals[kind] += _shape_bytes(shapes) * mult
            count += 1
        for wm in _WHILE_RE.finditer(text):
            cond, body = wm.group(2), wm.group(3)
            trips = _trip_count(comps.get(cond, ""))
            visit(body, mult * trips)

    if entry:
        visit(entry, 1.0)
    out = {k: int(v) for k, v in totals.items()}
    out["total"] = int(sum(v for k, v in totals.items() if k in _COLLECTIVES))
    out["count"] = count
    return out


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """→ {op_kind: summed result bytes} + 'total' + 'count'.

    Bytes are per-SPMD-program (i.e. per device) since compiled HLO for SPMD
    is the single-device program.
    """
    out: Dict[str, int] = defaultdict(int)
    count = 0
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        # '-start' ops are paired with '-done'; count starts only
        if f"{kind}-done" in m.group(0):
            continue
        out[kind] += _shape_bytes(shapes)
        count += 1
    out["total"] = sum(v for k, v in out.items() if k in _COLLECTIVES)
    out["count"] = count
    return dict(out)


def peak_memory_bytes(mem) -> int:
    """``CompiledMemoryStats.peak_memory_in_bytes`` with a jax-0.4.x fallback
    (argument + output + temp — the upper bound XLA reports pieces of)."""
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is not None:
        return int(peak)
    return int(
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
    )


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as one flat dict — jax 0.4.x returns a
    one-element list of per-program dicts, newer jax the dict itself."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)
