"""Batched federation tick engine — one device program per scheduler tick.

After PR 1/PR 2 made eval and local training device-resident, a federation
tick was still a serial Python loop: each Ready owner got its own
``train_ppat`` call, its own retrain dispatch, and its own backtrack-score
call, with eager aggregation glue (gathers, procrustes, scatters, virtual
extension) and host syncs between every stage. Tick wall-clock grew linearly
in owner count and the device idled between handshakes.

This engine turns the scheduler into a *planner*: at tick start it collects
every Ready owner's pending work into a tick plan — (client → host)
handshake pairs plus self-train owners — and executes the whole tick on
device with host syncs only at the tick boundary. Each plan entry is an
independent program that chains the full pipeline in-graph:

    PPAT (init + all adversarial rounds) → synthesize + procrustes refine →
    KGEmb aggregation (entity/relation scatter) → virtual extension →
    bucket-padded retrain scan → strip → backtrack scoring
    (accuracy threshold scores or fused-rank hit@10 counts)

Host-side work per tick shrinks to: splitting keys, the accept/reject
decisions, snapshot/broadcast bookkeeping, and the moments accountant.

**Trace-time program dedup.** Entries are grouped by signature — the static
``EntrySpec`` plus the input pytree's shapes/dtypes (``entry_signature``) —
and one program is traced and compiled per unique signature, not per owner:
N equal-shaped owners (the paper's decentralized deployments are exactly
this) compile ONE tick-entry program where the PR 3 whole-tick mega-program
compiled N identical subgraph copies (~1 min one-time for 8 owners on CPU
CI). All entry dispatches are asynchronous; the engine blocks once, at the
end of the tick.

**Multi-device placement** (``kernels.dispatch.resolve_tick_placement`` /
``REPRO_TICK_PLACEMENT``): with ``placement="sharded"`` (the ``auto``
default whenever >1 device is visible — on CPU CI via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) a signature bucket
of equal-shaped owners is stacked along a leading owner axis and executed
by one ``shard_map`` SPMD program over the ``("owners",)`` mesh
(``core.distributed.owner_shard_map``) — one device per owner, still ONE
compile per bucket. ``placement="single"`` keeps every entry program on the
default device.

**Owner-sticky device residency** (``kernels.dispatch.resolve_tick_residency``
/ ``REPRO_TICK_RESIDENCY``): every owner gets a sticky home device from the
engine's ``core.distributed.OwnerPlacement`` registry — assigned once, in
registration order, and stable across plan recomposition — and its state
LIVES there across ticks:

  * immutable per-owner inputs (padded triple stores, aligned-index uploads,
    virtual-extension id sets, backtrack-scoring negatives/CSR filters, and
    the per-entry scalars) are cached per (owner, version, device): committed
    once on first use and re-referenced every subsequent tick — the
    steady-state tick performs ZERO ``device_put`` of cached immutable
    inputs (pinned by the transfer-guard regression test);
  * shard_map group operands are assembled zero-copy from the resident
    per-owner shards via ``jax.make_array_from_single_device_arrays``
    (``core.distributed.assemble_group``) instead of ``jnp.stack`` +
    re-shard, and group outputs are split back into still-committed
    per-owner shards (``disassemble_group``) — an owner's new embedding
    tables never leave its device; only the scalar decisions/scores/ε sync
    to host;
  * with ``residency="resident"`` (default) accepted params stay committed
    to the owner's device in trainer state — non-sharded consumers (the
    serial ``tick_impl="reference"`` path, eval, checkpointing, serving)
    accept committed arrays; ``residency="normalize"`` restores the old
    normalize-to-device-0 behavior;
  * signature buckets are cut into chunks whose extents are restricted to
    full-mesh or power-of-two sizes (``core.distributed.chunk_extents``),
    partial chunks padded with masked dummy entries (replicas of a real
    entry whose outputs are discarded) — a bucket shrinking by one owner
    re-pads into an already-compiled extent, capping group compiles per
    signature at ~log₂(devices) instead of one per exact bucket size;
  * the per-tick mutable leaves — params (already resident after the first
    tick), PPAT/train keys, and the tick-consistent client views (the
    paper's actual client → host communication) — move via explicit
    ``jax.device_put`` only.

Why per-entry programs / shard_map slices and not ``vmap``/``lax.map``
stacking: XLA recompiles a stacked body in a different fusion context,
which drifts results by ~1 ulp — enough to (rarely) flip an accept/reject
decision, and enough to break the bit-parity contract with the serial
reference path. The standalone entry program and the per-device shard_map
body, however, compile the SAME unstacked per-entry trace the serial path
jits (pinned by the tick parity tests at ≥4 simulated devices).

Everything immutable is cached across ticks per (client, host) pair or per
owner: aligned-index uploads, virtual-extension structure (neighbor ids,
joining relations, remapped adjacency triples), bucket-padded extended
triple stores, and backtrack-scoring inputs (fixed negatives, CSR filters).

**Level-aware streaming** (``tick_sync="stream"``): the streaming scheduler
calls ``execute`` once per dependency level instead of once per tick. The
engine is level-ready by construction: entry inputs (params, client views,
engine keys) are materialized at CALL time and every accept/restore is
applied before ``execute`` returns, so an update accepted at level k is
live state when level k+1's protos are built — the result feeding that
lets a same-pass re-offer handshake read a fresher version. Reaping stays
per-entry and asynchronous within a level (one ``block_until_ready`` per
entry, group fallback included), so a level's slowest entry bounds only
its own level, not the pass. Streamed passes carry pre-split PPAT keys on
their entries (``TickEntry.key_ppat``, assigned in plan order) so the
scheduler key stream is consumed in barrier order no matter how the level
cut interleaves owners.

Bit-parity contract (asserted by ``tests/test_tick_engine.py`` and the tick
benchmark): with the same per-pair keys, a batched tick produces the same
accept/reject decisions, the same scores, the same ε history, and
bit-identical embeddings as ``tick_impl="reference"`` — per level under
streaming exactly as per tick under the barrier.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alignment import procrustes
from repro.core.ppat import PPAT_BUCKET, PPATConfig, _pad_rows, ppat_entry_graph
from repro.core.privacy import MomentsAccountant
from repro.kge.engine import (
    pad_tables,
    pad_triples,
    resolve_renorm,
    shape_spec,
    strip_tables,
    train_scan_graph,
)
from repro.kge.eval import side_counts_graph
from repro.kge.models import KGEModel, score_triples


# ---------------------------------------------------------------------------
# per-entry static spec + traced graph
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class EntrySpec:
    """Static (hashable) trace parameters for one tick-plan entry. Together
    with the input-array shapes it fully determines the entry subgraph; the
    tick program cache is keyed on the tuple of specs (jit re-specializes on
    shapes underneath)."""

    kind: str                  # "ppat" | "self-train"
    model: KGEModel            # logical-count model of the host owner
    epochs: int
    batch: int
    train_impl: str
    interpret: bool
    renorm: str                # entity-norm schedule, resolved at plan time
    cfg: Optional[PPATConfig]  # PPAT config (ppat entries only)
    aggregation: str
    refine: bool               # procrustes refinement on the DP release
    score: str                 # "accuracy" | "hit10" | "none"
    lp_batch: int              # hit10 chunk size (mirrors link_prediction)
    block_e: int
    #: Byzantine robust-acceptance mode over synthesized rows (ppat entries;
    #: "none" keeps the defenses-off trace byte-identical)
    robust: str = "none"
    #: whether the entry emits the cosine-shift screen statistic
    cos: bool = False


def _extend_params(
    p: Dict[str, jnp.ndarray], model: KGEModel, v_ent, v_rel
) -> Dict[str, jnp.ndarray]:
    """In-graph twin of ``KGETrainer.extend_tables`` — the per-family pad
    rules come from the same ``virtual_pad_rows`` definition."""
    from repro.kge.models import virtual_pad_rows

    p = dict(p)
    p["ent"] = jnp.concatenate([p["ent"], v_ent])
    p["rel"] = jnp.concatenate([p["rel"], v_rel])
    pads = virtual_pad_rows(p, model.dim, v_ent.shape[0], v_rel.shape[0])
    for k, pad in pads.items():
        p[k] = jnp.concatenate([p[k], pad])
    return p


def entry_graph(inp: Dict[str, jnp.ndarray], spec: EntrySpec) -> Dict:
    """One plan entry's full pipeline as a pure graph.

    Every stage calls the SAME functions the serial path traces
    (``ppat_entry_graph``, ``train_scan_graph``, ``side_counts_graph``,
    ``score_triples``) on identically-shaped inputs, so the per-entry
    subgraph is the serial path's compiled computation — the root of the
    batched-vs-reference bit-parity guarantee.
    """
    model = spec.model
    p = inp["params"]
    out: Dict = {}
    n_virt_e = n_virt_r = 0

    if spec.kind == "ppat":
        ce = inp["client_ent"]
        if "rel_c" in inp:
            # relation-aligned pairs keep exact-shape glue (rare; the
            # concatenated [ent | rel] layout cannot be segment-padded
            # without changing the PPAT sampling space)
            x = jnp.concatenate([ce[inp["idx_c"]],
                                 inp["client_rel"][inp["rel_c"]]])
            y = jnp.concatenate([p["ent"][inp["idx_h"]],
                                 p["rel"][inp["rel_h"]]])
            n_true = x.shape[0]
            x = _pad_rows(x, PPAT_BUCKET)
            y = _pad_rows(y, PPAT_BUCKET)
        else:
            # bucket-padded glue: index arrays are PPAT_BUCKET-padded at
            # plan time (client gathers clamp, host slots point one past the
            # table), rows beyond the true count are masked to the exact
            # zeros ``_pad_rows`` would produce — one compiled program
            # serves every pair whose alignment lands in the same bucket
            mask = (jnp.arange(inp["idx_c"].shape[0]) < inp["n_x"])[:, None]
            x = jnp.where(mask, ce[inp["idx_c"]], 0.0)
            y = jnp.where(mask, p["ent"][inp["idx_h"]], 0.0)
        hp, w, metrics, n0s, n1s = ppat_entry_graph(
            x, y, inp["n_x"], inp["n_y"], inp["key_ppat"], spec.cfg,
        )
        # hp is returned (not used host-side) so this subgraph keeps the
        # same live outputs as the serial _ppat_entry program
        out["ppat_host"], out["ppat_metrics"] = hp, metrics
        out["n0s"], out["n1s"] = n0s, n1s

        # DP-synthesized embeddings for the aligned set (host side); zero
        # padding rows synthesize to zero and add exact zeros to the
        # procrustes contraction — same shapes, same bits as the serial path
        synth = x @ w
        refine_mat = None
        if spec.refine:
            refine_mat = procrustes(synth, y)
            synth = synth @ refine_mat
        if spec.robust != "none" or spec.cos:
            # robust acceptance over the ENTITY rows of the synthesized
            # release (relation glue rows pass through untouched), on the
            # same padded shapes the serial path hands robust_rows — the
            # defenses-armed parity contract
            from repro.core.aggregation import robust_rows_graph

            n_rob = (
                jnp.int32(inp["idx_c"].shape[0]) if "rel_c" in inp
                else inp["n_x"]
            )
            synth, mean_cos = robust_rows_graph(
                y, synth, n_rob, mode=spec.robust, want_cos=spec.cos,
            )
            if spec.cos:
                out["mean_cos"] = mean_cos
        p = dict(p)
        if "rel_c" in inp:
            n_ent = inp["idx_c"].shape[0]
            new_ent = synth[:n_ent]
            if spec.aggregation == "average":
                new_ent = 0.5 * (p["ent"][inp["idx_h"]] + new_ent)
            p["ent"] = p["ent"].at[inp["idx_h"]].set(new_ent)
            cur = p["rel"][inp["rel_h"]]
            new = synth[n_ent:n_true]
            if spec.aggregation == "average":
                new = 0.5 * (cur + new)
            p["rel"] = p["rel"].at[inp["rel_h"]].set(new)
        else:
            new_ent = synth
            if spec.aggregation == "average":
                new_ent = 0.5 * (p["ent"][inp["idx_h"]] + new_ent)
            # padded slots index one past the table → dropped
            p["ent"] = p["ent"].at[inp["idx_h"]].set(new_ent, mode="drop")

        if "neigh" in inp:  # virtual extension: G(N(X)) in host space
            if refine_mat is None:
                gen = lambda e: e @ w                    # noqa: E731
            else:
                gen = lambda e: (e @ w) @ refine_mat     # noqa: E731
            # neigh/rels are bucket-padded; rows past the true virtual
            # counts hold garbage but are inert — no triple references
            # them, the corruption bound (traced true count) keeps them
            # out of negatives, and the final strip slices them away
            v_ent = gen(ce[inp["neigh"]])
            v_rel = gen(inp["client_rel_full"][inp["rels"]])
            p = _extend_params(p, model, v_ent, v_rel)
            n_virt_e, n_virt_r = v_ent.shape[0], v_rel.shape[0]

    # ---- retrain (KGEmb-Update / self-train) on bucket-padded tables ----
    counts = dataclasses.replace(
        model,
        num_entities=model.num_entities + n_virt_e,
        num_relations=model.num_relations + n_virt_r,
    )
    padded, _, _ = pad_tables(p, counts)
    padded, losses = train_scan_graph(
        padded, inp["triples"], inp["key_train"], inp["lr"],
        inp["num_entities"],
        spec=shape_spec(model), epochs=spec.epochs, batch=spec.batch,
        impl=spec.train_impl, interpret=spec.interpret, renorm=spec.renorm,
    )
    out["losses"] = losses
    p = strip_tables(padded, model)  # bucket padding AND virtual rows off
    out["params"] = p

    # ---- backtrack scoring ---------------------------------------------
    if spec.score == "accuracy":
        va, vn = inp["va"], inp["va_neg"]
        sp = score_triples(p, model, va[:, 0], va[:, 1], va[:, 2])
        sn = score_triples(p, model, vn[:, 0], vn[:, 1], vn[:, 2])
        out["score"] = (sp, sn)
    elif spec.score == "hit10":
        test, ft, fh = inp["test"], inp["filt_t"], inp["filt_h"]
        chunks = []
        for i in range(0, test.shape[0], spec.lp_batch):
            j = i + spec.lp_batch
            c = test[i:j]
            kw = dict(block_e=spec.block_e)
            ct = side_counts_graph(
                p, model, c[:, 0], c[:, 1], c[:, 2], ft[i:j], side="tail", **kw
            )
            ch = side_counts_graph(
                p, model, c[:, 0], c[:, 1], c[:, 2], fh[i:j], side="head", **kw
            )
            chunks.append((ct, ch))
        out["score"] = tuple(chunks)
    return out


#: compiled per-entry programs, keyed by EntrySpec (jit further specializes
#: on input shapes — bucket padding keeps those stable, so steady-state
#: federation reuses one program per entry signature). The caches are
#: deliberately module-global with process lifetime, like jax.jit's own
#: compilation cache: schedulers over the same universe (parity tests, the
#: tick benchmark's reference/batched/sharded trio) share programs instead
#: of paying the compile per instance.
_ENTRY_PROGRAMS: Dict[EntrySpec, "jax.stages.Wrapped"] = {}

#: shard_map'ed group programs, keyed by (EntrySpec, group extent): one SPMD
#: program serves a whole signature bucket of equal-shaped owners
_GROUP_PROGRAMS: Dict[Tuple[EntrySpec, int], "jax.stages.Wrapped"] = {}


def _entry_program(spec: EntrySpec):
    prog = _ENTRY_PROGRAMS.get(spec)
    if prog is None:
        prog = jax.jit(functools.partial(entry_graph, spec=spec))
        _ENTRY_PROGRAMS[spec] = prog
    return prog


def _group_entry_graph(stacked: Dict, spec: EntrySpec) -> Dict:
    """shard_map body: each mesh device holds a local extent-1 slice of the
    stacked group inputs; dropping it runs the UNSTACKED entry graph — the
    identical trace (hence identical fusion, hence identical bits) to the
    single-entry program, unlike vmap/lax.map stacking (see module doc)."""
    inp = jax.tree.map(lambda x: x[0], stacked)
    out = entry_graph(inp, spec)
    return jax.tree.map(lambda x: x[None], out)


def _group_program(spec: EntrySpec, extent: int):
    key = (spec, extent)
    prog = _GROUP_PROGRAMS.get(key)
    if prog is None:
        from repro.core.distributed import owner_shard_map

        prog = jax.jit(
            owner_shard_map(
                functools.partial(_group_entry_graph, spec=spec), extent
            )
        )
        _GROUP_PROGRAMS[key] = prog
    return prog


def entry_signature(spec: EntrySpec, inp: Dict) -> Tuple:
    """The trace-time dedup key: the static spec plus the input pytree's
    structure/shapes/dtypes. Two plan entries with equal signatures are
    served by ONE traced-and-compiled program — N equal-shaped owners cost
    one compile, not N."""
    leaves, treedef = jax.tree.flatten(inp)
    return (
        spec, treedef,
        tuple((x.shape, str(jnp.result_type(x))) for x in leaves),
    )


def tick_program_cache_size() -> int:
    """Number of compiled tick-entry program specializations (single-entry
    and shard_map group programs together). Both tick-level invariants are
    asserted against this counter: steady-state ticks must not retrace, and
    N equal-shaped owners must compile exactly one program per unique entry
    signature — not one per owner."""
    progs = list(_ENTRY_PROGRAMS.values()) + list(_GROUP_PROGRAMS.values())
    return sum(p._cache_size() for p in progs)


# ---------------------------------------------------------------------------
# the engine: per-scheduler caches + tick execution
# ---------------------------------------------------------------------------
class TickEngine:
    """Executes a scheduler's tick plan as asynchronously dispatched,
    signature-deduped entry programs (optionally placed across devices),
    with one host sync per tick.

    Holds the cross-tick caches; everything cached is immutable for the
    scheduler's lifetime (KG splits, aligned index sets, virtual-extension
    structure, padded triple stores) or version-keyed on the owner's
    scoring universe (scoring inputs). Each cache entry's device leaves live
    under ``info["arrays"]`` and are committed per device on first use
    (``_resident_on``) — the owner-sticky placement registry keeps an owner
    on one device, so in steady state every cached input is referenced
    in place, never re-staged.
    """

    def __init__(self, sched):
        from repro.core.distributed import OwnerPlacement

        self.sched = sched
        self._pair: Dict[Tuple[str, str], Dict] = {}
        self._own: Dict[str, Dict] = {}
        self._score: Dict[str, Dict] = {}
        self._misc: Dict[str, Dict] = {}
        #: sticky owner → home device assignments (stable across plan
        #: recomposition; see core.distributed.OwnerPlacement)
        self.placement = OwnerPlacement()
        #: device_put count for per-device cache population — grows only on
        #: cache misses (first tick per (owner, version, device)), pinned
        #: flat across steady-state ticks by the transfer-guard test
        self.resident_transfers = 0

    # ------------------------------------------------------------- caches
    def _resident_on(self, info: Dict, device) -> Dict[str, jnp.ndarray]:
        """The committed-per-``device`` copy of a cache entry's array leaves,
        built (with ONE explicit transfer) on first use and referenced in
        place afterwards — steady-state ticks touch no cached bytes."""
        ondev = info.setdefault("_ondev", {})
        got = ondev.get(device)
        if got is None:
            got = jax.device_put(info["arrays"], device)
            ondev[device] = got
            self.resident_transfers += 1
        return got

    def _pair_info(self, client: str, host: str) -> Dict:
        key = (client, host)
        info = self._pair.get(key)
        if info is not None:
            return info
        from repro.kge.engine import ENT_BUCKET, REL_BUCKET, bucket

        sched = self.sched
        idx_c, idx_h = sched.registry.entities(client, host)
        rel = sched.registry.relations(client, host)
        has_rel = rel is not None and len(rel[0])
        host_tr = sched.trainers[host]
        e_log = host_tr.model.num_entities
        n_true = len(idx_c) + (len(rel[0]) if has_rel else 0)
        arrays: Dict[str, jnp.ndarray] = {}
        info = {"n_aligned": n_true, "arrays": arrays}
        # client-entity rows this handshake actually reads (aligned set,
        # plus virtual neighbors below) — the receiver-side corrupt screen
        # checks exactly these, matching the serial path's gather screens
        screen_idx = np.asarray(idx_c, np.int64)
        if has_rel:
            # exact-shape glue (see entry_graph) — no index padding
            arrays["idx_c"] = jnp.asarray(idx_c, jnp.int32)
            arrays["idx_h"] = jnp.asarray(idx_h, jnp.int32)
            arrays["rel_c"] = jnp.asarray(rel[0], jnp.int32)
            arrays["rel_h"] = jnp.asarray(rel[1], jnp.int32)
        else:
            # PPAT_BUCKET-padded index arrays → one compiled tick program
            # per alignment bucket, not per exact alignment size. Client
            # slots clamp to row 0 (rows are masked to zero in-graph); host
            # slots point one past the table so scatters drop them.
            n_pad = bucket(n_true, PPAT_BUCKET)
            ic = np.zeros(n_pad, np.int32)
            ic[:n_true] = idx_c
            ih = np.full(n_pad, e_log, np.int32)
            ih[:n_true] = idx_h
            arrays["idx_c"] = jnp.asarray(ic)
            arrays["idx_h"] = jnp.asarray(ih)
        n_virt = 0
        extra = None
        if sched.use_virtual:
            from repro.core.aggregation import virtual_structure

            vs = virtual_structure(
                sched.kgs[client], idx_c, idx_h,
                e_log, host_tr.model.num_relations,
            )
            if vs is not None:
                neigh, rels, extra = vs
                n_virt = len(neigh)
                # bucket-pad the virtual id sets too (slots clamp to row 0;
                # the resulting table rows are inert and stripped). Neighbor
                # counts vary by hundreds across pairs, so they round to a
                # power-of-two bucket — pair-to-pair variation must not
                # recompile the tick program.
                nv_pad = max(PPAT_BUCKET, 1 << (n_virt - 1).bit_length())
                nr_pad = bucket(len(rels), REL_BUCKET)
                npad = np.zeros(nv_pad, np.int32)
                npad[:n_virt] = neigh
                rpad = np.zeros(nr_pad, np.int32)
                rpad[: len(rels)] = rels
                arrays["neigh"] = jnp.asarray(npad)
                arrays["rels"] = jnp.asarray(rpad)
                screen_idx = np.concatenate(
                    [screen_idx, np.asarray(neigh, np.int64)]
                )
        info["screen_idx"] = screen_idx
        # extended triple store: train + virtual adjacency, cycle-padded —
        # immutable per pair, so upload + pad once instead of per handshake
        tr = sched.kgs[host].train
        if extra is not None and len(extra):
            tr = np.concatenate([tr, extra])
        b = min(host_tr.batch_size, len(tr))
        info["batch"] = b
        arrays["triples"] = pad_triples(jnp.asarray(tr, jnp.int32), b)
        # per-entry scalars are cached device arrays too: rebuilding them
        # from Python numbers every tick is a per-tick host→device transfer
        arrays["n_x"] = jnp.int32(n_true)
        arrays["n_y"] = jnp.int32(n_true)
        arrays["num_entities"] = jnp.int32(e_log + n_virt)  # true ext. count
        # the schedule the serial path resolves for this store/table size
        info["renorm"] = resolve_renorm(
            arrays["triples"].shape[0], bucket(e_log + n_virt, ENT_BUCKET)
        )
        self._pair[key] = info
        return info

    def _own_info(self, name: str) -> Dict:
        info = self._own.get(name)
        if info is not None:
            return info
        from repro.kge.engine import ENT_BUCKET, bucket

        sched = self.sched
        tr = sched.kgs[name].train
        model = sched.trainers[name].model
        b = min(sched.trainers[name].batch_size, len(tr))
        arrays = {
            "triples": pad_triples(jnp.asarray(tr, jnp.int32), b),
            "num_entities": jnp.int32(model.num_entities),
        }
        info = {"batch": b, "arrays": arrays}
        info["renorm"] = resolve_renorm(
            arrays["triples"].shape[0], bucket(model.num_entities, ENT_BUCKET)
        )
        self._own[name] = info
        return info

    def _misc_info(self, name: str) -> Dict:
        """Per-owner scalar leaves that are constant across ticks (the
        learning rate) — version-keyed on the value so a user mutating
        ``trainer.lr`` between runs is still honored."""
        lr = self.sched.trainers[name].lr
        info = self._misc.get(name)
        if info is None or info["version"] != (lr,):
            info = {"version": (lr,), "arrays": {"lr": jnp.float32(lr)}}
            self._misc[name] = info
        return info

    def _score_info(self, name: str) -> Dict:
        metric = self._metric_kind()
        version = self.sched._score_universe(name)
        info = self._score.get(name)
        if info is not None and info["metric"] == metric \
                and info["version"] == version:
            return info
        # (re)build — covers a score_fn swapped after a previous run AND an
        # owner whose scoring universe changed (e.g. an accepted virtual
        # extension that grew the entity table)
        sched = self.sched
        arrays: Dict[str, jnp.ndarray] = {}
        info = {"metric": metric, "version": version, "arrays": arrays}
        if metric == "accuracy":
            va, va_neg = sched._accuracy_inputs(name)
            arrays["va"] = jnp.asarray(va, jnp.int32)
            arrays["va_neg"] = jnp.asarray(va_neg, jnp.int32)
        elif metric == "hit10":
            test, filt_t, filt_h = sched._hit10_inputs(name)
            arrays["test"] = jnp.asarray(test, jnp.int32)
            arrays["filt_t"] = jnp.asarray(filt_t, jnp.int32)
            arrays["filt_h"] = jnp.asarray(filt_h, jnp.int32)
            info["ntest"] = len(test)
        self._score[name] = info
        return info

    def _metric_kind(self) -> str:
        """"accuracy"/"hit10" when the scheduler uses its default score
        functions (batchable in-graph), "none" for custom ``score_fn`` —
        those are scored host-side on the candidate params instead."""
        sched = self.sched
        fn = sched.score_fn
        if getattr(fn, "__func__", None) is type(sched)._valid_accuracy:
            return "accuracy"
        if getattr(fn, "__func__", None) is type(sched)._valid_hit10:
            return "hit10"
        return "none"

    # ---------------------------------------------------------- execution
    def _materialize(self, proto: Tuple[Dict, List], device) -> Dict:
        """One entry's full input pytree, every leaf committed to
        ``device``: resident leaves are referenced from the per-device
        caches (zero bytes moved in steady state), the per-tick mutable
        leaves (params, keys, client views) move via ONE explicit
        ``device_put`` — params are already resident after the first tick,
        so that put is a no-op for them."""
        mut, res = proto
        inp: Dict = {}
        for info, names in res:
            ondev = self._resident_on(info, device)
            for tgt, src in names.items():
                inp[tgt] = ondev[src]
        inp.update(jax.device_put(mut, device))
        return inp

    @staticmethod
    def _base_view(proto: Tuple[Dict, List]) -> Dict:
        """Device-independent view of an entry's inputs (the base cache
        copies), for signature computation before placement is decided."""
        mut, res = proto
        inp = dict(mut)
        for info, names in res:
            for tgt, src in names.items():
                inp[tgt] = info["arrays"][src]
        return inp

    def _dispatch(
        self,
        specs: List[EntrySpec],
        protos: List[Tuple[Dict, List]],
        owners: List[str],
        placement: str,
        residency: str,
    ) -> Tuple[List[Optional[Dict]], List[Optional[Exception]],
               List[Optional[Tuple[int, ...]]]]:
        """Launch every entry program asynchronously; returns
        ``(outs, errs, groups)`` in plan order: per-entry output pytrees
        (unmaterialized), per-entry dispatch exceptions, and for each entry
        the tuple of plan indices sharing its shard_map group output
        (``None`` for singletons) so the blocking phase can fall back when a
        group failure only surfaces at execution time.

        ``single``: every entry runs its signature's program on the default
        device. ``sharded``: entries are bucketed by signature and ordered
        by their owner's sticky home slot (``OwnerPlacement``); buckets are
        cut into ``chunk_extents`` chunks — full-mesh or power-of-two
        extents, partial chunks padded with masked dummy replicas of the
        chunk's last real entry — and each chunk runs as ONE shard_map
        program over the owner mesh, its operands assembled zero-copy from
        the resident per-owner shards. In the paper's symmetric deployment
        (N equal owners, N devices) every owner's chunk position IS its home
        slot, so nothing but keys and client views moves between devices;
        skewed buckets keep stable positions instead (an entry executing
        off-home leaves its params committed where it ran, so a stable
        bucket composition converges to zero per-tick movement too, with the
        per-device input caches absorbing the immutables). Group programs
        compile per (signature, chunk extent) — extents restricted to
        ``{devices} ∪ {2^k}`` cap that at ~log₂(devices) per signature.

        Fault isolation: each dispatch unit is wrapped, so one bad entry
        records a per-entry error instead of aborting the tick, and a
        shard_map group that fails AT DISPATCH falls back to per-entry
        execution of its members on their home devices — one poisoned owner
        never sinks its bucket-mates. Entries whose spec is ``None`` were
        already isolated by the fault layer and are skipped."""
        n = len(specs)
        outs: List[Optional[Dict]] = [None] * n
        errs: List[Optional[Exception]] = [None] * n
        groups: List[Optional[Tuple[int, ...]]] = [None] * n
        devices = jax.devices()

        def single(i: int, device) -> None:
            try:
                outs[i] = _entry_program(specs[i])(
                    self._materialize(protos[i], device)
                )
            except Exception as ex:  # noqa: BLE001 — isolate, don't abort
                errs[i] = ex

        if placement == "single":
            for i, spec in enumerate(specs):
                if spec is not None:
                    single(i, devices[0])
            return outs, errs, groups

        from repro.core.distributed import (
            assemble_group,
            chunk_extents,
            disassemble_group,
        )

        buckets: Dict[Tuple, List[int]] = {}
        for i, (spec, proto) in enumerate(zip(specs, protos)):
            if spec is None:
                continue
            sig = entry_signature(spec, self._base_view(proto))
            buckets.setdefault(sig, []).append(i)
        for sig, idxs in buckets.items():
            spec = specs[idxs[0]]
            # stable slot order: in the symmetric case chunk position k is
            # exactly home device k; ties (more owners than devices) break
            # by name so positions don't shuffle between equal-shaped ticks
            idxs = sorted(
                idxs, key=lambda i: (self.placement.slot(owners[i]), owners[i])
            )
            pos = 0
            for real, extent in chunk_extents(len(idxs), len(devices)):
                chunk = idxs[pos : pos + real]
                pos += real
                if extent == 1:
                    i = chunk[0]
                    # owner-sticky singleton: runs on (and leaves its
                    # results committed to) the owner's home device, no
                    # matter how the rest of the plan is composed
                    single(i, self.placement.device(owners[i]))
                    continue
                try:
                    members = [
                        self._materialize(protos[i], devices[k])
                        for k, i in enumerate(chunk)
                    ]
                    for k in range(real, extent):  # masked dummy tail
                        members.append(
                            self._materialize(protos[chunk[-1]], devices[k])
                        )
                    out = _group_program(spec, extent)(
                        assemble_group(members, extent)
                    )
                except Exception:  # noqa: BLE001 — group fallback
                    for i in chunk:
                        single(i, self.placement.device(owners[i]))
                    continue
                # dummy-position outputs are simply never read
                for shard, i in zip(disassemble_group(out, extent), chunk):
                    outs[i] = shard
                    groups[i] = tuple(chunk)
        if residency == "normalize":
            # legacy behavior: stage every result back to the default device
            # (None is an empty pytree node, so failed slots pass through)
            outs = jax.device_put(outs, devices[0])
        return outs, errs, groups

    def execute(
        self,
        entries: List,
        tick: int,
        *,
        placement: Optional[str] = None,
        residency: Optional[str] = None,
        faults=None,
        adversary=None,
        deadline: Optional[float] = None,
    ) -> List:
        """Run one planned tick batched; returns the FederationEvents, in
        plan order, with protocol side effects (accept/reject, snapshot,
        broadcast, ε accounting) applied exactly as the serial path does.

        ``faults`` (a ``core.faults.FaultInjector``, default ``None`` = the
        bit-identical pre-fault path) injects this tick's planned faults at
        the same protocol points as the serial engine: crash/drop isolate an
        entry BEFORE its PPAT key split and engine-key consume (so surviving
        entries draw from the same stream positions either engine would give
        them), corrupt client views are screened at proto-build time over
        exactly the rows the serial gathers read, and straggles add their
        simulated delay to the entry's measured wall-clock, tripping
        ``deadline`` — late results are discarded through the normal
        backtrack restore and the handshake deferred. One failing entry
        never aborts the tick.

        ``adversary`` (a ``core.adversary.Adversary``, default ``None`` =
        the bit-identical pre-attack path) tampers client views at the same
        fixed point as the serial engine: view → adversary tamper → fault
        corruption → receiver screens, all before any key is consumed — so
        the engines' key streams AND the adversary's replay cache stay in
        lockstep."""
        from repro.core.faults import CorruptEmbeddingError
        from repro.core.federation import FederationEvent, NodeState
        from repro.kge.eval import _metrics, best_threshold_accuracy
        from repro.kernels.dispatch import (
            resolve_interpret,
            resolve_tick_placement,
            resolve_tick_residency,
            resolve_train_impl,
        )

        sched = self.sched
        placement = resolve_tick_placement(
            placement if placement is not None else sched.tick_placement
        )
        residency = resolve_tick_residency(
            residency if residency is not None else sched.tick_residency
        )
        t0 = time.perf_counter()
        impls = {
            e.host: resolve_train_impl(None, sched.trainers[e.host].model.family)
            for e in entries
        }
        if "reference" in impls.values():
            # the host-loop dense path cannot be embedded in a tick program;
            # silently substituting the sparse step would betray the oracle
            # the user asked for — fail loudly before touching any state
            raise ValueError(
                "tick_impl='batched' cannot embed the 'reference' training "
                "step (REPRO_TRAIN_IMPL=reference); run with "
                "tick_impl='reference' instead"
            )
        n = len(entries)
        specs: List[Optional[EntrySpec]] = [None] * n
        protos: List[Optional[Tuple[Dict, List]]] = [None] * n
        owners: List[str] = [e.host for e in entries]
        entry_faults: List = [None] * n
        entry_attacks: List = [None] * n
        #: FederationEvents of entries isolated before dispatch
        pre_events: List[Optional[FederationEvent]] = [None] * n
        for i, e in enumerate(entries):
            tr = sched.trainers[e.host]
            fault = (
                faults.draw(tick, e.host, e.client)
                if faults is not None else None
            )
            entry_faults[i] = fault
            atk = (
                adversary.draw(tick, e.host, e.client)
                if adversary is not None and e.kind == "ppat" else None
            )
            entry_attacks[i] = atk
            pair = cview = None
            if atk is not None:
                # adversary tamper happens BEFORE crash/drop isolation (the
                # serial loop tampers every planned view): a replay attack's
                # stale-view cache must advance identically in both engines
                # even when the entry then dies to an injected fault
                pair = self._pair_info(e.client, e.host)
                cview = e.client_view or dict(sched.trainers[e.client].params)
                cview = adversary.tamper_view(
                    cview, atk, tick, e.host, e.client,
                    rows=pair["screen_idx"],
                )
            if fault is not None and fault.kind in ("crash", "drop"):
                # host dies / offer message lost before any work — isolated
                # BEFORE the PPAT key split and the engine-key consume, so
                # surviving entries draw from the same stream positions the
                # serial path would give them
                sched._entry_failed(
                    e.host, e.client if e.kind == "ppat" else None, fault.kind
                )
                pre_events[i] = sched.events[-1]
                continue
            metric = self._metric_kind()
            score_info = self._score_info(e.host)
            if e.kind == "ppat":
                if pair is None:
                    pair = self._pair_info(e.client, e.host)
                    cview = (
                        e.client_view
                        or dict(sched.trainers[e.client].params)
                    )
                if fault is not None and fault.kind == "corrupt":
                    cview = faults.corrupt_view(cview, fault, tick, e.host)
                if faults is not None:
                    # receiver-side integrity screen over exactly the rows
                    # the serial path's gathers read (aligned + virtual
                    # neighbors), before any key is consumed — the engines
                    # stay in lockstep on every stream
                    try:
                        sched.screen_incoming(
                            e.host, e.client, cview, bound=faults.norm_bound
                        )
                    except CorruptEmbeddingError:
                        sched._entry_failed(e.host, e.client, "corrupt")
                        pre_events[i] = sched.events[-1]
                        continue
            if sched.state[e.host] is not NodeState.QUARANTINED:
                # a mid-tick quarantine (this owner blamed as the client of
                # an earlier entry) survives its already-planned execution
                sched.state[e.host] = NodeState.BUSY
            # per-tick mutable leaves (explicit device_put at placement
            # time); everything else is referenced from the per-device
            # resident caches via (info, {input name: cache key}) entries
            mut: Dict = {
                "params": dict(tr.params),
                "key_train": tr.consume_engine_key(),
            }
            res: List[Tuple[Dict, Dict[str, str]]] = [
                (self._misc_info(e.host), {"lr": "lr"}),
            ]
            kw = dict(
                kind=e.kind,
                model=tr.model,
                epochs=sched.update_epochs,
                train_impl=impls[e.host],
                interpret=resolve_interpret(None),
                cfg=None,
                aggregation=sched.aggregation,
                refine=sched.procrustes_refine,
                score=metric,
                lp_batch=128,
                block_e=512,
            )
            if e.kind == "ppat":
                # streamed passes pre-split keys in plan order at pass
                # start (TickEntry.key_ppat) so per-level execution keeps
                # the barrier key-stream order; barrier ticks split here
                sub = getattr(e, "key_ppat", None)
                if sub is None:
                    sched._key, sub = jax.random.split(sched._key)
                # the client view is the paper's client → host communication
                # — per-tick state, shipped to the host's device explicitly
                mut.update(client_ent=cview["ent"], key_ppat=sub)
                names = {
                    k: k
                    for k in ("idx_c", "idx_h", "n_x", "n_y", "triples",
                              "num_entities")
                }
                if "rel_c" in pair["arrays"]:
                    names.update(rel_c="rel_c", rel_h="rel_h")
                    mut["client_rel"] = cview["rel"]
                if "neigh" in pair["arrays"]:
                    names.update(neigh="neigh", rels="rels")
                    mut["client_rel_full"] = cview["rel"]
                res.append((pair, names))
                kw.update(
                    cfg=sched.ppat_cfg, batch=pair["batch"],
                    renorm=pair["renorm"], robust=sched.robust_agg,
                    cos=sched.cos_screen is not None,
                )
            else:
                own = self._own_info(e.host)
                res.append(
                    (own, {"triples": "triples", "num_entities": "num_entities"})
                )
                kw.update(batch=own["batch"], renorm=own["renorm"])
            if metric == "accuracy":
                res.append((score_info, {"va": "va", "va_neg": "va_neg"}))
            elif metric == "hit10":
                res.append((
                    score_info,
                    {"test": "test", "filt_t": "filt_t", "filt_h": "filt_h"},
                ))
            specs[i] = EntrySpec(**kw)
            protos[i] = (mut, res)

        outs, errs, groups = self._dispatch(
            specs, protos, owners, placement, residency
        )
        # block per entry so one failing program poisons one entry, not the
        # tick; a shard_map group whose failure only surfaces at execution
        # time is re-dispatched per-entry on the members' home devices (the
        # group's healthy owners still land their results)
        retried: set = set()
        for i in range(n):
            if outs[i] is None or errs[i] is not None:
                continue
            try:
                outs[i] = jax.block_until_ready(outs[i])
            except Exception as ex:  # noqa: BLE001 — isolate, don't abort
                g = groups[i]
                if g is None or g in retried:
                    errs[i] = ex
                    continue
                retried.add(g)
                for j in g:
                    groups[j] = None
                    try:
                        outs[j] = _entry_program(specs[j])(
                            self._materialize(
                                protos[j], self.placement.device(owners[j])
                            )
                        )
                    except Exception as e2:  # noqa: BLE001
                        outs[j], errs[j] = None, e2
                if errs[i] is None:
                    try:
                        outs[i] = jax.block_until_ready(outs[i])
                    except Exception as e3:  # noqa: BLE001
                        errs[i] = e3
        # honest AND monotonic: outputs are materialized, and perf_counter
        # is immune to wall-clock adjustments (time.time() is not)
        seconds = time.perf_counter() - t0

        events = []
        for i, e in enumerate(entries):
            if pre_events[i] is not None:
                events.append(pre_events[i])
                continue
            spec, out = specs[i], outs[i]
            if out is None or errs[i] is not None:
                # an uninjected exception the dispatch/blocking phase
                # isolated: same failure path as a crash, attributed to the
                # host, kind "error"
                sched._entry_failed(
                    e.host, e.client if e.kind == "ppat" else None, "error"
                )
                events.append(sched.events[-1])
                continue
            tr = sched.trainers[e.host]
            fault = entry_faults[i]
            epsilon = float("nan")
            if e.kind == "ppat":
                acct = MomentsAccountant(sched.ppat_cfg.lam, sched.ppat_cfg.delta)
                acct.update(
                    np.asarray(out["n0s"]).ravel(), np.asarray(out["n1s"]).ravel()
                )
                epsilon = acct.epsilon()
                sched.epsilons.append(epsilon)
                sched.accountant.merge(acct)  # federation-lifetime ε
            before = sched.best_score[e.host]
            if spec.score == "accuracy":
                sp, sn = (np.asarray(v) for v in out["score"])
                _, after = best_threshold_accuracy(sp, sn, max_candidates=256)
            elif spec.score == "hit10":
                ntest = self._score_info(e.host)["ntest"]
                ranks = np.empty(2 * ntest, dtype=np.int64)
                for ci, (ct, ch) in zip(
                    range(0, ntest, spec.lp_batch), out["score"]
                ):
                    nc = len(np.asarray(ct))
                    ranks[2 * ci : 2 * (ci + nc) : 2] = np.asarray(ct) + 1
                    ranks[2 * ci + 1 : 2 * (ci + nc) : 2] = np.asarray(ch) + 1
                after = _metrics(ranks)["hit@10"]
            else:  # custom score_fn: score host-side on the candidate params
                tr.params = dict(out["params"])
                after = sched.score_fn(e.host)
            # straggler deadline: the entry's result arrived, but too late
            # to merge — injected straggles contribute their simulated delay
            elapsed = seconds
            if fault is not None and fault.kind == "straggle":
                elapsed += fault.delay
            straggled = deadline is not None and elapsed > deadline
            # cosine-shift accept gate (see federate_once): same statistic,
            # same reputation-sharpened threshold, same decision
            mean_cos = None
            if e.kind == "ppat" and spec.cos:
                mean_cos = float(out["mean_cos"])
            poisoned = (
                mean_cos is not None and not straggled
                and mean_cos < sched._cos_tau(e.client)
            )
            accepted = after > before and not straggled and not poisoned
            if accepted:
                tr.params = dict(out["params"])
                sched.best_score[e.host] = after
                sched.best_snapshot[e.host] = tr.snapshot()
            else:
                tr.restore(sched.best_snapshot[e.host])
            if sched.state[e.host] is NodeState.BUSY:
                # conditional: a mid-tick quarantine (this host blamed as
                # the client of another entry) survives its own completion
                sched.state[e.host] = NodeState.READY
            atk = entry_attacks[i]
            fault_kind = (
                "straggle" if straggled else ("poison" if poisoned else None)
            )
            ev = FederationEvent(
                tick, e.host, e.client,
                "ppat" if e.kind == "ppat" else "self-train",
                before, after, accepted, epsilon=epsilon, seconds=elapsed,
                fault=fault_kind,
                attack=atk.kind if atk is not None else None,
            )
            sched.events.append(ev)
            events.append(ev)
            if accepted:
                sched.broadcast(e.host)
                if e.kind == "ppat":
                    sched._rep_recover(e.host, e.client)
                sched._notify_accept(e.host)
            if straggled:
                sched._entry_failed(e.host, e.client, "straggle", emit=False)
            elif poisoned:
                sched._entry_failed(e.host, e.client, "poison", emit=False)
            else:
                sched._note_entry_ok(e.host, e.client)
        return events
