"""Batched federation tick engine — one device program per scheduler tick.

After PR 1/PR 2 made eval and local training device-resident, a federation
tick was still a serial Python loop: each Ready owner got its own
``train_ppat`` call, its own retrain dispatch, and its own backtrack-score
call, with eager aggregation glue (gathers, procrustes, scatters, virtual
extension) and host syncs between every stage. Tick wall-clock grew linearly
in owner count and the device idled between handshakes.

This engine turns the scheduler into a *planner*: at tick start it collects
every Ready owner's pending work into a tick plan — (client → host)
handshake pairs plus self-train owners — and executes the whole tick as ONE
compiled program. Each plan entry contributes an independent subgraph that
chains the full pipeline in-graph:

    PPAT (init + all adversarial rounds) → synthesize + procrustes refine →
    KGEmb aggregation (entity/relation scatter) → virtual extension →
    bucket-padded retrain scan → strip → backtrack scoring
    (accuracy threshold scores or fused-rank hit@10 counts)

Host-side work per tick shrinks to: splitting keys, the accept/reject
decisions, snapshot/broadcast bookkeeping, and the moments accountant.

Why independent subgraphs and not ``vmap``/``lax.map`` stacking: XLA
recompiles a stacked body in a different fusion context, which drifts
results by ~1 ulp — enough to (rarely) flip an accept/reject decision, and
enough to break the bit-parity contract with the serial reference path. N
copies of the same per-entry trace inside one program, however, compile to
the same per-copy fusion as the standalone jitted calls (pinned by the tick
parity tests), and XLA:CPU's thunk executor runs the independent subgraphs
concurrently — measured ~1.5× on the scan stages alone on 2-core CI, on top
of eliminating the per-owner eager-op and sync overhead that dominates the
serial loop. On TPU/GPU the same program exposes the cross-owner
parallelism to the compiler scheduler.

Everything immutable is cached across ticks per (client, host) pair or per
owner: aligned-index uploads, virtual-extension structure (neighbor ids,
joining relations, remapped adjacency triples), bucket-padded extended
triple stores, and backtrack-scoring inputs (fixed negatives, CSR filters).

Bit-parity contract (asserted by ``tests/test_tick_engine.py`` and the tick
benchmark): with the same per-pair keys, a batched tick produces the same
accept/reject decisions, the same scores, the same ε history, and
bit-identical embeddings as ``tick_impl="reference"``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.alignment import procrustes
from repro.core.ppat import PPAT_BUCKET, PPATConfig, _pad_rows, ppat_entry_graph
from repro.core.privacy import MomentsAccountant
from repro.kge.engine import (
    pad_tables,
    pad_triples,
    resolve_renorm,
    shape_spec,
    strip_tables,
    train_scan_graph,
)
from repro.kge.eval import side_counts_graph
from repro.kge.models import KGEModel, score_triples


# ---------------------------------------------------------------------------
# per-entry static spec + traced graph
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class EntrySpec:
    """Static (hashable) trace parameters for one tick-plan entry. Together
    with the input-array shapes it fully determines the entry subgraph; the
    tick program cache is keyed on the tuple of specs (jit re-specializes on
    shapes underneath)."""

    kind: str                  # "ppat" | "self-train"
    model: KGEModel            # logical-count model of the host owner
    epochs: int
    batch: int
    train_impl: str
    interpret: bool
    renorm: str                # entity-norm schedule, resolved at plan time
    cfg: Optional[PPATConfig]  # PPAT config (ppat entries only)
    aggregation: str
    refine: bool               # procrustes refinement on the DP release
    score: str                 # "accuracy" | "hit10" | "none"
    lp_batch: int              # hit10 chunk size (mirrors link_prediction)
    block_e: int


def _extend_params(
    p: Dict[str, jnp.ndarray], model: KGEModel, v_ent, v_rel
) -> Dict[str, jnp.ndarray]:
    """In-graph twin of ``KGETrainer.extend_tables`` — the per-family pad
    rules come from the same ``virtual_pad_rows`` definition."""
    from repro.kge.models import virtual_pad_rows

    p = dict(p)
    p["ent"] = jnp.concatenate([p["ent"], v_ent])
    p["rel"] = jnp.concatenate([p["rel"], v_rel])
    pads = virtual_pad_rows(p, model.dim, v_ent.shape[0], v_rel.shape[0])
    for k, pad in pads.items():
        p[k] = jnp.concatenate([p[k], pad])
    return p


def entry_graph(inp: Dict[str, jnp.ndarray], spec: EntrySpec) -> Dict:
    """One plan entry's full pipeline as a pure graph.

    Every stage calls the SAME functions the serial path traces
    (``ppat_entry_graph``, ``train_scan_graph``, ``side_counts_graph``,
    ``score_triples``) on identically-shaped inputs, so the per-entry
    subgraph is the serial path's compiled computation — the root of the
    batched-vs-reference bit-parity guarantee.
    """
    model = spec.model
    p = inp["params"]
    out: Dict = {}
    n_virt_e = n_virt_r = 0

    if spec.kind == "ppat":
        ce = inp["client_ent"]
        if "rel_c" in inp:
            # relation-aligned pairs keep exact-shape glue (rare; the
            # concatenated [ent | rel] layout cannot be segment-padded
            # without changing the PPAT sampling space)
            x = jnp.concatenate([ce[inp["idx_c"]],
                                 inp["client_rel"][inp["rel_c"]]])
            y = jnp.concatenate([p["ent"][inp["idx_h"]],
                                 p["rel"][inp["rel_h"]]])
            n_true = x.shape[0]
            x = _pad_rows(x, PPAT_BUCKET)
            y = _pad_rows(y, PPAT_BUCKET)
        else:
            # bucket-padded glue: index arrays are PPAT_BUCKET-padded at
            # plan time (client gathers clamp, host slots point one past the
            # table), rows beyond the true count are masked to the exact
            # zeros ``_pad_rows`` would produce — one compiled program
            # serves every pair whose alignment lands in the same bucket
            mask = (jnp.arange(inp["idx_c"].shape[0]) < inp["n_x"])[:, None]
            x = jnp.where(mask, ce[inp["idx_c"]], 0.0)
            y = jnp.where(mask, p["ent"][inp["idx_h"]], 0.0)
        hp, w, metrics, n0s, n1s = ppat_entry_graph(
            x, y, inp["n_x"], inp["n_y"], inp["key_ppat"], spec.cfg,
        )
        # hp is returned (not used host-side) so this subgraph keeps the
        # same live outputs as the serial _ppat_entry program
        out["ppat_host"], out["ppat_metrics"] = hp, metrics
        out["n0s"], out["n1s"] = n0s, n1s

        # DP-synthesized embeddings for the aligned set (host side); zero
        # padding rows synthesize to zero and add exact zeros to the
        # procrustes contraction — same shapes, same bits as the serial path
        synth = x @ w
        refine_mat = None
        if spec.refine:
            refine_mat = procrustes(synth, y)
            synth = synth @ refine_mat
        p = dict(p)
        if "rel_c" in inp:
            n_ent = inp["idx_c"].shape[0]
            new_ent = synth[:n_ent]
            if spec.aggregation == "average":
                new_ent = 0.5 * (p["ent"][inp["idx_h"]] + new_ent)
            p["ent"] = p["ent"].at[inp["idx_h"]].set(new_ent)
            cur = p["rel"][inp["rel_h"]]
            new = synth[n_ent:n_true]
            if spec.aggregation == "average":
                new = 0.5 * (cur + new)
            p["rel"] = p["rel"].at[inp["rel_h"]].set(new)
        else:
            new_ent = synth
            if spec.aggregation == "average":
                new_ent = 0.5 * (p["ent"][inp["idx_h"]] + new_ent)
            # padded slots index one past the table → dropped
            p["ent"] = p["ent"].at[inp["idx_h"]].set(new_ent, mode="drop")

        if "neigh" in inp:  # virtual extension: G(N(X)) in host space
            if refine_mat is None:
                gen = lambda e: e @ w                    # noqa: E731
            else:
                gen = lambda e: (e @ w) @ refine_mat     # noqa: E731
            # neigh/rels are bucket-padded; rows past the true virtual
            # counts hold garbage but are inert — no triple references
            # them, the corruption bound (traced true count) keeps them
            # out of negatives, and the final strip slices them away
            v_ent = gen(ce[inp["neigh"]])
            v_rel = gen(inp["client_rel_full"][inp["rels"]])
            p = _extend_params(p, model, v_ent, v_rel)
            n_virt_e, n_virt_r = v_ent.shape[0], v_rel.shape[0]

    # ---- retrain (KGEmb-Update / self-train) on bucket-padded tables ----
    counts = dataclasses.replace(
        model,
        num_entities=model.num_entities + n_virt_e,
        num_relations=model.num_relations + n_virt_r,
    )
    padded, _, _ = pad_tables(p, counts)
    padded, losses = train_scan_graph(
        padded, inp["triples"], inp["key_train"], inp["lr"],
        inp["num_entities"],
        spec=shape_spec(model), epochs=spec.epochs, batch=spec.batch,
        impl=spec.train_impl, interpret=spec.interpret, renorm=spec.renorm,
    )
    out["losses"] = losses
    p = strip_tables(padded, model)  # bucket padding AND virtual rows off
    out["params"] = p

    # ---- backtrack scoring ---------------------------------------------
    if spec.score == "accuracy":
        va, vn = inp["va"], inp["va_neg"]
        sp = score_triples(p, model, va[:, 0], va[:, 1], va[:, 2])
        sn = score_triples(p, model, vn[:, 0], vn[:, 1], vn[:, 2])
        out["score"] = (sp, sn)
    elif spec.score == "hit10":
        test, ft, fh = inp["test"], inp["filt_t"], inp["filt_h"]
        chunks = []
        for i in range(0, test.shape[0], spec.lp_batch):
            j = i + spec.lp_batch
            c = test[i:j]
            kw = dict(block_e=spec.block_e)
            ct = side_counts_graph(
                p, model, c[:, 0], c[:, 1], c[:, 2], ft[i:j], side="tail", **kw
            )
            ch = side_counts_graph(
                p, model, c[:, 0], c[:, 1], c[:, 2], fh[i:j], side="head", **kw
            )
            chunks.append((ct, ch))
        out["score"] = tuple(chunks)
    return out


def _tick_graph(inputs: Tuple[Dict, ...], specs: Tuple[EntrySpec, ...]):
    return tuple(entry_graph(i, s) for i, s in zip(inputs, specs))


#: compiled tick programs, keyed by the tuple of entry specs (jit further
#: specializes on input shapes — bucket padding keeps those stable, so
#: steady-state federation reuses one program per plan signature). The cache
#: is deliberately module-global with process lifetime, like jax.jit's own
#: compilation cache: schedulers over the same universe (parity tests, the
#: tick benchmark's reference/batched pair) share programs instead of paying
#: the multi-subgraph compile per instance.
_PROGRAMS: Dict[Tuple[EntrySpec, ...], "jax.stages.Wrapped"] = {}


def _tick_program(specs: Tuple[EntrySpec, ...]):
    prog = _PROGRAMS.get(specs)
    if prog is None:
        prog = jax.jit(functools.partial(_tick_graph, specs=specs))
        _PROGRAMS[specs] = prog
    return prog


def tick_program_cache_size() -> int:
    """Number of compiled tick-program specializations — the tick-level
    retrace-free invariant is asserted against this counter."""
    return sum(p._cache_size() for p in _PROGRAMS.values())


# ---------------------------------------------------------------------------
# the engine: per-scheduler caches + tick execution
# ---------------------------------------------------------------------------
class TickEngine:
    """Executes a scheduler's tick plan as one batched device program.

    Holds the cross-tick caches; everything cached is immutable for the
    scheduler's lifetime (KG splits, aligned index sets, virtual-extension
    structure, padded triple stores, scoring inputs).
    """

    def __init__(self, sched):
        self.sched = sched
        self._pair: Dict[Tuple[str, str], Dict] = {}
        self._own: Dict[str, Dict] = {}
        self._score: Dict[str, Dict] = {}

    # ------------------------------------------------------------- caches
    def _pair_info(self, client: str, host: str) -> Dict:
        key = (client, host)
        info = self._pair.get(key)
        if info is not None:
            return info
        from repro.kge.engine import ENT_BUCKET, REL_BUCKET, bucket

        sched = self.sched
        idx_c, idx_h = sched.registry.entities(client, host)
        rel = sched.registry.relations(client, host)
        has_rel = rel is not None and len(rel[0])
        host_tr = sched.trainers[host]
        e_log = host_tr.model.num_entities
        n_true = len(idx_c) + (len(rel[0]) if has_rel else 0)
        info = {"n_aligned": n_true}
        if has_rel:
            # exact-shape glue (see entry_graph) — no index padding
            info["idx_c"] = jnp.asarray(idx_c, jnp.int32)
            info["idx_h"] = jnp.asarray(idx_h, jnp.int32)
            info["rel_c"] = jnp.asarray(rel[0], jnp.int32)
            info["rel_h"] = jnp.asarray(rel[1], jnp.int32)
        else:
            # PPAT_BUCKET-padded index arrays → one compiled tick program
            # per alignment bucket, not per exact alignment size. Client
            # slots clamp to row 0 (rows are masked to zero in-graph); host
            # slots point one past the table so scatters drop them.
            n_pad = bucket(n_true, PPAT_BUCKET)
            ic = np.zeros(n_pad, np.int32)
            ic[:n_true] = idx_c
            ih = np.full(n_pad, e_log, np.int32)
            ih[:n_true] = idx_h
            info["idx_c"] = jnp.asarray(ic)
            info["idx_h"] = jnp.asarray(ih)
        n_virt = 0
        extra = None
        if sched.use_virtual:
            from repro.core.aggregation import virtual_structure

            vs = virtual_structure(
                sched.kgs[client], idx_c, idx_h,
                e_log, host_tr.model.num_relations,
            )
            if vs is not None:
                neigh, rels, extra = vs
                n_virt = len(neigh)
                # bucket-pad the virtual id sets too (slots clamp to row 0;
                # the resulting table rows are inert and stripped). Neighbor
                # counts vary by hundreds across pairs, so they round to a
                # power-of-two bucket — pair-to-pair variation must not
                # recompile the tick program.
                nv_pad = max(PPAT_BUCKET, 1 << (n_virt - 1).bit_length())
                nr_pad = bucket(len(rels), REL_BUCKET)
                npad = np.zeros(nv_pad, np.int32)
                npad[:n_virt] = neigh
                rpad = np.zeros(nr_pad, np.int32)
                rpad[: len(rels)] = rels
                info["neigh"] = jnp.asarray(npad)
                info["rels"] = jnp.asarray(rpad)
        # extended triple store: train + virtual adjacency, cycle-padded —
        # immutable per pair, so upload + pad once instead of per handshake
        tr = sched.kgs[host].train
        if extra is not None and len(extra):
            tr = np.concatenate([tr, extra])
        b = min(host_tr.batch_size, len(tr))
        info["batch"] = b
        info["triples"] = pad_triples(jnp.asarray(tr, jnp.int32), b)
        info["num_entities"] = e_log + n_virt  # true extended count
        # the schedule the serial path resolves for this store/table size
        info["renorm"] = resolve_renorm(
            info["triples"].shape[0], bucket(e_log + n_virt, ENT_BUCKET)
        )
        self._pair[key] = info
        return info

    def _own_info(self, name: str) -> Dict:
        info = self._own.get(name)
        if info is not None:
            return info
        from repro.kge.engine import ENT_BUCKET, bucket

        sched = self.sched
        tr = sched.kgs[name].train
        model = sched.trainers[name].model
        b = min(sched.trainers[name].batch_size, len(tr))
        info = {
            "batch": b,
            "triples": pad_triples(jnp.asarray(tr, jnp.int32), b),
        }
        info["renorm"] = resolve_renorm(
            info["triples"].shape[0], bucket(model.num_entities, ENT_BUCKET)
        )
        self._own[name] = info
        return info

    def _score_info(self, name: str) -> Dict:
        metric = self._metric_kind()
        info = self._score.get(name)
        if info is not None and info["metric"] == metric:
            return info
        # (re)build — also covers a score_fn swapped after a previous run
        sched = self.sched
        info = {"metric": metric}
        if metric == "accuracy":
            va, va_neg = sched._accuracy_inputs(name)
            info["va"] = jnp.asarray(va, jnp.int32)
            info["va_neg"] = jnp.asarray(va_neg, jnp.int32)
        elif metric == "hit10":
            test, filt_t, filt_h = sched._hit10_inputs(name)
            info["test"] = jnp.asarray(test, jnp.int32)
            info["filt_t"] = jnp.asarray(filt_t, jnp.int32)
            info["filt_h"] = jnp.asarray(filt_h, jnp.int32)
            info["ntest"] = len(test)
        self._score[name] = info
        return info

    def _metric_kind(self) -> str:
        """"accuracy"/"hit10" when the scheduler uses its default score
        functions (batchable in-graph), "none" for custom ``score_fn`` —
        those are scored host-side on the candidate params instead."""
        sched = self.sched
        fn = sched.score_fn
        if getattr(fn, "__func__", None) is type(sched)._valid_accuracy:
            return "accuracy"
        if getattr(fn, "__func__", None) is type(sched)._valid_hit10:
            return "hit10"
        return "none"

    # ---------------------------------------------------------- execution
    def execute(self, entries: List, tick: int) -> List:
        """Run one planned tick batched; returns the FederationEvents, in
        plan order, with protocol side effects (accept/reject, snapshot,
        broadcast, ε accounting) applied exactly as the serial path does."""
        from repro.core.federation import FederationEvent, NodeState
        from repro.kge.eval import _metrics, best_threshold_accuracy
        from repro.kernels.dispatch import resolve_interpret, resolve_train_impl

        sched = self.sched
        t0 = time.time()
        impls = {
            e.host: resolve_train_impl(None, sched.trainers[e.host].model.family)
            for e in entries
        }
        if "reference" in impls.values():
            # the host-loop dense path cannot be embedded in a tick program;
            # silently substituting the sparse step would betray the oracle
            # the user asked for — fail loudly before touching any state
            raise ValueError(
                "tick_impl='batched' cannot embed the 'reference' training "
                "step (REPRO_TRAIN_IMPL=reference); run with "
                "tick_impl='reference' instead"
            )
        specs: List[EntrySpec] = []
        inputs: List[Dict] = []
        for e in entries:
            tr = sched.trainers[e.host]
            sched.state[e.host] = NodeState.BUSY
            metric = self._metric_kind()
            score_info = self._score_info(e.host)
            inp: Dict = {
                "params": dict(tr.params),
                "lr": jnp.float32(tr.lr),
                "key_train": tr.consume_engine_key(),
            }
            kw = dict(
                kind=e.kind,
                model=tr.model,
                epochs=sched.update_epochs,
                train_impl=impls[e.host],
                interpret=resolve_interpret(None),
                cfg=None,
                aggregation=sched.aggregation,
                refine=sched.procrustes_refine,
                score=metric,
                lp_batch=128,
                block_e=512,
            )
            if e.kind == "ppat":
                pair = self._pair_info(e.client, e.host)
                cview = e.client_view or dict(sched.trainers[e.client].params)
                sched._key, sub = jax.random.split(sched._key)
                inp.update(
                    client_ent=cview["ent"],
                    idx_c=pair["idx_c"], idx_h=pair["idx_h"],
                    n_x=jnp.int32(pair["n_aligned"]),
                    n_y=jnp.int32(pair["n_aligned"]),
                    key_ppat=sub,
                    triples=pair["triples"],
                    num_entities=jnp.int32(pair["num_entities"]),
                )
                if "rel_c" in pair:
                    inp.update(
                        rel_c=pair["rel_c"], rel_h=pair["rel_h"],
                        client_rel=cview["rel"],
                    )
                if "neigh" in pair:
                    inp.update(
                        neigh=pair["neigh"], rels=pair["rels"],
                        client_rel_full=cview["rel"],
                    )
                kw.update(
                    cfg=sched.ppat_cfg, batch=pair["batch"],
                    renorm=pair["renorm"],
                )
            else:
                own = self._own_info(e.host)
                inp["triples"] = own["triples"]
                inp["num_entities"] = jnp.int32(tr.model.num_entities)
                kw.update(batch=own["batch"], renorm=own["renorm"])
            if metric == "accuracy":
                inp.update(va=score_info["va"], va_neg=score_info["va_neg"])
            elif metric == "hit10":
                inp.update(
                    test=score_info["test"],
                    filt_t=score_info["filt_t"], filt_h=score_info["filt_h"],
                )
            specs.append(EntrySpec(**kw))
            inputs.append(inp)

        outs = _tick_program(tuple(specs))(tuple(inputs))
        outs = jax.block_until_ready(outs)
        seconds = time.time() - t0  # honest: outputs are materialized

        events = []
        for e, spec, out in zip(entries, specs, outs):
            tr = sched.trainers[e.host]
            epsilon = float("nan")
            if e.kind == "ppat":
                acct = MomentsAccountant(sched.ppat_cfg.lam, sched.ppat_cfg.delta)
                acct.update(
                    np.asarray(out["n0s"]).ravel(), np.asarray(out["n1s"]).ravel()
                )
                epsilon = acct.epsilon()
                sched.epsilons.append(epsilon)
            before = sched.best_score[e.host]
            if spec.score == "accuracy":
                sp, sn = (np.asarray(v) for v in out["score"])
                _, after = best_threshold_accuracy(sp, sn, max_candidates=256)
            elif spec.score == "hit10":
                ntest = self._score_info(e.host)["ntest"]
                ranks = np.empty(2 * ntest, dtype=np.int64)
                for ci, (ct, ch) in zip(
                    range(0, ntest, spec.lp_batch), out["score"]
                ):
                    n = len(np.asarray(ct))
                    ranks[2 * ci : 2 * (ci + n) : 2] = np.asarray(ct) + 1
                    ranks[2 * ci + 1 : 2 * (ci + n) : 2] = np.asarray(ch) + 1
                after = _metrics(ranks)["hit@10"]
            else:  # custom score_fn: score host-side on the candidate params
                tr.params = dict(out["params"])
                after = sched.score_fn(e.host)
            accepted = after > before
            if accepted:
                tr.params = dict(out["params"])
                sched.best_score[e.host] = after
                sched.best_snapshot[e.host] = tr.snapshot()
            else:
                tr.restore(sched.best_snapshot[e.host])
            sched.state[e.host] = NodeState.READY
            ev = FederationEvent(
                tick, e.host, e.client,
                "ppat" if e.kind == "ppat" else "self-train",
                before, after, accepted, epsilon=epsilon, seconds=seconds,
            )
            sched.events.append(ev)
            events.append(ev)
            if accepted:
                sched.broadcast(e.host)
        return events
