"""Federated training orchestrator — §3.3, Alg. 1, Fig. 2.

Implements the handshake protocol faithfully as a host-side scheduler:
  * states Ready / Busy / Sleep per KG owner;
  * a handshake queue per owner: entries are client KGs offering to federate
    (their generator vs. our discriminators);
  * KGEmb-Update: PPAT → aggregate synthesized embeddings (+ optional
    virtual entities) → local retrain → score;
  * Backtrack: keep new embeddings only if the score improved, else restore
    the previous snapshot (Alg. 1 l. 17);
  * Broadcast: on improvement, send handshake signals to every partner with
    shared aligned entities (Alg. 1 l. 30).

The paper's wall-clock asynchrony (OS processes sleeping/waking) is modeled
as scheduler ticks. Each tick is *planned* at tick start: every Ready owner
contributes one plan entry — a handshake for the front of its offer queue,
or a self-train — and client embeddings are read as of the tick-start
snapshot (a tick-consistent view: mid-tick broadcasts and accepts take
effect from the NEXT tick). The plan then executes through one of two
engines (``kernels.dispatch.resolve_tick_impl`` / ``REPRO_TICK_IMPL``):

  * ``batched`` (default) — ``core.tick_engine`` executes the tick as
    independent per-owner entry programs (PPAT, aggregation, retrain,
    backtrack scoring), deduped by entry signature at trace time and placed
    across ``jax.devices()`` per ``tick_placement``
    ("auto" | "single" | "sharded", ``REPRO_TICK_PLACEMENT`` override) —
    bit-identical to the serial order-independent case with the same
    per-pair keys;
  * ``reference`` — the serial per-owner loop below, kept as the parity
    oracle.

This preserves the protocol semantics (pairing, queueing, backtracking,
broadcast-wakeup) without real multi-process execution — see DESIGN.md §3.
"""
from __future__ import annotations

import enum
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import kgemb_update, virtual_extension
from repro.core.alignment import AlignmentRegistry
from repro.core.ppat import PPATConfig, train_ppat
from repro.kernels.dispatch import resolve_tick_impl
from repro.kge.eval import triple_classification_accuracy
from repro.kge.trainer import KGETrainer


class NodeState(enum.Enum):
    READY = "ready"
    BUSY = "busy"
    SLEEP = "sleep"


@dataclass
class FederationEvent:
    """One protocol action. ``seconds`` measures *executed* work (stage
    outputs are blocked on before reading the clock); entries of a batched
    tick ran inside one fused device program, so they all report that
    program's wall-clock."""

    tick: int
    host: str
    client: Optional[str]
    kind: str  # "ppat" | "self-train" | "init"
    score_before: float
    score_after: float
    accepted: bool
    epsilon: float = float("nan")
    seconds: float = 0.0


@dataclass
class TickEntry:
    """One planned unit of tick work. ``client_view`` freezes the client's
    params at plan time so both tick engines read the same tick-consistent
    state regardless of execution order."""

    host: str
    kind: str  # "ppat" | "self-train"
    client: Optional[str] = None
    client_view: Optional[Dict[str, jnp.ndarray]] = None


class _ClientView:
    """Read-only embedding access over a plan-time params snapshot, with the
    trainer surface ``virtual_extension`` expects. ``device`` optionally
    commits every gathered row batch to the host's device — with owner-
    sticky residency the snapshot lives on the CLIENT's device, and handing
    host-side math a differently-committed operand is an error; the explicit
    put is the client → host communication of the paper's protocol."""

    def __init__(self, params: Dict[str, jnp.ndarray], model, device=None):
        self.params = params
        self.model = model
        self.device = device

    def _ship(self, rows: jnp.ndarray) -> jnp.ndarray:
        return rows if self.device is None else jax.device_put(rows, self.device)

    def get_entity_embeddings(self, idx) -> jnp.ndarray:
        return self._ship(self.params["ent"][jnp.asarray(idx)])

    def get_relation_embeddings(self, idx) -> jnp.ndarray:
        return self._ship(self.params["rel"][jnp.asarray(idx)])


class FederationScheduler:
    def __init__(
        self,
        kgs: Dict[str, object],
        *,
        families: Optional[Dict[str, str]] = None,
        dim: int = 64,
        registry: Optional[AlignmentRegistry] = None,
        ppat_cfg: Optional[PPATConfig] = None,
        aggregation: str = "average",
        procrustes_refine: bool = True,
        use_virtual: bool = True,
        local_epochs: int = 50,
        update_epochs: int = 25,
        score_fn: Optional[Callable] = None,
        score_split: str = "valid",
        score_metric: str = "accuracy",
        score_max_test: int = 200,
        seed: int = 0,
        margin: float = 2.0,
        batch_size: int = 100,
        tick_impl: Optional[str] = None,
        tick_placement: Optional[str] = None,
        tick_residency: Optional[str] = None,
    ):
        # score_split="test" reproduces Alg. 1 verbatim (the paper backtracks
        # on g_j.test); "valid" (default) is the leakage-free variant.
        # score_metric="hit10" backtracks on filtered Hit@10 instead of
        # classification accuracy, ranked by the streaming fused-rank engine
        # (candidate ranking never materializes (B, E) host-side).
        self.score_split = score_split
        self.score_metric = score_metric
        self.score_max_test = score_max_test
        self.tick_impl = tick_impl
        # "auto" | "single" | "sharded" (None → env/auto): where the batched
        # engine places tick-entry programs; resolved per execute so a
        # REPRO_TICK_PLACEMENT change between runs takes effect
        self.tick_placement = tick_placement
        # "auto" | "resident" | "normalize" (None → env/auto): whether tick
        # results stay committed to each owner's sticky home device
        # ("resident", the default — steady-state ticks move no cached
        # inputs and only scalars sync to host) or are staged back to the
        # default device each tick ("normalize", the legacy behavior)
        self.tick_residency = tick_residency
        self.kgs = kgs
        self.registry = registry or AlignmentRegistry.from_kgs(kgs)
        families = families or {n: "transe" for n in kgs}
        self.trainers: Dict[str, KGETrainer] = {
            n: KGETrainer(kg, families[n], dim=dim, seed=seed + i, margin=margin,
                          batch_size=batch_size)
            for i, (n, kg) in enumerate(kgs.items())
        }
        self.ppat_cfg = ppat_cfg or PPATConfig(seed=seed)
        if aggregation not in ("average", "replace"):
            # validate up front: both tick engines bake the mode into their
            # handshake math, and only the serial path would otherwise reach
            # kgemb_update's own check
            raise ValueError(f"unknown aggregation mode {aggregation!r}")
        self.aggregation = aggregation
        self.procrustes_refine = procrustes_refine
        self.use_virtual = use_virtual
        self.local_epochs = local_epochs
        self.update_epochs = update_epochs
        default_score = (
            self._valid_hit10 if score_metric == "hit10" else self._valid_accuracy
        )
        self.score_fn = score_fn or default_score
        self.state: Dict[str, NodeState] = {n: NodeState.READY for n in kgs}
        self.queue: Dict[str, deque] = {n: deque() for n in kgs}
        # membership mirror of each queue: broadcast() dedupes handshake
        # offers in O(1) instead of scanning the deque per partner
        self._queued: Dict[str, set] = {n: set() for n in kgs}
        self.best_score: Dict[str, float] = {}
        self.best_snapshot: Dict[str, dict] = {}
        self.events: List[FederationEvent] = []
        self.epsilons: List[float] = []
        self._tick = 0
        self._key = jax.random.PRNGKey(seed + 101)
        # backtrack-scoring inputs are built from the immutable kg splits —
        # cache them per owner instead of regenerating fixed negatives /
        # rebuilding CSR filters on every score call (the floating filter
        # width also retraced the rank kernels every tick). Entries are
        # version-keyed on their actual dependencies: accuracy negatives on
        # the owner's scoring universe (``_score_universe`` — anything that
        # grows the entity tables, e.g. an accepted virtual extension held
        # across scoring, redraws them against the POST-accept universe),
        # hit@10 CSR filters on the scoring config only (they are
        # universe-extent independent).
        self._acc_inputs: Dict[str, tuple] = {}
        self._lp_inputs: Dict[str, tuple] = {}
        from repro.core.tick_engine import TickEngine

        self._tick_engine = TickEngine(self)

    # ------------------------------------------------------------ scoring
    def _score_universe(self, name: str) -> tuple:
        """Version key for an owner's cached scoring inputs: the scoring
        config plus the CURRENT embedding-universe extents. In the standard
        protocol virtual rows are stripped before scoring, so this is
        constant; it changes exactly when an extension is accepted into (or
        otherwise grows) the owner's tables — the case where pre-accept
        fixed negatives / CSR filters would be stale."""
        m = self.trainers[name].model
        return (
            self.score_split, self.score_max_test,
            m.num_entities, m.num_relations,
        )

    def _accuracy_inputs(self, name: str) -> tuple:
        """(valid, fixed 1:1 negatives) for the accuracy backtrack metric —
        built once per owner per scoring-universe version (kg splits are
        immutable; the negative-sampling range is not, see
        ``_score_universe``)."""
        version = self._score_universe(name)
        cached = self._acc_inputs.get(name)
        if cached is None or cached[0] != version:
            from repro.kge.data import corrupt_triples

            kg = self.kgs[name]
            rng = np.random.default_rng(0)  # fixed negatives → comparable
            va = kg.test if self.score_split == "test" else kg.valid
            # corrupt against the owner's CURRENT entity universe (matches
            # the trainer's extended-count negative sampling) — equals
            # kg.num_entities whenever no extension is active
            neg = corrupt_triples(
                rng, va, self.trainers[name].model.num_entities
            )
            cached = (version, (va, neg))
            self._acc_inputs[name] = cached
        return cached[1]

    def _hit10_inputs(self, name: str) -> tuple:
        """(test, filt_t, filt_h) for the hit@10 backtrack metric — CSR
        filters are a Python pass over every triple, built once per owner
        per scoring CONFIG. Unlike the accuracy negatives, these arrays do
        not depend on the embedding-universe extents (ids below the base
        entity count stay valid when virtual rows are appended, and virtual
        candidates are correctly unfiltered), so growing the tables must NOT
        trigger the expensive rebuild — only a split/max_test change does."""
        version = (self.score_split, self.score_max_test)
        cached = self._lp_inputs.get(name)
        if cached is None or cached[0] != version:
            from repro.kge.eval import build_score_inputs

            split = "test" if self.score_split == "test" else "valid"
            cached = (
                version,
                build_score_inputs(
                    self.kgs[name], split=split, max_test=self.score_max_test
                ),
            )
            self._lp_inputs[name] = cached
        return cached[1]

    def _valid_accuracy(self, name: str) -> float:
        tr = self.trainers[name]
        from repro.kge.eval import best_threshold_accuracy
        from repro.kge.models import score_triples

        va, va_neg = self._accuracy_inputs(name)

        def s(t):
            t = jnp.asarray(t)
            return np.asarray(
                score_triples(tr.params, tr.model, t[:, 0], t[:, 1], t[:, 2])
            )

        sp, sn = s(va), s(va_neg)
        _, acc = best_threshold_accuracy(sp, sn, max_candidates=256)
        return acc

    def _valid_hit10(self, name: str) -> float:
        """Backtrack score = filtered Hit@10 on the score split, ranked by the
        streaming fused-rank engine. Prefers the tick engine's device-resident
        scoring cache (zero per-call uploads; the computation runs on the
        owner's home device when its params are resident there), falling back
        to the host-side arrays for custom-score configurations."""
        from repro.kge.eval import link_prediction

        tr = self.trainers[name]
        split = "test" if self.score_split == "test" else "valid"
        info = self._tick_engine._score_info(name)
        if info["metric"] == "hit10":
            a = info["arrays"]
            pre = (a["test"], a["filt_t"], a["filt_h"])
        else:
            pre = self._hit10_inputs(name)
        lp = link_prediction(
            tr.params, tr.model, self.kgs[name],
            split=split, max_test=self.score_max_test,
            precomputed=pre,
        )
        return lp["hit@10"]

    # ------------------------------------------------------ initial train
    def initial_training(self, epochs: Optional[int] = None) -> Dict[str, float]:
        """Alg. 1 ll. 2–4: local training to the best initial score."""
        epochs = epochs or self.local_epochs
        for name, tr in self.trainers.items():
            tr.train_epochs(epochs)
            score = self.score_fn(name)
            self.best_score[name] = score
            self.best_snapshot[name] = tr.snapshot()
            self.events.append(
                FederationEvent(self._tick, name, None, "init", 0.0, score, True)
            )
        # everyone announces itself once training is done (Fig. 2, round 1)
        for name in self.trainers:
            self.broadcast(name)
        return dict(self.best_score)

    # --------------------------------------------------------- primitives
    def broadcast(self, name: str) -> None:
        """Send handshake signal to all partners with aligned entities."""
        for partner in self.registry.partners(name):
            if name not in self._queued[partner]:
                self.queue[partner].append(name)
                self._queued[partner].add(name)
            if self.state[partner] is NodeState.SLEEP:
                self.state[partner] = NodeState.READY  # wake-up signal

    def _pop_offer(self, name: str) -> str:
        client = self.queue[name].popleft()
        self._queued[name].discard(client)
        return client

    def federate_once(
        self,
        host: str,
        client: str,
        *,
        client_view: Optional[Dict[str, jnp.ndarray]] = None,
    ) -> FederationEvent:
        """ActiveHandshake + KGEmb-Update + Backtrack for one (client, host).

        ``client_view`` optionally freezes the client's params (the planner
        passes the tick-start snapshot so serial and batched ticks read the
        same state); by default the client's live params are used.
        """
        # perf_counter: event timings must be monotonic (time.time() jumps
        # with NTP/clock adjustments)
        t0 = time.perf_counter()
        self.state[host] = NodeState.BUSY
        ent = self.registry.entities(client, host)
        rel = self.registry.relations(client, host)
        hos_tr = self.trainers[host]
        # after owner-sticky batched ticks the two parties' params may be
        # committed to different devices; all handshake math runs host-side,
        # so client rows are shipped to the host's device (a no-op while
        # both live on the default device)
        from repro.core.distributed import committed_device

        cli = _ClientView(
            client_view or dict(self.trainers[client].params),
            self.trainers[client].model,
            device=committed_device(hos_tr.params),
        )

        idx_c, idx_h = ent
        x = cli.get_entity_embeddings(idx_c)
        y = hos_tr.get_entity_embeddings(idx_h)
        if rel is not None and len(rel[0]):
            x = jnp.concatenate([x, cli.get_relation_embeddings(rel[0])])
            y = jnp.concatenate([y, hos_tr.get_relation_embeddings(rel[1])])

        self._key, sub = jax.random.split(self._key)
        ppat_client, ppat_host, hist = train_ppat(x, y, self.ppat_cfg, key=sub)
        self.epsilons.append(hist["epsilon"])

        # DP-synthesized embeddings for the aligned set, host side. Generate
        # and refine on the PPAT_BUCKET-padded aligned set (zero rows beyond
        # the true count): zero rows map to zero synth rows and contribute
        # exact zeros to the procrustes contraction, and the bucketed shape
        # is what lets the batched tick engine reuse one compiled program
        # across handshake pairs with slightly different alignment sizes.
        from repro.core.ppat import PPAT_BUCKET, _pad_rows

        n_true = x.shape[0]
        synth = ppat_client.generate(_pad_rows(x, PPAT_BUCKET))
        refine = None
        if self.procrustes_refine:
            # host-local MUSE refinement: post-processing of the DP release
            # with host-private Y — does not change the (ε, δ) guarantee.
            from repro.core.alignment import procrustes

            refine = procrustes(synth, _pad_rows(y, PPAT_BUCKET))
            synth = synth @ refine
        n_ent = len(idx_c)
        kgemb_update(hos_tr, idx_h, synth[:n_ent], mode=self.aggregation)
        if rel is not None and len(rel[0]):
            cur = hos_tr.get_relation_embeddings(rel[1])
            new = synth[n_ent:n_true]
            if self.aggregation == "average":
                new = 0.5 * (cur + new)
            hos_tr.set_relation_embeddings(rel[1], new)

        ve = None
        if self.use_virtual:
            gen = (
                ppat_client.generate
                if refine is None
                else (lambda e: ppat_client.generate(e) @ refine)
            )
            ve = virtual_extension(
                hos_tr, cli, self.kgs[client], idx_c, idx_h, gen
            )
        hos_tr.train_epochs(self.update_epochs)  # KGEmb-Update retrain
        if ve is not None:
            hos_tr.strip_virtual()

        before = self.best_score[host]
        after = self.score_fn(host)
        accepted = after > before
        if accepted:  # Backtrack (Alg. 1 l. 17)
            self.best_score[host] = after
            self.best_snapshot[host] = hos_tr.snapshot()
        else:
            hos_tr.restore(self.best_snapshot[host])
        self.state[host] = NodeState.READY
        jax.block_until_ready(hos_tr.params)  # time executed work, not enqueue
        ev = FederationEvent(
            self._tick, host, client, "ppat", before, after, accepted,
            epsilon=hist["epsilon"], seconds=time.perf_counter() - t0,
        )
        self.events.append(ev)
        if accepted:
            self.broadcast(host)
        return ev

    def self_train_once(self, name: str) -> FederationEvent:
        """Alg. 1 ll. 23–27: local iterative training when the queue is empty."""
        t0 = time.perf_counter()
        tr = self.trainers[name]
        tr.train_epochs(self.update_epochs)
        before = self.best_score[name]
        after = self.score_fn(name)
        accepted = after > before
        if accepted:
            self.best_score[name] = after
            self.best_snapshot[name] = tr.snapshot()
            self.broadcast(name)
        else:
            tr.restore(self.best_snapshot[name])
        jax.block_until_ready(tr.params)  # time executed work, not enqueue
        ev = FederationEvent(
            self._tick, name, None, "self-train", before, after, accepted,
            seconds=time.perf_counter() - t0,
        )
        self.events.append(ev)
        return ev

    # -------------------------------------------------------------- loop
    def plan_tick(self, *, self_train: bool = True) -> List[TickEntry]:
        """Snapshot this tick's work from the current protocol state: every
        Ready owner contributes one entry (front-of-queue handshake, else
        self-train), owners with nothing to do go to Sleep. Offers are popped
        and client views frozen NOW — broadcasts emitted while the tick
        executes only affect later ticks, which is what makes the plan a
        fixed unit of device work for the batched engine."""
        entries: List[TickEntry] = []
        for name in self.trainers:
            if self.state[name] is not NodeState.READY:
                continue
            if self.queue[name]:
                client = self._pop_offer(name)
                entries.append(TickEntry(
                    name, "ppat", client,
                    client_view=dict(self.trainers[client].params),
                ))
            elif self_train:
                entries.append(TickEntry(name, "self-train"))
            else:
                self.state[name] = NodeState.SLEEP
        return entries

    def run(
        self,
        max_ticks: int = 6,
        *,
        self_train: bool = True,
        tick_impl: Optional[str] = None,
        tick_placement: Optional[str] = None,
        tick_residency: Optional[str] = None,
    ) -> Dict[str, float]:
        """Scheduler ticks until quiescence (all queues empty, no improvement)
        or ``max_ticks``. Each tick serves every Ready owner once, per the
        tick-start plan. ``tick_impl`` ("batched" | "reference"),
        ``tick_placement`` ("auto" | "single" | "sharded") and
        ``tick_residency`` ("auto" | "resident" | "normalize") override the
        constructor/env-resolved engine, device placement, and output
        residency for this run."""
        impl = resolve_tick_impl(
            tick_impl if tick_impl is not None else self.tick_impl
        )
        if impl == "batched":
            # validate BEFORE any plan pops offers: the host-loop dense
            # training step cannot be embedded in a tick program, and
            # failing mid-plan would drop queued handshakes
            from repro.kernels.dispatch import resolve_train_impl

            for tr in self.trainers.values():
                if resolve_train_impl(None, tr.model.family) == "reference":
                    raise ValueError(
                        "tick_impl='batched' cannot embed the 'reference' "
                        "training step (REPRO_TRAIN_IMPL=reference); run "
                        "with tick_impl='reference' instead"
                    )
        for _ in range(max_ticks):
            self._tick += 1
            plan = self.plan_tick(self_train=self_train)
            if impl == "batched" and plan:
                events = self._tick_engine.execute(
                    plan, self._tick, placement=tick_placement,
                    residency=tick_residency,
                )
            else:
                events = [
                    self.federate_once(
                        e.host, e.client, client_view=e.client_view
                    )
                    if e.kind == "ppat"
                    else self.self_train_once(e.host)
                    for e in plan
                ]
            any_progress = any(ev.accepted for ev in events)
            if not any_progress and all(not q for q in self.queue.values()):
                break  # "whole training continues until no more improvement"
        return dict(self.best_score)
