"""Federated training orchestrator — §3.3, Alg. 1, Fig. 2.

Implements the handshake protocol faithfully as a host-side scheduler:
  * states Ready / Busy / Sleep per KG owner;
  * a handshake queue per owner: entries are client KGs offering to federate
    (their generator vs. our discriminators);
  * KGEmb-Update: PPAT → aggregate synthesized embeddings (+ optional
    virtual entities) → local retrain → score;
  * Backtrack: keep new embeddings only if the score improved, else restore
    the previous snapshot (Alg. 1 l. 17);
  * Broadcast: on improvement, send handshake signals to every partner with
    shared aligned entities (Alg. 1 l. 30).

The paper's wall-clock asynchrony (OS processes sleeping/waking) is modeled
as scheduler ticks. Each tick is *planned* at tick start: every Ready owner
contributes one plan entry — a handshake for the front of its offer queue,
or a self-train — and client embeddings are read as of the tick-start
snapshot (a tick-consistent view: mid-tick broadcasts and accepts take
effect from the NEXT tick). The plan then executes through one of two
engines (``kernels.dispatch.resolve_tick_impl`` / ``REPRO_TICK_IMPL``):

  * ``batched`` (default) — ``core.tick_engine`` executes the tick as
    independent per-owner entry programs (PPAT, aggregation, retrain,
    backtrack scoring), deduped by entry signature at trace time and placed
    across ``jax.devices()`` per ``tick_placement``
    ("auto" | "single" | "sharded", ``REPRO_TICK_PLACEMENT`` override) —
    bit-identical to the serial order-independent case with the same
    per-pair keys;
  * ``reference`` — the serial per-owner loop below, kept as the parity
    oracle.

**Scheduling discipline** (``kernels.dispatch.resolve_tick_sync`` /
``REPRO_TICK_SYNC`` / ``tick_sync=``): ``barrier`` (default) runs the
lockstep loop above — one plan, one barrier, accepts visible next tick.
``stream`` runs the dependency-level streaming scheduler (``_run_stream``):
each pass's frontier is cut into **dependency levels** (entries whose
host/client sets overlap serialize; disjoint entries stream), levels
dispatch into the chosen engine as they clear, and an accepted update can
serve a later-level host in the same wall-clock pass. Client views are
**versioned** (``_view_version``, bumped on every accept) and frozen at
plan time; at each level's dispatch a bounded-staleness gate compares the
frozen version against the client's current one — a view more than
``staleness_bound`` versions stale triggers a **re-offer handshake** (the
entry re-freezes a fresh view and executes in a trailing level of the same
pass; still stale after one re-offer, the offer returns to the queue for
the next pass) instead of a blind accept. Determinism is preserved by
construction: execution order is the (deterministic) level structure, the
scheduler PPAT key stream is pre-split in plan order (``key_ppat``), and
fault/adversary draws stay keyed on ``(tick, host, client)`` — so both
engines remain in bit-lockstep under streaming, and a streamed run whose
staleness gate never fires is bit-identical to the barrier scheduler.
Per-owner simulated-time accounting (``sim_times`` / ``sim_makespan``)
is reporting-only: no decision reads it.

This preserves the protocol semantics (pairing, queueing, backtracking,
broadcast-wakeup) without real multi-process execution — see DESIGN.md §3.
"""
from __future__ import annotations

import enum
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    ROBUST_AGG_MODES,
    kgemb_update,
    robust_rows,
    virtual_extension,
)
from repro.core.alignment import AlignmentRegistry
from repro.core.faults import screen_rows
from repro.core.ppat import PPATConfig, train_ppat
from repro.core.privacy import MomentsAccountant
from repro.kernels.dispatch import (
    resolve_tick_adversary,
    resolve_tick_faults,
    resolve_tick_impl,
    resolve_tick_sync,
)
from repro.kge.trainer import KGETrainer


class NodeState(enum.Enum):
    READY = "ready"
    BUSY = "busy"
    SLEEP = "sleep"
    #: temporarily expelled from the mesh after repeated attributed failures
    #: (crash/straggle as a host, corrupted embeddings as a client); released
    #: back to READY after ``quarantine_ticks`` ticks. Quarantined owners
    #: plan no entries and their queued offers are deferred, not dropped.
    QUARANTINED = "quarantined"


@dataclass
class FederationEvent:
    """One protocol action. ``seconds`` measures *executed* work (stage
    outputs are blocked on before reading the clock); entries of a batched
    tick ran inside one fused device program, so they all report that
    program's wall-clock."""

    tick: int
    host: str
    client: Optional[str]
    kind: str  # "ppat" | "self-train" | "init"
    score_before: float
    score_after: float
    accepted: bool
    epsilon: float = float("nan")
    seconds: float = 0.0
    #: non-None when this entry failed: "crash" | "straggle" | "drop" |
    #: "corrupt" | "poison" (cosine-shift screen rejected the exchange) |
    #: "error" (an uninjected exception isolated by the tick)
    fault: Optional[str] = None
    #: audit trail: the injected adversarial attack kind ("drift" | "sybil"
    #: | "replay"), if an adversary tampered with this entry's client view
    attack: Optional[str] = None
    #: dependency level the entry executed at (0 for every barrier-mode
    #: entry; streamed passes number levels from 0)
    level: int = 0
    #: the host's per-owner logical clock after this entry — a monotone
    #: count of entries the owner has hosted (init, handshake, self-train),
    #: the per-owner notion of progress once owners desynchronize
    owner_clock: int = 0
    #: the client-view version this entry read (handshakes: the client's
    #: published-version counter at view-freeze time; init/self-train: the
    #: host's own published version at stamp time)
    view_version: int = 0
    #: simulated completion time under the active scheduling discipline's
    #: time model (reporting only, 0.0 for unaccounted audit events) — the
    #: async smoke gate counts events finishing before a straggler's chain
    sim_finish: float = 0.0


@dataclass
class TickEntry:
    """One planned unit of tick work. ``client_view`` freezes the client's
    params at plan time so both tick engines read the same tick-consistent
    state regardless of execution order."""

    host: str
    kind: str  # "ppat" | "self-train"
    client: Optional[str] = None
    client_view: Optional[Dict[str, jnp.ndarray]] = None
    #: the client's published-version counter at view-freeze time; the
    #: streamed scheduler's bounded-staleness gate compares it against the
    #: client's CURRENT version when the entry's level dispatches
    view_version: int = 0
    #: simulated publish time of the frozen view (streamed-mode reporting
    #: only — a consumer of a fresh publish cannot start before it)
    sim_wait: float = 0.0
    #: pre-split scheduler PPAT key (streamed mode): assigned in plan order
    #: at pass start so per-level execution consumes the key stream in
    #: exactly the order the barrier scheduler would, no matter how the
    #: level cut interleaves owners. ``None`` → the engines split at
    #: execution time (the barrier path, bit-identical to the pre-stream
    #: scheduler).
    key_ppat: Optional[jnp.ndarray] = None


class _ClientView:
    """Read-only embedding access over a plan-time params snapshot, with the
    trainer surface ``virtual_extension`` expects. ``device`` optionally
    commits every gathered row batch to the host's device — with owner-
    sticky residency the snapshot lives on the CLIENT's device, and handing
    host-side math a differently-committed operand is an error; the explicit
    put is the client → host communication of the paper's protocol.

    ``screen`` (a row-norm bound; only set while a fault injector is active)
    turns every gather into the receiver-side integrity check of the
    fault-tolerance layer: non-finite or norm-bound-violating incoming rows
    raise ``CorruptEmbeddingError``, which the scheduler routes through the
    backtrack-restore failure path and blames on the sending client."""

    def __init__(self, params: Dict[str, jnp.ndarray], model, device=None,
                 *, screen: Optional[float] = None, host: str = "",
                 client: Optional[str] = None):
        self.params = params
        self.model = model
        self.device = device
        self.screen = screen
        self._who = (host, client)

    def _ship(self, rows: jnp.ndarray) -> jnp.ndarray:
        if self.screen is not None:
            screen_rows(rows, bound=self.screen, host=self._who[0],
                        client=self._who[1], what="client embeddings")
        return rows if self.device is None else jax.device_put(rows, self.device)

    def get_entity_embeddings(self, idx) -> jnp.ndarray:
        return self._ship(self.params["ent"][jnp.asarray(idx)])

    def get_relation_embeddings(self, idx) -> jnp.ndarray:
        return self._ship(self.params["rel"][jnp.asarray(idx)])


class FederationScheduler:
    def __init__(
        self,
        kgs: Dict[str, object],
        *,
        families: Optional[Dict[str, str]] = None,
        dim: int = 64,
        registry: Optional[AlignmentRegistry] = None,
        ppat_cfg: Optional[PPATConfig] = None,
        aggregation: str = "average",
        procrustes_refine: bool = True,
        use_virtual: bool = True,
        local_epochs: int = 50,
        update_epochs: int = 25,
        score_fn: Optional[Callable] = None,
        score_split: str = "valid",
        score_metric: str = "accuracy",
        score_max_test: int = 200,
        seed: int = 0,
        margin: float = 2.0,
        batch_size: int = 100,
        tick_impl: Optional[str] = None,
        tick_placement: Optional[str] = None,
        tick_residency: Optional[str] = None,
        tick_faults=None,
        tick_adversary=None,
        robust_agg: str = "none",
        cos_screen: Optional[float] = None,
        rep_decay: float = 0.5,
        rep_recover: float = 0.25,
        retry_budget: int = 3,
        backoff_ticks: int = 1,
        quarantine_ticks: int = 4,
        tick_deadline: Optional[float] = None,
        tick_sync: Optional[str] = None,
        staleness_bound: int = 0,
    ):
        # score_split="test" reproduces Alg. 1 verbatim (the paper backtracks
        # on g_j.test); "valid" (default) is the leakage-free variant.
        # score_metric="hit10" backtracks on filtered Hit@10 instead of
        # classification accuracy, ranked by the streaming fused-rank engine
        # (candidate ranking never materializes (B, E) host-side).
        self.score_split = score_split
        self.score_metric = score_metric
        self.score_max_test = score_max_test
        self.tick_impl = tick_impl
        # "auto" | "single" | "sharded" (None → env/auto): where the batched
        # engine places tick-entry programs; resolved per execute so a
        # REPRO_TICK_PLACEMENT change between runs takes effect
        self.tick_placement = tick_placement
        # "auto" | "resident" | "normalize" (None → env/auto): whether tick
        # results stay committed to each owner's sticky home device
        # ("resident", the default — steady-state ticks move no cached
        # inputs and only scalars sync to host) or are staged back to the
        # default device each tick ("normalize", the legacy behavior)
        self.tick_residency = tick_residency
        # fault-tolerance layer (None/off ⇒ bit-identical pre-fault fast
        # path). ``tick_faults`` is a REPRO_TICK_FAULTS-style spec string, a
        # core.faults.FaultPlan, or a FaultInjector; resolution happens per
        # run() so an env change between runs takes effect.
        self.tick_faults = tick_faults
        # adversarial-peer layer (None/off ⇒ bit-identical pre-attack fast
        # path). ``tick_adversary`` is a REPRO_TICK_ADVERSARY-style spec
        # string, a core.adversary.AdversaryPlan, or an Adversary; resolved
        # per run() like ``tick_faults``.
        self.tick_adversary = tick_adversary
        # ---- robust acceptance (the Byzantine defenses; all off by
        # default — the defenses-off path is bit-identical) ----------------
        if robust_agg not in ROBUST_AGG_MODES:
            raise ValueError(
                f"unknown robust_agg mode {robust_agg!r} "
                f"(one of {'|'.join(ROBUST_AGG_MODES)})"
            )
        #: robust aggregation over synthesized aligned rows before KGEmb
        self.robust_agg = robust_agg
        if cos_screen is not None and not -1.0 <= cos_screen <= 1.0:
            raise ValueError(f"cos_screen={cos_screen} outside [-1, 1]")
        #: cosine-shift accept gate: a handshake whose mean cosine between
        #: the host's current rows and the synthesized rows falls below the
        #: (reputation-sharpened) threshold is rejected as "poison"
        self.cos_screen = cos_screen
        self.rep_decay = rep_decay      # reputation *= decay on blame
        self.rep_recover = rep_recover  # reputation += recover on accept
        self.retry_budget = retry_budget          # attributed failures → quarantine
        self.backoff_ticks = backoff_ticks        # base of the exponential backoff
        self.quarantine_ticks = quarantine_ticks  # timed release horizon
        self.tick_deadline = tick_deadline        # per-entry straggler deadline (s)
        # "auto" | "barrier" | "stream" (None → env/auto): the scheduling
        # discipline — lockstep ticks (the parity oracle) or dependency-
        # level streaming passes; resolved per run() like the other knobs
        self.tick_sync = tick_sync
        if staleness_bound < 0:
            raise ValueError(f"staleness_bound={staleness_bound} must be >= 0")
        #: streamed mode's bounded-staleness acceptance rule, in accepted-
        #: version bumps: a frozen client view whose client has published
        #: more than this many versions since the freeze is NOT blindly
        #: used — the entry re-offers with a fresh view instead. 0 =
        #: strictest (any same-pass publish forces a re-offer); a large
        #: bound always uses the plan-frozen view, which makes the streamed
        #: pass bit-identical to a barrier tick.
        self.staleness_bound = staleness_bound
        self.kgs = kgs
        self.registry = registry or AlignmentRegistry.from_kgs(kgs)
        families = families or {n: "transe" for n in kgs}
        self.trainers: Dict[str, KGETrainer] = {
            n: KGETrainer(kg, families[n], dim=dim, seed=seed + i, margin=margin,
                          batch_size=batch_size)
            for i, (n, kg) in enumerate(kgs.items())
        }
        self.ppat_cfg = ppat_cfg or PPATConfig(seed=seed)
        if aggregation not in ("average", "replace"):
            # validate up front: both tick engines bake the mode into their
            # handshake math, and only the serial path would otherwise reach
            # kgemb_update's own check
            raise ValueError(f"unknown aggregation mode {aggregation!r}")
        self.aggregation = aggregation
        self.procrustes_refine = procrustes_refine
        self.use_virtual = use_virtual
        self.local_epochs = local_epochs
        self.update_epochs = update_epochs
        default_score = (
            self._valid_hit10 if score_metric == "hit10" else self._valid_accuracy
        )
        self.score_fn = score_fn or default_score
        self.state: Dict[str, NodeState] = {n: NodeState.READY for n in kgs}
        self.queue: Dict[str, deque] = {n: deque() for n in kgs}
        # membership mirror of each queue: broadcast() dedupes handshake
        # offers in O(1) instead of scanning the deque per partner
        self._queued: Dict[str, set] = {n: set() for n in kgs}
        self.best_score: Dict[str, float] = {}
        self.best_snapshot: Dict[str, dict] = {}
        #: version-publish hook: called as ``fn(owner, tick, params)`` every
        #: time an owner's update is ACCEPTED (initial training, handshake,
        #: self-train — both tick engines), with the accepted params. The
        #: serving tier subscribes here to hot-swap its table versions; the
        #: fast path is unchanged while no listener is registered.
        self._accept_listeners: List[Callable] = []
        self.events: List[FederationEvent] = []
        self.epsilons: List[float] = []
        # federation-lifetime privacy spend: every handshake's per-query
        # moment bounds composed into one accountant (additive in α — see
        # MomentsAccountant.merge). ``epsilons`` keeps the per-handshake
        # history; this answers "what has the whole federation spent".
        self.accountant = MomentsAccountant(
            self.ppat_cfg.lam, self.ppat_cfg.delta
        )
        # ---- failure semantics state (all empty while faults never fire) --
        #: consecutive failures per handshake pair (host, client) — drives
        #: the exponential backoff of that pair's re-queued offer
        self._retries: Dict[tuple, int] = {}
        #: consecutive failures attributed to a peer (host for crash and
        #: straggle, client for corrupt; drops blame nobody) — at
        #: ``retry_budget`` the peer is quarantined
        self._peer_failures: Dict[str, int] = {}
        #: deferred handshake offers: (release_tick, host, client), re-queued
        #: by plan_tick once their backoff expires
        self._deferred: List[tuple] = []
        #: quarantined peer → release tick
        self._quarantine_until: Dict[str, int] = {}
        #: continuous reputation per peer (absent = pristine 1.0): decays
        #: multiplicatively on every attributed blame, recovers additively
        #: on accepted handshakes. With defenses armed it gates handshake
        #: priority (``_next_offer``) and sharpens the cosine screen
        #: (``_cos_tau``); kept sparse so the defenses-off path carries no
        #: state. Serialized by save_scheduler/restore_scheduler.
        self._reputation: Dict[str, float] = {}
        self._injector = None          # cached resolved FaultInjector
        self._injector_src = None
        self._adversary = None         # cached resolved Adversary
        self._adversary_src = None
        self._tick = 0
        # ---- streaming-scheduler state (barrier runs keep these coherent
        # too, so checkpoints can switch modes) ----------------------------
        #: per-owner logical clock: entries this owner has hosted (init,
        #: handshake, self-train) — per-owner progress once owners
        #: desynchronize; stamped onto every FederationEvent
        self._owner_clock: Dict[str, int] = {}
        #: per-owner published-version counter, bumped on every ACCEPT
        #: (initial training, handshake, self-train — all accept paths go
        #: through ``_notify_accept``). Client views are stamped with the
        #: client's version at freeze time; the streamed bounded-staleness
        #: gate compares against the current value.
        self._view_version: Dict[str, int] = {}
        #: simulated-time accounting (REPORTING ONLY — no scheduling
        #: decision reads these, which is what keeps streamed runs
        #: deterministic): when each owner's device next frees up, and when
        #: each owner's latest accepted version was published
        self._owner_free: Dict[str, float] = {}
        self._publish_sim: Dict[str, float] = {}
        self._key = jax.random.PRNGKey(seed + 101)
        # backtrack-scoring inputs are built from the immutable kg splits —
        # cache them per owner instead of regenerating fixed negatives /
        # rebuilding CSR filters on every score call (the floating filter
        # width also retraced the rank kernels every tick). Entries are
        # version-keyed on their actual dependencies: accuracy negatives on
        # the owner's scoring universe (``_score_universe`` — anything that
        # grows the entity tables, e.g. an accepted virtual extension held
        # across scoring, redraws them against the POST-accept universe),
        # hit@10 CSR filters on the scoring config only (they are
        # universe-extent independent).
        self._acc_inputs: Dict[str, tuple] = {}
        self._lp_inputs: Dict[str, tuple] = {}
        from repro.core.tick_engine import TickEngine

        self._tick_engine = TickEngine(self)

    # ------------------------------------------------------------ scoring
    def _score_universe(self, name: str) -> tuple:
        """Version key for an owner's cached scoring inputs: the scoring
        config plus the CURRENT embedding-universe extents. In the standard
        protocol virtual rows are stripped before scoring, so this is
        constant; it changes exactly when an extension is accepted into (or
        otherwise grows) the owner's tables — the case where pre-accept
        fixed negatives / CSR filters would be stale."""
        m = self.trainers[name].model
        return (
            self.score_split, self.score_max_test,
            m.num_entities, m.num_relations,
        )

    def _accuracy_inputs(self, name: str) -> tuple:
        """(valid, fixed 1:1 negatives) for the accuracy backtrack metric —
        built once per owner per scoring-universe version (kg splits are
        immutable; the negative-sampling range is not, see
        ``_score_universe``)."""
        version = self._score_universe(name)
        cached = self._acc_inputs.get(name)
        if cached is None or cached[0] != version:
            from repro.kge.data import corrupt_triples

            kg = self.kgs[name]
            rng = np.random.default_rng(0)  # fixed negatives → comparable
            va = kg.test if self.score_split == "test" else kg.valid
            # corrupt against the owner's CURRENT entity universe (matches
            # the trainer's extended-count negative sampling) — equals
            # kg.num_entities whenever no extension is active
            neg = corrupt_triples(
                rng, va, self.trainers[name].model.num_entities
            )
            cached = (version, (va, neg))
            self._acc_inputs[name] = cached
        return cached[1]

    def _hit10_inputs(self, name: str) -> tuple:
        """(test, filt_t, filt_h) for the hit@10 backtrack metric — CSR
        filters are a Python pass over every triple, built once per owner
        per scoring CONFIG. Unlike the accuracy negatives, these arrays do
        not depend on the embedding-universe extents (ids below the base
        entity count stay valid when virtual rows are appended, and virtual
        candidates are correctly unfiltered), so growing the tables must NOT
        trigger the expensive rebuild — only a split/max_test change does."""
        version = (self.score_split, self.score_max_test)
        cached = self._lp_inputs.get(name)
        if cached is None or cached[0] != version:
            from repro.kge.eval import build_score_inputs

            split = "test" if self.score_split == "test" else "valid"
            cached = (
                version,
                build_score_inputs(
                    self.kgs[name], split=split, max_test=self.score_max_test
                ),
            )
            self._lp_inputs[name] = cached
        return cached[1]

    def _valid_accuracy(self, name: str) -> float:
        tr = self.trainers[name]
        from repro.kge.eval import best_threshold_accuracy
        from repro.kge.models import score_triples

        va, va_neg = self._accuracy_inputs(name)

        def s(t):
            t = jnp.asarray(t)
            return np.asarray(
                score_triples(tr.params, tr.model, t[:, 0], t[:, 1], t[:, 2])
            )

        sp, sn = s(va), s(va_neg)
        _, acc = best_threshold_accuracy(sp, sn, max_candidates=256)
        return acc

    def _valid_hit10(self, name: str) -> float:
        """Backtrack score = filtered Hit@10 on the score split, ranked by the
        streaming fused-rank engine. Prefers the tick engine's device-resident
        scoring cache (zero per-call uploads; the computation runs on the
        owner's home device when its params are resident there), falling back
        to the host-side arrays for custom-score configurations."""
        from repro.kge.eval import link_prediction

        tr = self.trainers[name]
        split = "test" if self.score_split == "test" else "valid"
        info = self._tick_engine._score_info(name)
        if info["metric"] == "hit10":
            a = info["arrays"]
            pre = (a["test"], a["filt_t"], a["filt_h"])
        else:
            pre = self._hit10_inputs(name)
        lp = link_prediction(
            tr.params, tr.model, self.kgs[name],
            split=split, max_test=self.score_max_test,
            precomputed=pre,
        )
        return lp["hit@10"]

    # ------------------------------------------------------ initial train
    def initial_training(self, epochs: Optional[int] = None) -> Dict[str, float]:
        """Alg. 1 ll. 2–4: local training to the best initial score."""
        epochs = epochs or self.local_epochs
        for name, tr in self.trainers.items():
            tr.train_epochs(epochs)
            score = self.score_fn(name)
            self.best_score[name] = score
            self.best_snapshot[name] = tr.snapshot()
            ev = FederationEvent(
                self._tick, name, None, "init", 0.0, score, True
            )
            self.events.append(ev)
            self._notify_accept(name)
            self._stamp_events([None], [ev], level=0)
        # everyone announces itself once training is done (Fig. 2, round 1)
        for name in self.trainers:
            self.broadcast(name)
        return dict(self.best_score)

    # --------------------------------------------------------- primitives
    def add_accept_listener(self, fn: Callable) -> None:
        """Subscribe ``fn(owner, tick, params)`` to accepted updates — the
        serving tier's version-publish hook. Listeners run synchronously at
        the accept site (AFTER the snapshot/broadcast bookkeeping) and see
        the owner's accepted params; they must catch their own exceptions —
        a serving-side publish failure must not abort a federation tick
        (the tier's listener does exactly that, counting failures in its
        stats)."""
        self._accept_listeners.append(fn)

    def _notify_accept(self, owner: str) -> None:
        # every accept path publishes a new view version FIRST (before the
        # listener early-return): the streamed staleness gate and the
        # owner-sticky residency registry key on it whether or not a
        # serving tier is attached
        version = self._view_version.get(owner, 0) + 1
        self._view_version[owner] = version
        self._tick_engine.placement.note_version(owner, version)
        if not self._accept_listeners:
            return
        params = self.trainers[owner].params
        for fn in self._accept_listeners:
            fn(owner, self._tick, params)

    def broadcast(self, name: str) -> None:
        """Send handshake signal to all partners with aligned entities."""
        for partner in self.registry.partners(name):
            if name not in self._queued[partner]:
                self.queue[partner].append(name)
                self._queued[partner].add(name)
            if self.state[partner] is NodeState.SLEEP:
                self.state[partner] = NodeState.READY  # wake-up signal

    def _pop_offer(self, name: str) -> str:
        client = self.queue[name].popleft()
        self._queued[name].discard(client)
        return client

    def federate_once(
        self,
        host: str,
        client: str,
        *,
        client_view: Optional[Dict[str, jnp.ndarray]] = None,
        fault=None,
        attack=None,
        screen: Optional[float] = None,
        deadline: Optional[float] = None,
        key: Optional[jnp.ndarray] = None,
    ) -> FederationEvent:
        """ActiveHandshake + KGEmb-Update + Backtrack for one (client, host).

        ``key`` optionally supplies a pre-split PPAT key (the streamed
        scheduler assigns keys in plan order at pass start so per-level
        execution preserves the barrier key-stream order); by default the
        scheduler key stream is split here, exactly as the barrier path
        always has.

        ``client_view`` optionally freezes the client's params (the planner
        passes the tick-start snapshot so serial and batched ticks read the
        same state); by default the client's live params are used.

        Fault-layer hooks (all inert by default): ``fault`` is this entry's
        injected fault (``crash``/``drop`` raise ``FaultError`` before any
        PPAT key is consumed — the caller's failure handler isolates and
        re-queues; a ``straggle`` adds its simulated delay to the measured
        wall-clock), ``screen`` arms the corrupt-embedding screens on client
        gathers, and ``deadline`` marks entries whose wall-clock exceeds it
        as stragglers — their result is discarded via the normal backtrack
        restore and the event carries ``fault="straggle"``.

        ``attack`` is the adversary layer's audit annotation: the caller
        already tampered ``client_view`` per the drawn attack; the event
        records its kind. The Byzantine defenses (``robust_agg`` /
        ``cos_screen``) run here regardless of whether an attack fired —
        honest exchanges must survive them.
        """
        # perf_counter: event timings must be monotonic (time.time() jumps
        # with NTP/clock adjustments)
        t0 = time.perf_counter()
        if self.state[host] is not NodeState.QUARANTINED:
            # an owner quarantined mid-tick (blamed as the client of an
            # earlier entry) still executes its already-planned entry, but
            # its QUARANTINED state must survive the execution
            self.state[host] = NodeState.BUSY
        if fault is not None and fault.kind in ("crash", "drop"):
            from repro.core.faults import FaultError

            # the host process dies / the PPAT offer message is lost before
            # any work happens — in particular before the key split, so the
            # retried handshake draws from the same stream position the
            # batched engine would
            raise FaultError(fault.kind, host, client)
        ent = self.registry.entities(client, host)
        rel = self.registry.relations(client, host)
        hos_tr = self.trainers[host]
        # after owner-sticky batched ticks the two parties' params may be
        # committed to different devices; all handshake math runs host-side,
        # so client rows are shipped to the host's device (a no-op while
        # both live on the default device)
        from repro.core.distributed import committed_device

        cli = _ClientView(
            client_view or dict(self.trainers[client].params),
            self.trainers[client].model,
            device=committed_device(hos_tr.params),
            screen=screen, host=host, client=client,
        )

        idx_c, idx_h = ent
        x = cli.get_entity_embeddings(idx_c)
        y = hos_tr.get_entity_embeddings(idx_h)
        if rel is not None and len(rel[0]):
            x = jnp.concatenate([x, cli.get_relation_embeddings(rel[0])])
            y = jnp.concatenate([y, hos_tr.get_relation_embeddings(rel[1])])

        if key is None:
            self._key, key = jax.random.split(self._key)
        ppat_client, ppat_host, hist = train_ppat(x, y, self.ppat_cfg, key=key)
        self.epsilons.append(hist["epsilon"])
        self.accountant.merge(ppat_host.accountant)  # federation-lifetime ε

        # DP-synthesized embeddings for the aligned set, host side. Generate
        # and refine on the PPAT_BUCKET-padded aligned set (zero rows beyond
        # the true count): zero rows map to zero synth rows and contribute
        # exact zeros to the procrustes contraction, and the bucketed shape
        # is what lets the batched tick engine reuse one compiled program
        # across handshake pairs with slightly different alignment sizes.
        from repro.core.ppat import PPAT_BUCKET, _pad_rows

        n_true = x.shape[0]
        synth = ppat_client.generate(_pad_rows(x, PPAT_BUCKET))
        refine = None
        if self.procrustes_refine:
            # host-local MUSE refinement: post-processing of the DP release
            # with host-private Y — does not change the (ε, δ) guarantee.
            from repro.core.alignment import procrustes

            refine = procrustes(synth, _pad_rows(y, PPAT_BUCKET))
            synth = synth @ refine
        n_ent = len(idx_c)
        # ---- robust acceptance (Byzantine defenses; "none"+None skips the
        # call entirely — the defenses-off path stays bit-identical). Runs
        # on the SAME padded shapes the batched engine traces, over the
        # entity rows only (relation glue rows pass through untouched).
        mean_cos: Optional[float] = None
        if self.robust_agg != "none" or self.cos_screen is not None:
            synth, mc = robust_rows(
                _pad_rows(y, PPAT_BUCKET), synth, jnp.int32(n_ent),
                mode=self.robust_agg, want_cos=self.cos_screen is not None,
            )
            if self.cos_screen is not None:
                mean_cos = float(mc)
        kgemb_update(hos_tr, idx_h, synth[:n_ent], mode=self.aggregation)
        if rel is not None and len(rel[0]):
            cur = hos_tr.get_relation_embeddings(rel[1])
            new = synth[n_ent:n_true]
            if self.aggregation == "average":
                new = 0.5 * (cur + new)
            hos_tr.set_relation_embeddings(rel[1], new)

        ve = None
        if self.use_virtual:
            gen = (
                ppat_client.generate
                if refine is None
                else (lambda e: ppat_client.generate(e) @ refine)
            )
            ve = virtual_extension(
                hos_tr, cli, self.kgs[client], idx_c, idx_h, gen
            )
        hos_tr.train_epochs(self.update_epochs)  # KGEmb-Update retrain
        if ve is not None:
            hos_tr.strip_virtual()

        before = self.best_score[host]
        after = self.score_fn(host)
        jax.block_until_ready(hos_tr.params)  # time executed work, not enqueue
        # straggler deadline: the result arrived, but too late to merge this
        # tick — discard it through the normal backtrack restore and let the
        # caller's failure handler defer the handshake. Injected straggles
        # contribute their *simulated* delay; a genuinely slow entry trips
        # the same deadline.
        elapsed = time.perf_counter() - t0
        if fault is not None and fault.kind == "straggle":
            elapsed += fault.delay
        straggled = deadline is not None and elapsed > deadline
        # cosine-shift accept gate: a synthesized release pointing away from
        # the host's own rows is rejected as poison even if the backtrack
        # score would have admitted it. The threshold sharpens as the
        # client's reputation decays (``_cos_tau``).
        poisoned = (
            mean_cos is not None and not straggled
            and mean_cos < self._cos_tau(client)
        )
        accepted = after > before and not straggled and not poisoned
        if accepted:  # Backtrack (Alg. 1 l. 17)
            self.best_score[host] = after
            self.best_snapshot[host] = hos_tr.snapshot()
        else:
            hos_tr.restore(self.best_snapshot[host])
        if self.state[host] is NodeState.BUSY:
            # conditional: a mid-tick quarantine (this host blamed as the
            # client of another entry) must survive its own entry completing
            self.state[host] = NodeState.READY
        fault_kind = (
            "straggle" if straggled else ("poison" if poisoned else None)
        )
        ev = FederationEvent(
            self._tick, host, client, "ppat", before, after, accepted,
            epsilon=hist["epsilon"], seconds=elapsed, fault=fault_kind,
            attack=attack.kind if attack is not None else None,
        )
        self.events.append(ev)
        if accepted:
            self.broadcast(host)
            self._rep_recover(host, client)
            self._notify_accept(host)
        if fault_kind is None:
            self._note_entry_ok(host, client)
        return ev

    def self_train_once(
        self,
        name: str,
        *,
        fault=None,
        deadline: Optional[float] = None,
    ) -> FederationEvent:
        """Alg. 1 ll. 23–27: local iterative training when the queue is empty."""
        t0 = time.perf_counter()
        if fault is not None and fault.kind == "crash":
            from repro.core.faults import FaultError

            raise FaultError("crash", name, None)
        tr = self.trainers[name]
        tr.train_epochs(self.update_epochs)
        before = self.best_score[name]
        after = self.score_fn(name)
        jax.block_until_ready(tr.params)  # time executed work, not enqueue
        elapsed = time.perf_counter() - t0
        if fault is not None and fault.kind == "straggle":
            elapsed += fault.delay
        straggled = deadline is not None and elapsed > deadline
        accepted = after > before and not straggled
        if accepted:
            self.best_score[name] = after
            self.best_snapshot[name] = tr.snapshot()
            self.broadcast(name)
            self._notify_accept(name)
        else:
            tr.restore(self.best_snapshot[name])
        ev = FederationEvent(
            self._tick, name, None, "self-train", before, after, accepted,
            seconds=elapsed, fault="straggle" if straggled else None,
        )
        self.events.append(ev)
        if not straggled:
            self._note_entry_ok(name)
        return ev

    # -------------------------------------------------- failure semantics
    def _note_entry_ok(self, host: str, client: Optional[str] = None) -> None:
        """A completed entry clears its pair's retry backoff and both
        participants' attributed-failure counts (quarantine counts
        consecutive failures, not lifetime ones)."""
        self._retries.pop((host, client), None)
        self._peer_failures.pop(host, None)
        if client is not None:
            self._peer_failures.pop(client, None)

    def _entry_failed(
        self,
        host: str,
        client: Optional[str],
        fault_kind: str,
        *,
        emit: bool = True,
    ) -> None:
        """Isolate one failed tick entry: restore the host to its best
        snapshot, emit the fault event, re-queue the handshake with
        exponential backoff, and attribute blame toward quarantine
        (crash/straggle/error → host, corrupt/poison → the sending client,
        drop → the network, i.e. nobody). Blame also decays the peer's
        continuous reputation — state that only *gates* decisions while the
        Byzantine defenses are armed (``_defended``)."""
        snap = self.best_snapshot.get(host)
        if snap is not None:
            self.trainers[host].restore(snap)
        if self.state[host] is NodeState.BUSY:
            self.state[host] = NodeState.READY
        if emit:
            before = self.best_score.get(host, float("nan"))
            self.events.append(FederationEvent(
                self._tick, host, client,
                "ppat" if client is not None else "self-train",
                before, before, False, fault=fault_kind,
            ))
        if client is not None:
            att = self._retries.get((host, client), 0) + 1
            self._retries[(host, client)] = att
            release = self._tick + self.backoff_ticks * (2 ** min(att - 1, 6))
            self._deferred.append((release, host, client))
        peer = {"corrupt": client, "poison": client, "drop": None}.get(
            fault_kind, host
        )
        if peer is not None:
            self._reputation[peer] = (
                self._reputation.get(peer, 1.0) * self.rep_decay
            )
            n = self._peer_failures.get(peer, 0) + 1
            self._peer_failures[peer] = n
            if n >= self.retry_budget:
                self._quarantine(peer)

    def _rep_recover(self, *peers: str) -> None:
        """Accepted handshakes additively repair both participants'
        reputation; entries reaching pristine 1.0 are dropped so the map
        stays sparse (absent = 1.0) and the defenses-off path carries no
        state."""
        for p in peers:
            r = self._reputation.get(p)
            if r is None:
                continue
            r += self.rep_recover
            if r >= 1.0:
                del self._reputation[p]
            else:
                self._reputation[p] = r

    @property
    def _defended(self) -> bool:
        """Whether the Byzantine defenses are armed — reputation state only
        influences scheduling/screen decisions when this holds, so fault-only
        runs stay bit-identical to the pre-defense engine."""
        return self.robust_agg != "none" or self.cos_screen is not None

    def _cos_tau(self, client: str) -> float:
        """Effective cosine-shift threshold for this client: the configured
        ``cos_screen`` sharpened toward 1.0 as the client's reputation
        decays — a peer caught misbehaving must look *more* consistent to
        get a handshake accepted."""
        if self.cos_screen is None:
            return -1.0
        rep = self._reputation.get(client, 1.0)
        return 1.0 - rep * (1.0 - self.cos_screen)

    def _quarantine(self, peer: str) -> None:
        """Expel a repeatedly-failing peer from the mesh for
        ``quarantine_ticks`` ticks; its queued offers are deferred by
        ``_next_offer`` and it plans no entries until the timed release."""
        self.state[peer] = NodeState.QUARANTINED
        self._quarantine_until[peer] = self._tick + self.quarantine_ticks
        self._peer_failures.pop(peer, None)

    def _release_due(self) -> None:
        """Timed releases, run at plan time before entries are chosen:
        quarantined peers whose sentence expired return to READY, and
        deferred offers whose backoff expired re-enter their host's queue
        (deduped, with the usual sleep wake-up)."""
        for peer, until in list(self._quarantine_until.items()):
            if self._tick >= until:
                del self._quarantine_until[peer]
                if self.state[peer] is NodeState.QUARANTINED:
                    self.state[peer] = NodeState.READY
        still: List[tuple] = []
        for release, host, client in self._deferred:
            if self._tick < release:
                still.append((release, host, client))
                continue
            if client not in self._queued[host]:
                self.queue[host].append(client)
                self._queued[host].add(client)
            if self.state[host] is NodeState.SLEEP:
                self.state[host] = NodeState.READY
        self._deferred = still

    def _next_offer(self, name: str) -> Optional[str]:
        """Front-of-queue client for this owner, skipping quarantined
        clients — their offers are deferred until the quarantine release,
        not dropped. Identical to a plain pop while no peer is quarantined
        (the faults-off bit-parity path).

        With the Byzantine defenses armed AND any reputation below pristine,
        the pop becomes reputation-priority: the highest-reputation queued
        offer is served first (FIFO among ties), so suspected poisoners wait
        behind peers in good standing. The gate on ``_defended`` keeps every
        existing fault-storm trace byte-identical — reputation state may
        accumulate, but it changes no decision until defenses are on."""
        if self._defended and self._reputation and self.queue[name]:
            best = max(
                self._reputation.get(c, 1.0) for c in self.queue[name]
            )
            for client in self.queue[name]:
                if self._reputation.get(client, 1.0) == best:
                    self.queue[name].remove(client)
                    self._queued[name].discard(client)
                    if self.state.get(client) is NodeState.QUARANTINED:
                        release = self._quarantine_until.get(
                            client, self._tick + 1
                        )
                        self._deferred.append((release, name, client))
                        return self._next_offer(name)
                    return client
        while self.queue[name]:
            client = self._pop_offer(name)
            if self.state.get(client) is NodeState.QUARANTINED:
                release = self._quarantine_until.get(client, self._tick + 1)
                self._deferred.append((release, name, client))
                continue
            return client
        return None

    def _unwind_plan(self, plan: List["TickEntry"], done) -> None:
        """Exception-safety for ``run``: put the un-executed remainder of a
        plan back where ``plan_tick`` found it — handshake offers return to
        the FRONT of their host's queue in plan order, BUSY hosts reset to
        READY — so the scheduler stays re-runnable after an unexpected
        failure instead of silently dropping queued work."""
        for e in reversed(plan):
            if e.host in done:
                continue
            if e.kind == "ppat" and e.client not in self._queued[e.host]:
                self.queue[e.host].appendleft(e.client)
                self._queued[e.host].add(e.client)
            if self.state[e.host] is NodeState.BUSY:
                self.state[e.host] = NodeState.READY

    def _fault_injector(self, tick_faults=None):
        """Resolve the fault layer (call-site arg > constructor > env) to a
        cached ``FaultInjector``, or ``None`` when off — the default, in
        which case every hook downstream is an ``is None`` check."""
        src = resolve_tick_faults(
            tick_faults if tick_faults is not None else self.tick_faults
        )
        if src is None:
            self._injector = self._injector_src = None
            return None
        from repro.core.faults import FaultInjector, FaultPlan

        if isinstance(src, FaultInjector):
            self._injector = self._injector_src = src
            return src
        if self._injector is not None and self._injector_src == src:
            return self._injector
        plan = src if isinstance(src, FaultPlan) else FaultPlan.parse(src)
        self._injector = FaultInjector(plan)
        self._injector_src = src
        return self._injector

    def _adversary_for(self, tick_adversary=None):
        """Resolve the adversarial-peer layer (call-site arg > constructor >
        env) to a cached ``core.adversary.Adversary``, or ``None`` when off —
        the default, in which case every hook downstream is an ``is None``
        check. The cache matters beyond speed: the Adversary carries the
        replay-attack stale-view cache, which must persist across run()
        calls (and checkpoint restore rebinds it here)."""
        src = resolve_tick_adversary(
            tick_adversary if tick_adversary is not None
            else self.tick_adversary
        )
        if src is None:
            self._adversary = self._adversary_src = None
            return None
        from repro.core.adversary import Adversary, resolve_adversary

        if isinstance(src, Adversary):
            self._adversary = self._adversary_src = src
            return src
        if self._adversary is not None and self._adversary_src == src:
            return self._adversary
        self._adversary = resolve_adversary(src)
        self._adversary_src = src
        return self._adversary

    def screen_incoming(
        self, host: str, client: str, view: Dict, *, bound: float
    ) -> None:
        """The shared receiver-side acceptance screen both tick engines run
        on an incoming client view BEFORE any PPAT key is consumed: every
        row the host will read (aligned set + virtual neighbors) must be
        finite and inside the norm bound, else ``CorruptEmbeddingError``
        routes the entry through the failure path with the client blamed.
        One call site per engine — screen-policy changes cannot diverge
        between the reference and batched paths."""
        pair = self._tick_engine._pair_info(client, host)
        screen_rows(
            np.asarray(view["ent"])[pair["screen_idx"]],
            bound=bound, host=host, client=client,
            what="client embeddings",
        )

    # -------------------------------------------------------------- loop
    def plan_tick(self, *, self_train: bool = True) -> List[TickEntry]:
        """Snapshot this tick's work from the current protocol state: every
        Ready owner contributes one entry (front-of-queue handshake, else
        self-train), owners with nothing to do go to Sleep. Offers are popped
        and client views frozen NOW — broadcasts emitted while the tick
        executes only affect later ticks, which is what makes the plan a
        fixed unit of device work for the batched engine.

        Fault-layer bookkeeping happens first: expired quarantines release,
        and deferred offers whose backoff lapsed re-enter their queues —
        both no-ops while no fault ever fired."""
        self._release_due()
        entries: List[TickEntry] = []
        for name in self.trainers:
            if self.state[name] is not NodeState.READY:
                continue
            client = self._next_offer(name)
            if client is not None:
                entries.append(TickEntry(
                    name, "ppat", client,
                    client_view=dict(self.trainers[client].params),
                    view_version=self._view_version.get(client, 0),
                    sim_wait=self._publish_sim.get(client, 0.0),
                ))
            elif self_train:
                entries.append(TickEntry(name, "self-train"))
            else:
                self.state[name] = NodeState.SLEEP
        return entries

    def run(
        self,
        max_ticks: int = 6,
        *,
        self_train: bool = True,
        tick_impl: Optional[str] = None,
        tick_placement: Optional[str] = None,
        tick_residency: Optional[str] = None,
        tick_faults=None,
        tick_adversary=None,
        tick_sync: Optional[str] = None,
        staleness_bound: Optional[int] = None,
    ) -> Dict[str, float]:
        """Scheduler ticks until quiescence (all queues empty, no improvement,
        nothing deferred or quarantined) or ``max_ticks``. Each tick serves
        every Ready owner once, per the tick-start plan. ``tick_impl``
        ("batched" | "reference"), ``tick_placement``
        ("auto" | "single" | "sharded"), ``tick_residency``
        ("auto" | "resident" | "normalize"), ``tick_faults`` (a
        ``REPRO_TICK_FAULTS``-style spec / ``FaultPlan`` / ``FaultInjector``)
        and ``tick_adversary`` (a ``REPRO_TICK_ADVERSARY``-style spec /
        ``AdversaryPlan`` / ``Adversary``) override the constructor/
        env-resolved engine, device placement, output residency, fault layer,
        and adversarial-peer layer for this run.

        ``tick_sync`` ("auto" | "barrier" | "stream", ``REPRO_TICK_SYNC``)
        picks the scheduling discipline: lockstep barrier ticks (the
        default and parity oracle) or dependency-level streaming passes
        (``_run_stream``), where disjoint owner groups advance at their own
        cadence against versioned client views and ``staleness_bound``
        (versions; overrides the constructor value) gates how stale a
        frozen view may be before a re-offer handshake replaces it.

        Failure semantics: one failing entry never aborts its tick — it is
        isolated, its host restored from the best snapshot, and the
        handshake re-queued with exponential backoff (``_entry_failed``);
        an *unexpected* exception unwinds the plan's un-executed remainder
        back into the queues before propagating, so the scheduler is always
        re-runnable."""
        impl = resolve_tick_impl(
            tick_impl if tick_impl is not None else self.tick_impl
        )
        sync = resolve_tick_sync(
            tick_sync if tick_sync is not None else self.tick_sync
        )
        bound = (
            self.staleness_bound if staleness_bound is None
            else int(staleness_bound)
        )
        if bound < 0:
            raise ValueError(f"staleness_bound={bound} must be >= 0")
        injector = self._fault_injector(tick_faults)
        adversary = self._adversary_for(tick_adversary)
        deadline = self.tick_deadline
        if impl == "batched":
            # validate BEFORE any plan pops offers: the host-loop dense
            # training step cannot be embedded in a tick program, and
            # failing mid-plan would drop queued handshakes
            from repro.kernels.dispatch import resolve_train_impl

            for tr in self.trainers.values():
                if resolve_train_impl(None, tr.model.family) == "reference":
                    raise ValueError(
                        "tick_impl='batched' cannot embed the 'reference' "
                        "training step (REPRO_TRAIN_IMPL=reference); run "
                        "with tick_impl='reference' instead"
                    )
        if sync == "stream":
            return self._run_stream(
                max_ticks, self_train=self_train, impl=impl,
                injector=injector, adversary=adversary, deadline=deadline,
                tick_placement=tick_placement, tick_residency=tick_residency,
                bound=bound,
            )
        for _ in range(max_ticks):
            self._tick += 1
            plan = self.plan_tick(self_train=self_train)
            if impl == "batched" and plan:
                try:
                    events = self._tick_engine.execute(
                        plan, self._tick, placement=tick_placement,
                        residency=tick_residency, faults=injector,
                        adversary=adversary, deadline=deadline,
                    )
                except Exception:
                    done = {
                        ev.host for ev in self.events if ev.tick == self._tick
                    }
                    self._unwind_plan(plan, done)
                    raise
            else:
                events = self._run_serial(plan, injector, adversary, deadline)
            self._stamp_events(plan, events, level=0)
            self._sim_account_barrier(events)
            any_progress = any(ev.accepted for ev in events)
            if (
                not any_progress
                and all(not q for q in self.queue.values())
                and not self._deferred
                and not self._quarantine_until
            ):
                break  # "whole training continues until no more improvement"
        return dict(self.best_score)

    # ------------------------------------------------- streaming scheduler
    @staticmethod
    def _cut_levels(plan: List[TickEntry]) -> List[List[TickEntry]]:
        """Cut a pass frontier into dependency levels: an entry lands one
        level past the last earlier entry sharing a participant (host or
        client) with it, so overlapping entries serialize in plan order and
        disjoint owner groups stream side by side. The cut is a pure
        function of the plan — the deterministic execution order streaming
        rides on."""
        levels: List[List[TickEntry]] = []
        last: Dict[str, int] = {}
        for e in plan:
            parts = {e.host} if e.client is None else {e.host, e.client}
            k = max((last[p] + 1 for p in parts if p in last), default=0)
            while len(levels) <= k:
                levels.append([])
            levels[k].append(e)
            for p in parts:
                last[p] = k
        return levels

    def _assign_entry_keys(self, entries: List[TickEntry], injector) -> None:
        """Pre-split the scheduler PPAT key stream over a streaming pass's
        handshake entries in PLAN order, so per-level execution consumes
        keys in exactly the order the barrier scheduler would regardless of
        how the level cut interleaves owners. Entries whose injected fault
        kills them before any key is consumed (crash/drop, or a corrupt
        view the receiver screen rejects) are skipped, matching both
        engines' no-key-for-isolated-entries behavior — the draw here uses
        the stateless plan (not the counting injector) so telemetry counts
        stay single-counted."""
        for e in entries:
            if e.kind != "ppat" or e.key_ppat is not None:
                continue
            if injector is not None:
                f = injector.plan.draw(self._tick, e.host, e.client)
                if f is not None and f.kind in ("crash", "drop", "corrupt"):
                    continue
            self._key, sub = jax.random.split(self._key)
            e.key_ppat = sub

    def _stamp_events(
        self,
        entries: List[Optional[TickEntry]],
        events: List[FederationEvent],
        *,
        level: int,
    ) -> None:
        """Annotate freshly-emitted events with their dependency level, the
        host's advanced per-owner clock, and the client-view version the
        entry read (handshakes) / the host's own published version (init,
        self-train). Runs in every mode so clocks stay coherent across
        barrier/stream switches and checkpoints."""
        for e, ev in zip(entries, events):
            clk = self._owner_clock.get(ev.host, 0) + 1
            self._owner_clock[ev.host] = clk
            ev.level = level
            ev.owner_clock = clk
            if e is not None and e.kind == "ppat":
                ev.view_version = e.view_version
            else:
                ev.view_version = self._view_version.get(ev.host, 0)

    def _sim_account_barrier(self, events: List[FederationEvent]) -> None:
        """Barrier-mode simulated-time model (reporting only — decisions
        never read sim times): every participant of a tick starts together
        once the last of them is free and finishes together after the
        slowest entry — exactly the synchrony cost the streamed mode
        removes, and the baseline ``sim_makespan`` the straggler bench
        compares against."""
        if not events:
            return
        hosts = {ev.host for ev in events}
        start = max(self._owner_free.get(h, 0.0) for h in hosts)
        fin = start + max(ev.seconds for ev in events)
        for h in hosts:
            self._owner_free[h] = fin
        for ev in events:
            ev.sim_finish = fin
            if ev.accepted:
                self._publish_sim[ev.host] = fin

    def _sim_account_stream(
        self, entries: List[TickEntry], events: List[FederationEvent]
    ) -> None:
        """Streamed simulated-time model: an entry starts as soon as its
        host is free AND the client version it actually read has been
        published (``sim_wait``) — fast owners reading a straggler's OLD
        published version never wait for it; only consumers of a fresh slow
        publish do, once."""
        for e, ev in zip(entries, events):
            start = max(self._owner_free.get(ev.host, 0.0), e.sim_wait)
            fin = start + max(ev.seconds, 0.0)
            self._owner_free[ev.host] = fin
            ev.sim_finish = fin
            if ev.accepted:
                self._publish_sim[ev.host] = fin

    def sim_times(self) -> Dict[str, float]:
        """Per-owner simulated completion times under the active scheduling
        discipline's time model (reporting only)."""
        return dict(self._owner_free)

    def sim_makespan(self) -> float:
        """Simulated federation makespan: when the last owner goes idle."""
        return max(self._owner_free.values(), default=0.0)

    def _run_stream(
        self,
        max_ticks: int,
        *,
        self_train: bool,
        impl: str,
        injector,
        adversary,
        deadline: Optional[float],
        tick_placement: Optional[str],
        tick_residency: Optional[str],
        bound: int,
    ) -> Dict[str, float]:
        """Dependency-level streaming passes (``tick_sync="stream"``).

        Each pass plans the frontier exactly like a barrier tick (one entry
        per Ready owner, client views frozen and version-stamped NOW), cuts
        it into dependency levels (``_cut_levels``), and dispatches level by
        level through the chosen engine — so an update accepted at level k
        feeds the versioned state a level-(k+1) re-offer reads in the SAME
        wall-clock pass, while disjoint owner groups stream without ever
        waiting on each other's levels.

        At each level's dispatch the bounded-staleness gate compares every
        handshake's frozen view version against the client's current one:
        within ``bound`` the frozen view is used as planned (``bound`` large
        ⇒ bit-identical to a barrier tick); beyond it the entry emits a
        ``fault="stale"`` audit event and re-offers — a fresh view is
        frozen and executed in a trailing level of this pass (the re-offer
        handshake); still stale after that one re-offer, the offer returns
        to the front of the host's queue for the next pass. Stale-gated
        entries consume no keys or fault draws, and re-offered executions
        re-draw the same ``(tick, host, client)`` fault — streaming changes
        the schedule, never the random streams."""
        for _ in range(max_ticks):
            self._tick += 1
            plan = self.plan_tick(self_train=self_train)
            self._assign_entry_keys(plan, injector)
            pending = [list(lv) for lv in self._cut_levels(plan)]
            pass_events: List[FederationEvent] = []
            reoffered: set = set()
            lvl = 0
            while pending:
                level_entries = pending.pop(0)
                live: List[TickEntry] = []
                reoffer_level: List[TickEntry] = []
                for e in level_entries:
                    if e.kind == "ppat":
                        delta = (
                            self._view_version.get(e.client, 0)
                            - e.view_version
                        )
                        if delta > bound:
                            # too stale to blindly accept: audit + re-offer
                            before = self.best_score.get(
                                e.host, float("nan")
                            )
                            ev = FederationEvent(
                                self._tick, e.host, e.client, "ppat",
                                before, before, False, fault="stale",
                            )
                            self.events.append(ev)
                            self._stamp_events([e], [ev], level=lvl)
                            pass_events.append(ev)
                            if (e.host, e.client) not in reoffered:
                                reoffered.add((e.host, e.client))
                                reoffer_level.append(TickEntry(
                                    e.host, "ppat", e.client,
                                    client_view=dict(
                                        self.trainers[e.client].params
                                    ),
                                    view_version=self._view_version.get(
                                        e.client, 0
                                    ),
                                    sim_wait=self._publish_sim.get(
                                        e.client, 0.0
                                    ),
                                ))
                            elif e.client not in self._queued[e.host]:
                                # one re-offer per pass: hand the offer back
                                # to the front of the queue for next pass
                                self.queue[e.host].appendleft(e.client)
                                self._queued[e.host].add(e.client)
                            continue
                    live.append(e)
                if reoffer_level:
                    # re-frozen views execute after everything already
                    # scheduled; their keys split now, in level order
                    self._assign_entry_keys(reoffer_level, injector)
                    pending.append(reoffer_level)
                if live:
                    try:
                        if impl == "batched":
                            events = self._tick_engine.execute(
                                live, self._tick, placement=tick_placement,
                                residency=tick_residency, faults=injector,
                                adversary=adversary, deadline=deadline,
                            )
                        else:
                            events = self._run_serial(
                                live, injector, adversary, deadline
                            )
                    except Exception:
                        done = {
                            ev.host for ev in self.events
                            if ev.tick == self._tick and ev.fault != "stale"
                        }
                        rest = live + [e for lv in pending for e in lv]
                        self._unwind_plan(rest, done)
                        raise
                    self._stamp_events(live, events, level=lvl)
                    self._sim_account_stream(live, events)
                    pass_events.extend(events)
                lvl += 1
            if (
                not any(ev.accepted for ev in pass_events)
                and all(not q for q in self.queue.values())
                and not self._deferred
                and not self._quarantine_until
            ):
                break
        return dict(self.best_score)

    def _run_serial(
        self, plan: List[TickEntry], injector, adversary,
        deadline: Optional[float],
    ) -> List[FederationEvent]:
        """Reference-engine tick execution with per-entry fault isolation.
        With ``injector=None`` and ``adversary=None`` this is exactly the
        pre-fault serial loop. Tamper order is fixed and identical in both
        engines: client view → adversary tamper → fault corruption →
        receiver screens — all before any PPAT key is consumed."""
        from repro.core.faults import FaultError

        events: List[FederationEvent] = []
        done: set = set()
        screen = injector.norm_bound if injector is not None else None
        for e in plan:
            fault = (
                injector.draw(self._tick, e.host, e.client)
                if injector is not None else None
            )
            attack = (
                adversary.draw(self._tick, e.host, e.client)
                if adversary is not None and e.kind == "ppat" else None
            )
            view = e.client_view
            if attack is not None:
                pair = self._tick_engine._pair_info(e.client, e.host)
                view = adversary.tamper_view(
                    view, attack, self._tick, e.host, e.client,
                    rows=pair["screen_idx"],
                )
            if (
                fault is not None and fault.kind == "corrupt"
                and e.kind == "ppat"
            ):
                view = injector.corrupt_view(view, fault, self._tick, e.host)
            try:
                if e.kind == "ppat":
                    if injector is not None:
                        # up-front receiver screen over every row this entry
                        # will read (aligned + virtual neighbors) — detection
                        # happens BEFORE any key is consumed, keeping the
                        # serial and batched key streams in lockstep (the
                        # per-gather screens below stay as defense in depth)
                        self.screen_incoming(
                            e.host, e.client, view, bound=screen
                        )
                    ev = self.federate_once(
                        e.host, e.client, client_view=view, fault=fault,
                        attack=attack, screen=screen, deadline=deadline,
                        key=e.key_ppat,
                    )
                else:
                    ev = self.self_train_once(
                        e.host, fault=fault, deadline=deadline
                    )
            except FaultError as fe:
                self._entry_failed(e.host, e.client, fe.kind)
                done.add(e.host)
                events.append(self.events[-1])
                continue
            except Exception:
                snap = self.best_snapshot.get(e.host)
                if snap is not None:
                    self.trainers[e.host].restore(snap)
                self._unwind_plan(plan, done)
                raise
            done.add(e.host)
            events.append(ev)
            if ev.fault == "straggle":
                self._entry_failed(e.host, e.client, "straggle", emit=False)
            elif ev.fault == "poison":
                self._entry_failed(e.host, e.client, "poison", emit=False)
        return events
