"""Federated training orchestrator — §3.3, Alg. 1, Fig. 2.

Implements the handshake protocol faithfully as a host-side scheduler:
  * states Ready / Busy / Sleep per KG owner;
  * a handshake queue per owner: entries are client KGs offering to federate
    (their generator vs. our discriminators);
  * KGEmb-Update: PPAT → aggregate synthesized embeddings (+ optional
    virtual entities) → local retrain → score;
  * Backtrack: keep new embeddings only if the score improved, else restore
    the previous snapshot (Alg. 1 l. 17);
  * Broadcast: on improvement, send handshake signals to every partner with
    shared aligned entities (Alg. 1 l. 30).

The paper's wall-clock asynchrony (OS processes sleeping/waking) is modeled
as scheduler ticks: each tick serves every Ready owner once. This preserves
the protocol semantics (pairing, queueing, backtracking, broadcast-wakeup)
without real multi-process execution — see DESIGN.md §3.
"""
from __future__ import annotations

import enum
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import kgemb_update, virtual_extension
from repro.core.alignment import AlignmentRegistry
from repro.core.ppat import PPATConfig, train_ppat
from repro.kge.eval import triple_classification_accuracy
from repro.kge.trainer import KGETrainer


class NodeState(enum.Enum):
    READY = "ready"
    BUSY = "busy"
    SLEEP = "sleep"


@dataclass
class FederationEvent:
    tick: int
    host: str
    client: Optional[str]
    kind: str  # "ppat" | "self-train" | "init"
    score_before: float
    score_after: float
    accepted: bool
    epsilon: float = float("nan")
    seconds: float = 0.0


class FederationScheduler:
    def __init__(
        self,
        kgs: Dict[str, object],
        *,
        families: Optional[Dict[str, str]] = None,
        dim: int = 64,
        registry: Optional[AlignmentRegistry] = None,
        ppat_cfg: Optional[PPATConfig] = None,
        aggregation: str = "average",
        procrustes_refine: bool = True,
        use_virtual: bool = True,
        local_epochs: int = 50,
        update_epochs: int = 25,
        score_fn: Optional[Callable] = None,
        score_split: str = "valid",
        score_metric: str = "accuracy",
        score_max_test: int = 200,
        seed: int = 0,
        margin: float = 2.0,
    ):
        # score_split="test" reproduces Alg. 1 verbatim (the paper backtracks
        # on g_j.test); "valid" (default) is the leakage-free variant.
        # score_metric="hit10" backtracks on filtered Hit@10 instead of
        # classification accuracy, ranked by the streaming fused-rank engine
        # (candidate ranking never materializes (B, E) host-side).
        self.score_split = score_split
        self.score_metric = score_metric
        self.score_max_test = score_max_test
        self.kgs = kgs
        self.registry = registry or AlignmentRegistry.from_kgs(kgs)
        families = families or {n: "transe" for n in kgs}
        self.trainers: Dict[str, KGETrainer] = {
            n: KGETrainer(kg, families[n], dim=dim, seed=seed + i, margin=margin)
            for i, (n, kg) in enumerate(kgs.items())
        }
        self.ppat_cfg = ppat_cfg or PPATConfig(seed=seed)
        self.aggregation = aggregation
        self.procrustes_refine = procrustes_refine
        self.use_virtual = use_virtual
        self.local_epochs = local_epochs
        self.update_epochs = update_epochs
        default_score = (
            self._valid_hit10 if score_metric == "hit10" else self._valid_accuracy
        )
        self.score_fn = score_fn or default_score
        self.state: Dict[str, NodeState] = {n: NodeState.READY for n in kgs}
        self.queue: Dict[str, deque] = {n: deque() for n in kgs}
        # membership mirror of each queue: broadcast() dedupes handshake
        # offers in O(1) instead of scanning the deque per partner
        self._queued: Dict[str, set] = {n: set() for n in kgs}
        self.best_score: Dict[str, float] = {}
        self.best_snapshot: Dict[str, dict] = {}
        self.events: List[FederationEvent] = []
        self.epsilons: List[float] = []
        self._tick = 0
        self._key = jax.random.PRNGKey(seed + 101)

    # ------------------------------------------------------------ scoring
    def _valid_accuracy(self, name: str) -> float:
        tr = self.trainers[name]
        kg = self.kgs[name]
        rng = np.random.default_rng(0)  # fixed negatives → comparable scores
        from repro.kge.data import corrupt_triples
        from repro.kge.eval import best_threshold_accuracy
        from repro.kge.models import score_triples

        va = kg.test if self.score_split == "test" else kg.valid
        va_neg = corrupt_triples(rng, va, kg.num_entities)

        def s(t):
            t = jnp.asarray(t)
            return np.asarray(
                score_triples(tr.params, tr.model, t[:, 0], t[:, 1], t[:, 2])
            )

        sp, sn = s(va), s(va_neg)
        _, acc = best_threshold_accuracy(sp, sn, max_candidates=256)
        return acc

    def _valid_hit10(self, name: str) -> float:
        """Backtrack score = filtered Hit@10 on the score split, ranked by the
        streaming fused-rank engine."""
        from repro.kge.eval import link_prediction

        tr = self.trainers[name]
        split = "test" if self.score_split == "test" else "valid"
        lp = link_prediction(
            tr.params, tr.model, self.kgs[name],
            split=split, max_test=self.score_max_test,
        )
        return lp["hit@10"]

    # ------------------------------------------------------ initial train
    def initial_training(self, epochs: Optional[int] = None) -> Dict[str, float]:
        """Alg. 1 ll. 2–4: local training to the best initial score."""
        epochs = epochs or self.local_epochs
        for name, tr in self.trainers.items():
            tr.train_epochs(epochs)
            score = self.score_fn(name)
            self.best_score[name] = score
            self.best_snapshot[name] = tr.snapshot()
            self.events.append(
                FederationEvent(self._tick, name, None, "init", 0.0, score, True)
            )
        # everyone announces itself once training is done (Fig. 2, round 1)
        for name in self.trainers:
            self.broadcast(name)
        return dict(self.best_score)

    # --------------------------------------------------------- primitives
    def broadcast(self, name: str) -> None:
        """Send handshake signal to all partners with aligned entities."""
        for partner in self.registry.partners(name):
            if name not in self._queued[partner]:
                self.queue[partner].append(name)
                self._queued[partner].add(name)
            if self.state[partner] is NodeState.SLEEP:
                self.state[partner] = NodeState.READY  # wake-up signal

    def _pop_offer(self, name: str) -> str:
        client = self.queue[name].popleft()
        self._queued[name].discard(client)
        return client

    def federate_once(self, host: str, client: str) -> FederationEvent:
        """ActiveHandshake + KGEmb-Update + Backtrack for one (client, host)."""
        t0 = time.time()
        self.state[host] = NodeState.BUSY
        ent = self.registry.entities(client, host)
        rel = self.registry.relations(client, host)
        cli_tr, hos_tr = self.trainers[client], self.trainers[host]

        idx_c, idx_h = ent
        x = cli_tr.get_entity_embeddings(idx_c)
        y = hos_tr.get_entity_embeddings(idx_h)
        if rel is not None and len(rel[0]):
            x = jnp.concatenate([x, cli_tr.get_relation_embeddings(rel[0])])
            y = jnp.concatenate([y, hos_tr.get_relation_embeddings(rel[1])])

        self._key, sub = jax.random.split(self._key)
        ppat_client, ppat_host, hist = train_ppat(x, y, self.ppat_cfg, key=sub)
        self.epsilons.append(hist["epsilon"])

        # DP-synthesized embeddings for the aligned set, host side
        synth = ppat_client.generate(x)
        refine = None
        if self.procrustes_refine:
            # host-local MUSE refinement: post-processing of the DP release
            # with host-private Y — does not change the (ε, δ) guarantee.
            from repro.core.alignment import procrustes

            refine = procrustes(synth, y)
            synth = synth @ refine
        n_ent = len(idx_c)
        kgemb_update(hos_tr, idx_h, synth[:n_ent], mode=self.aggregation)
        if rel is not None and len(rel[0]):
            cur = hos_tr.get_relation_embeddings(rel[1])
            new = synth[n_ent:]
            if self.aggregation == "average":
                new = 0.5 * (cur + new)
            hos_tr.set_relation_embeddings(rel[1], new)

        ve = None
        if self.use_virtual:
            gen = (
                ppat_client.generate
                if refine is None
                else (lambda e: ppat_client.generate(e) @ refine)
            )
            ve = virtual_extension(
                hos_tr, cli_tr, self.kgs[client], idx_c, idx_h, gen
            )
        hos_tr.train_epochs(self.update_epochs)  # KGEmb-Update retrain
        if ve is not None:
            hos_tr.strip_virtual()

        before = self.best_score[host]
        after = self.score_fn(host)
        accepted = after > before
        if accepted:  # Backtrack (Alg. 1 l. 17)
            self.best_score[host] = after
            self.best_snapshot[host] = hos_tr.snapshot()
        else:
            hos_tr.restore(self.best_snapshot[host])
        self.state[host] = NodeState.READY
        ev = FederationEvent(
            self._tick, host, client, "ppat", before, after, accepted,
            epsilon=hist["epsilon"], seconds=time.time() - t0,
        )
        self.events.append(ev)
        if accepted:
            self.broadcast(host)
        return ev

    def self_train_once(self, name: str) -> FederationEvent:
        """Alg. 1 ll. 23–27: local iterative training when the queue is empty."""
        t0 = time.time()
        tr = self.trainers[name]
        tr.train_epochs(self.update_epochs)
        before = self.best_score[name]
        after = self.score_fn(name)
        accepted = after > before
        if accepted:
            self.best_score[name] = after
            self.best_snapshot[name] = tr.snapshot()
            self.broadcast(name)
        else:
            tr.restore(self.best_snapshot[name])
        ev = FederationEvent(
            self._tick, name, None, "self-train", before, after, accepted,
            seconds=time.time() - t0,
        )
        self.events.append(ev)
        return ev

    # -------------------------------------------------------------- loop
    def run(self, max_ticks: int = 6, *, self_train: bool = True) -> Dict[str, float]:
        """Scheduler ticks until quiescence (all queues empty, no improvement)
        or ``max_ticks``. Each tick serves every Ready owner once."""
        for _ in range(max_ticks):
            self._tick += 1
            any_progress = False
            for name in self.trainers:
                if self.state[name] is not NodeState.READY:
                    continue
                if self.queue[name]:
                    client = self._pop_offer(name)
                    ev = self.federate_once(name, client)
                    any_progress = any_progress or ev.accepted
                elif self_train:
                    ev = self.self_train_once(name)
                    any_progress = any_progress or ev.accepted
                else:
                    self.state[name] = NodeState.SLEEP
            if not any_progress and all(not q for q in self.queue.values()):
                break  # "whole training continues until no more improvement"
        return dict(self.best_score)
