"""Mesh-mapped FKGE — the paper's process topology on a TPU mesh.

The paper runs each KG owner as a GPU process and ships (batch, d) adversarial
samples / gradients over OS pipes. On a pod we map each owner to a slice of
the mesh along a ``party`` axis and the exchange becomes a
``jax.lax.ppermute`` (collective-permute over ICI/DCI):

    client slice:  adv = X_batch @ W          ──ppermute──►  host slice
    host slice:    teachers/PATE/student step, ∂L_G/∂adv  ──ppermute──► client
    client slice:  W ← W − lr·Xᵀ·∂L_G/∂adv

Privacy boundary: the only tensors crossing slices are the generated samples
and their gradients — exactly the paper's interface. Raw X and Y never leave
their slice; this is verifiable in the lowered HLO (the collective-permute
operands are (batch, d) and (batch, d), nothing else).

Also provides a sharded KGE train step: the entity table is sharded over the
``model`` axis (LOD-scale tables don't fit one device) and triple batches over
``data``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pate import pate_vote, teacher_votes
from repro.core.ppat import PPATConfig, _disc_prob, _init_disc, _sgd_momentum
from repro.sharding.context import auto_axis_types_kw, shard_map_compat


def make_party_mesh(n_parties: int = 2) -> Mesh:
    devs = jax.devices()[:n_parties]
    return jax.make_mesh(
        (n_parties,), ("party",), devices=devs, **auto_axis_types_kw(1)
    )


# --------------------------------------------------------------- owner mesh
#: meshes are cached per extent so repeated tick dispatches hand jit the SAME
#: mesh object (equal-but-distinct meshes would still hit the pjit cache, but
#: the cache keeps sharding construction off the per-tick hot path)
_OWNER_MESHES: dict = {}


def make_owner_mesh(n_owners: int) -> Mesh:
    """A 1-D ``("owners",)`` mesh over the first ``n_owners`` devices — the
    federation tick engine's unit of spatial parallelism: each KG owner's
    tick-plan entry subgraph runs on its own device (the paper's
    one-process-per-KG topology, minus the OS pipes)."""
    mesh = _OWNER_MESHES.get(n_owners)
    if mesh is None:
        if n_owners > len(jax.devices()):
            raise ValueError(
                f"owner mesh of {n_owners} exceeds {len(jax.devices())} devices"
            )
        mesh = jax.make_mesh(
            (n_owners,), ("owners",), devices=jax.devices()[:n_owners],
            **auto_axis_types_kw(1),
        )
        _OWNER_MESHES[n_owners] = mesh
    return mesh


def owner_shard_map(fn, n_owners: int):
    """SPMD-map ``fn`` over a stacked-leading-owner-axis pytree: each owner's
    slice executes on its own mesh device, with no collectives — ``fn`` is
    traced ONCE, so N equal-shaped owners cost one trace + one compile
    instead of N (the tick engine's trace-time dedup lever). The body sees
    local shards of extent 1 and must keep the leading axis."""
    mesh = make_owner_mesh(n_owners)
    return shard_map_compat(
        fn, mesh=mesh, in_specs=(P("owners"),), out_specs=P("owners"),
        check=False,
    )


def owner_sharding(n_owners: int) -> NamedSharding:
    """Input sharding for ``owner_shard_map`` operands (leading owner axis)."""
    return NamedSharding(make_owner_mesh(n_owners), P("owners"))


# ---------------------------------------------------- owner-sticky placement
class OwnerPlacement:
    """Sticky owner → device registry: each owner is assigned a home device
    (round-robin, in first-seen order) the first time it is looked up, and
    the assignment NEVER changes afterwards — plan recomposition (drained
    queues, mixed handshake/self-train ticks, owners joining late) cannot
    re-place an owner. This is what lets the federation tick engine keep an
    owner's state (embedding tables, padded triple stores, CSR filters, pair
    caches) resident on one chip across ticks instead of re-staging it from
    the default device every dispatch."""

    def __init__(self, devices: Optional[Sequence] = None):
        self.devices: Tuple = tuple(
            devices if devices is not None else jax.devices()
        )
        self._slot: Dict[str, int] = {}
        #: latest published view version resident on each owner's home —
        #: streamed federation keys residency by VERSION, not tick: once
        #: owners desynchronize the global tick no longer says whether the
        #: state on a device is current, the owner's version counter does
        self._version: Dict[str, int] = {}

    def slot(self, owner: str) -> int:
        """The owner's sticky device index (== its preferred position in an
        owner-mesh chunk)."""
        s = self._slot.get(owner)
        if s is None:
            s = len(self._slot) % len(self.devices)
            self._slot[owner] = s
        return s

    def device(self, owner: str):
        return self.devices[self.slot(owner)]

    def note_version(self, owner: str, version: int) -> None:
        """Record that ``owner``'s sticky home now holds its ``version``-th
        accepted publish (called from every scheduler accept path)."""
        self._version[owner] = int(version)

    def version(self, owner: str) -> int:
        """The owner's latest published view version resident on its home
        (0 before any accept)."""
        return self._version.get(owner, 0)

    def assignments(self) -> Dict[str, int]:
        return dict(self._slot)

    def restore_assignments(self, slots: Dict[str, int]) -> None:
        """Adopt checkpointed sticky assignments (crash-consistent resume):
        a resumed scheduler sees owners in *resume-plan* order, not the
        original first-sight order, so without this the homes — and hence
        which device each owner's resident caches repopulate on — could
        differ from the interrupted run. Slots beyond this process's device
        count wrap (the mesh may have shrunk across the restart)."""
        for owner, slot in slots.items():
            self._slot[owner] = int(slot) % len(self.devices)


def replica_devices(home_slot: int, n: int, devices: Optional[Sequence] = None) -> List:
    """The serving tier's replica ring for an owner homed at ``home_slot``:
    ``n`` consecutive mesh devices starting at the home, wrapping, and
    clamped to the mesh size (asking for 4 replicas on a 2-device mesh
    yields 2). Replica 0 IS the owner's sticky home device — the device
    owner-sticky federation keeps the accepted tables resident on — so a
    version publish's first replica copy is zero-copy by construction."""
    devices = tuple(devices if devices is not None else jax.devices())
    if n < 1:
        raise ValueError(f"replica count must be >= 1, got {n}")
    n = min(int(n), len(devices))
    return [devices[(int(home_slot) + i) % len(devices)] for i in range(n)]


def committed_device(tree) -> Optional[jax.Device]:
    """The single device a pytree is committed to, or ``None`` when its
    leaves are uncommitted (free to follow any computation). Used by
    non-sharded consumers (the serial federation path, trainer handoff) to
    co-locate their own operands with owner-resident state."""
    for leaf in jax.tree.leaves(tree):
        if getattr(leaf, "committed", False):
            devs = leaf.devices()
            if len(devs) == 1:
                return next(iter(devs))
    return None


def chunk_extents(n: int, n_devices: int) -> List[Tuple[int, int]]:
    """Decompose a signature bucket of ``n`` entries into ``(real, extent)``
    chunks: greedy full-mesh chunks of ``n_devices`` entries, then ONE
    remainder chunk whose extent is the next power of two (capped at the
    device count) — the ``extent - real`` tail positions are filled with
    masked dummy entries (replicas of a real entry whose outputs are
    discarded).

    Restricting extents to ``{n_devices} ∪ {2^k < n_devices}`` caps group
    programs per signature at ~log₂(devices): a bucket shrinking by one
    owner (an owner draining its queue mid-federation) re-pads into an
    already-compiled extent instead of compiling one program per exact
    bucket size.
    """
    if n_devices < 1:
        raise ValueError("chunk_extents needs at least one device")
    out: List[Tuple[int, int]] = []
    pos = 0
    while n - pos >= n_devices:
        out.append((n_devices, n_devices))
        pos += n_devices
    r = n - pos
    if r:
        extent = min(1 << (r - 1).bit_length(), n_devices)
        out.append((r, extent))
    return out


def assemble_group(entries: List[Dict], extent: int) -> Dict:
    """Zero-copy stacking of per-owner inputs into shard_map group operands.

    ``entries`` are ``extent`` structurally-identical pytrees whose leaves
    are committed single-device arrays, entry ``k`` on mesh device ``k``.
    Each leaf is stacked along a leading owner axis via
    ``jax.make_array_from_single_device_arrays`` — a metadata-only view of
    the resident per-device shards, NOT a gather-to-one-device + re-shard
    (the ``jnp.stack`` + ``device_put`` this replaces paid 2·extent array
    movements per leaf per tick). The only per-leaf device work is the
    ``expand_dims`` reshape producing the (1, ...) shard view."""
    sharding = owner_sharding(extent)
    flats = [jax.tree.flatten(e) for e in entries]
    treedef = flats[0][1]
    stacked = []
    for leaves in zip(*(f[0] for f in flats)):
        shards = [jnp.expand_dims(x, 0) for x in leaves]
        stacked.append(
            jax.make_array_from_single_device_arrays(
                (extent,) + tuple(leaves[0].shape), sharding, shards
            )
        )
    return jax.tree.unflatten(treedef, stacked)


def disassemble_group(out, extent: int) -> List:
    """Split a shard_map group output back into per-owner pytrees WITHOUT
    moving data: position ``k``'s result is mesh device ``k``'s shard,
    squeezed back to the unstacked shape and still committed to that device
    — group outputs stay owner-resident across ticks."""
    leaves, treedef = jax.tree.flatten(out)
    per_pos = [[] for _ in range(extent)]
    for leaf in leaves:
        shards = sorted(leaf.addressable_shards, key=lambda s: s.index[0].start)
        for k in range(extent):
            per_pos[k].append(jnp.squeeze(shards[k].data, axis=0))
    return [jax.tree.unflatten(treedef, p) for p in per_pos]


def init_distributed_ppat(key, dim: int, cfg: PPATConfig):
    """Host discriminator params + client W, replicated pytree."""
    kt, ks = jax.random.split(key)
    teachers = jax.vmap(lambda k: _init_disc(k, dim, cfg.hidden))(
        jax.random.split(kt, cfg.num_teachers)
    )
    student = _init_disc(ks, dim, cfg.hidden)
    return {
        "teachers": teachers,
        "teachers_vel": jax.tree.map(jnp.zeros_like, teachers),
        "student": student,
        "student_vel": jax.tree.map(jnp.zeros_like, student),
        "w": jnp.eye(dim, dtype=jnp.float32),
        "w_vel": jnp.zeros((dim, dim), jnp.float32),
    }


def ppat_exchange_step(mesh: Mesh, cfg: PPATConfig):
    """Build the SPMD one-round function.

    Layout: party 0 = client (holds x batches), party 1 = host (holds y
    batches). SPMD means both slices execute the same program on their local
    shard; role-irrelevant results are masked out. The two ppermutes in the
    lowered HLO are the paper's pipe sends.
    """

    def step(state, xb, yb, key):
        # xb: (2, B, d) party-sharded — party 0's slice is the real X batch.
        # yb: (2, B, d) — party 1's slice is the real Y batch.
        def spmd(state, xb, yb, key):
            party = jax.lax.axis_index("party")
            xb = xb[0]  # local shard (1, B, d) → (B, d)
            yb = yb[0]
            key = key[0]

            # --- client role: generate adversarial samples ----------------
            adv_local = xb @ state["w"]
            # pipe: client → host (0 → 1)
            adv = jax.lax.ppermute(adv_local, "party", [(0, 1), (1, 0)])
            # on party 1, ``adv`` now holds the client's generated batch

            # --- host role: teachers + PATE + student ---------------------
            t = cfg.num_teachers
            b, d = adv.shape
            per = b // t
            adv_parts = adv[: per * t].reshape(t, per, d)
            real_parts = yb[: per * t].reshape(t, per, d)

            def teacher_loss(tp, fake, re):
                pf = _disc_prob(tp, fake)
                pr = _disc_prob(tp, re)
                return -(jnp.mean(jnp.log(1 - pf + 1e-8)) + jnp.mean(jnp.log(pr + 1e-8)))

            t_losses, t_grads = jax.vmap(jax.value_and_grad(teacher_loss))(
                state["teachers"], adv_parts, real_parts
            )
            is_host = (party == 1).astype(jnp.float32)
            t_grads = jax.tree.map(lambda g: g * is_host, t_grads)
            new_teachers, new_tvel = _sgd_momentum(
                state["teachers"], t_grads, state["teachers_vel"], cfg.lr, cfg.momentum
            )

            probs = jax.vmap(lambda tp: _disc_prob(tp, adv))(new_teachers)
            labels, n0, n1 = pate_vote(key, teacher_votes(probs), cfg.lam)

            def student_loss(sp):
                ps = _disc_prob(sp, adv)
                return -jnp.mean(
                    labels * jnp.log(ps + 1e-8) + (1 - labels) * jnp.log(1 - ps + 1e-8)
                )

            s_loss, s_grads = jax.value_and_grad(student_loss)(state["student"])
            s_grads = jax.tree.map(lambda g: g * is_host, s_grads)
            new_student, new_svel = _sgd_momentum(
                state["student"], s_grads, state["student_vel"], cfg.lr, cfg.momentum
            )

            def gen_loss(a):
                ps = _disc_prob(new_student, a)
                if cfg.saturating:
                    return jnp.mean(jnp.log(1 - ps + 1e-8))
                return -jnp.mean(jnp.log(ps + 1e-8))

            g_loss, grad_adv = jax.value_and_grad(gen_loss)(adv)
            # pipe: host → client (1 → 0)
            grad_back = jax.lax.ppermute(grad_adv, "party", [(1, 0), (0, 1)])

            # --- client role: apply chain rule to W -----------------------
            is_client = (party == 0).astype(jnp.float32)
            gw = (xb.T @ grad_back) * is_client
            new_wvel = cfg.momentum * state["w_vel"] + gw
            new_w = state["w"] - cfg.lr * new_wvel
            if cfg.ortho_beta:
                bta = cfg.ortho_beta
                new_w = (1 + bta) * new_w - bta * (new_w @ new_w.T) @ new_w
            new_w = jnp.where(is_client > 0, new_w, state["w"])

            new_state = {
                "teachers": new_teachers,
                "teachers_vel": new_tvel,
                "student": new_student,
                "student_vel": new_svel,
                "w": new_w,
                "w_vel": jnp.where(is_client > 0, new_wvel, state["w_vel"]),
            }
            # replicate role-owned state across parties so the pytree stays
            # consistent: host owns discriminators, client owns W.
            sync = lambda v, owner: jax.lax.ppermute(
                v, "party", [(owner, 1 - owner)]
            ) * (1 - _mine(party, owner)) + v * _mine(party, owner)

            def _mine(p, owner):
                return (p == owner).astype(jnp.float32)

            for k in ("teachers", "teachers_vel", "student", "student_vel"):
                new_state[k] = jax.tree.map(lambda v: sync(v, 1), new_state[k])
            for k in ("w", "w_vel"):
                new_state[k] = sync(new_state[k], 0)
            # metrics get a leading local axis so out_specs can concatenate
            # them over parties; row 1 (the host) is the authoritative one.
            metrics = {
                "gen_loss": g_loss[None],
                "student_loss": s_loss[None],
                "teacher_loss": jnp.mean(t_losses)[None],
            }
            return new_state, metrics, (n0, n1)

        fn = shard_map_compat(
            spmd,
            mesh=mesh,
            in_specs=(P(), P("party"), P("party"), P("party")),
            out_specs=(P(), P("party"), P("party")),
            check=False,
        )
        return fn(state, xb, yb, key)

    return jax.jit(step)


# ---------------------------------------------------------------- sharded KGE
def make_sharded_kge_step(mesh: Mesh, model, *, lr: float):
    """Data-parallel margin-loss step with the entity table sharded over
    'model' and triple batches over 'data' — the substrate FKGE rides on for
    LOD-scale KGs (1.4M × d tables)."""
    from repro.kge.models import margin_loss, score_triples

    ent_spec = P("model", None)
    rel_spec = P(None, None)

    def step(params, pos, neg):
        def loss_fn(p):
            sp = score_triples(p, model, pos[:, 0], pos[:, 1], pos[:, 2])
            sn = score_triples(p, model, neg[:, 0], neg[:, 1], neg[:, 2])
            return margin_loss(sp, sn, model.margin)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda x, g: x - lr * g, params, grads)
        return params, loss

    in_shardings = (
        {"ent": NamedSharding(mesh, ent_spec), "rel": NamedSharding(mesh, rel_spec)},
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P("data", None)),
    )
    out_shardings = (
        {"ent": NamedSharding(mesh, ent_spec), "rel": NamedSharding(mesh, rel_spec)},
        NamedSharding(mesh, P()),
    )
    return jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings)
