"""PPAT — privacy-preserving adversarial translation network (§3.2).

Structure (Fig. 3):
  client (g_i): generator G(X) = W·X, the MUSE-style translation matrix.
  host  (g_j): |T| teacher discriminators on disjoint partitions + one
               student discriminator trained with PATE noisy labels.

The privacy boundary is enforced *structurally*: ``PPATClient`` and
``PPATHost`` expose exactly the interface of Alg. 2 — the client only ever
ships generated samples ``G(X)`` (size batch×d) to the host; the host only
ever ships ``∂L_G/∂G(X)`` (size batch×d) back. Neither object ever reads the
other's raw embeddings. The ``train_ppat`` driver moves only those two
tensors per round, mirroring the paper's pipe IPC (and the mesh-mapped
variant in ``core.distributed`` moves them via collective-permute).

By default all adversarial rounds run as ONE compiled device scan
(``_ppat_scan``): the host syncs metrics a single time after the last round
instead of a ``float()`` round-trip per step, and aligned sets are
bucket-padded so every handshake pair reuses the compiled loop.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pate import pate_vote, teacher_votes
from repro.core.privacy import MomentsAccountant


@dataclass(frozen=True)
class PPATConfig:
    """§4.1.1: batch 32, 4 teachers, lr 0.02, momentum 0.9; §4.1.2: λ=0.05."""

    batch: int = 32
    num_teachers: int = 4
    lr: float = 0.02
    momentum: float = 0.9
    hidden: int = 128
    steps: int = 200
    lam: float = 0.05
    delta: float = 1e-5
    ortho_beta: float = 0.001  # MUSE orthogonality stabilizer for W
    saturating: bool = False   # Eq. 3 verbatim (True) vs non-saturating fix
    seed: int = 0


# ---------------------------------------------------------------- discriminators
def _init_disc(key, d: int, hidden: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, hidden), jnp.float32) / np.sqrt(d),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (hidden, 1), jnp.float32) / np.sqrt(hidden),
        "b2": jnp.zeros((1,), jnp.float32),
    }


def _disc_prob(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.leaky_relu(x @ p["w1"] + p["b1"], 0.2)
    return jax.nn.sigmoid((h @ p["w2"] + p["b2"])[..., 0])


def _sgd_momentum(params, grads, vel, lr, mom):
    new_vel = jax.tree.map(lambda v, g: mom * v + g, vel, grads)
    new_params = jax.tree.map(lambda p, v: p - lr * v, params, new_vel)
    return new_params, new_vel


# ---------------------------------------------------------------- host step (jit)
def _host_step_impl(
    host_params: dict,
    key: jax.Array,
    adv: jnp.ndarray,  # (B, d) generated samples — the ONLY client input
    real: jnp.ndarray,  # (B, d) host-side real batch (never leaves the host)
    cfg: PPATConfig,
):
    t = cfg.num_teachers
    b, d = adv.shape
    per = b // t
    adv_parts = adv[: per * t].reshape(t, per, d)
    real_parts = real[: per * t].reshape(t, per, d)

    # --- teacher update (Eq. 4), one vmapped step over the teacher axis ----
    def teacher_loss(tp, fake, re):
        pf = _disc_prob(tp, fake)
        pr = _disc_prob(tp, re)
        return -(jnp.mean(jnp.log(1 - pf + 1e-8)) + jnp.mean(jnp.log(pr + 1e-8)))

    t_losses, t_grads = jax.vmap(jax.value_and_grad(teacher_loss))(
        host_params["teachers"], adv_parts, real_parts
    )
    new_teachers, new_tvel = _sgd_momentum(
        host_params["teachers"], t_grads, host_params["teachers_vel"],
        cfg.lr, cfg.momentum,
    )

    # --- PATE voting on the full adv batch (Eqs. 5–6) ----------------------
    probs = jax.vmap(lambda tp: _disc_prob(tp, adv))(new_teachers)  # (T, B)
    votes = teacher_votes(probs)
    labels, n0, n1 = pate_vote(key, votes, cfg.lam)

    # --- student update (Eq. 7): BCE on generated samples w/ noisy labels --
    def student_loss(sp):
        ps = _disc_prob(sp, adv)
        return -jnp.mean(
            labels * jnp.log(ps + 1e-8) + (1 - labels) * jnp.log(1 - ps + 1e-8)
        )

    s_loss, s_grads = jax.value_and_grad(student_loss)(host_params["student"])
    new_student, new_svel = _sgd_momentum(
        host_params["student"], s_grads, host_params["student_vel"],
        cfg.lr, cfg.momentum,
    )

    # --- generator loss (Eq. 3) against the updated student; grad wrt adv --
    # Eq. 3 is the saturating form log(1−S(G(x))); by default we use the
    # standard non-saturating equivalent −log S(G(x)) (Goodfellow et al.),
    # which has the same fixed point but does not stall when the student
    # wins early. cfg.saturating=True restores the verbatim Eq. 3.
    def gen_loss(a):
        ps = _disc_prob(new_student, a)
        if cfg.saturating:
            return jnp.mean(jnp.log(1 - ps + 1e-8))
        return -jnp.mean(jnp.log(ps + 1e-8))

    g_loss, grad_adv = jax.value_and_grad(gen_loss)(adv)

    new_params = {
        "teachers": new_teachers,
        "teachers_vel": new_tvel,
        "student": new_student,
        "student_vel": new_svel,
    }
    metrics = {
        "teacher_loss": jnp.mean(t_losses),
        "student_loss": s_loss,
        "gen_loss": g_loss,
        "vote_mean": jnp.mean(labels),
    }
    return new_params, grad_adv, metrics, (n0, n1)


_host_step = functools.partial(jax.jit, static_argnames=("cfg",))(_host_step_impl)


def _generator_update(w, vel, xb, grad_adv, cfg: PPATConfig):
    """Chain rule through G(X)=XW (∂L/∂W = Xᵀ·∂L/∂G(X)) + momentum SGD +
    MUSE orthogonalization — shared by the stepwise client and the fused scan."""
    gw = xb.T @ grad_adv
    vel = cfg.momentum * vel + gw
    w = w - cfg.lr * vel
    if cfg.ortho_beta:
        b = cfg.ortho_beta
        w = (1 + b) * w - b * (w @ w.T) @ w
    return w, vel


# ------------------------------------------------------- fused device loop
def _init_host_params(key: jax.Array, dim: int, cfg: PPATConfig) -> dict:
    """Teachers + student (+ momentum state) — the PPATHost init as a pure
    graph, shared by the object API and the fused/batched entry graphs."""
    kt, ks = jax.random.split(key)
    teachers = jax.vmap(lambda k: _init_disc(k, dim, cfg.hidden))(
        jax.random.split(kt, cfg.num_teachers)
    )
    student = _init_disc(ks, dim, cfg.hidden)
    return {
        "teachers": teachers,
        "teachers_vel": jax.tree.map(jnp.zeros_like, teachers),
        "student": student,
        "student_vel": jax.tree.map(jnp.zeros_like, student),
    }


def ppat_scan_graph(
    host_params: dict,
    w: jnp.ndarray,
    vel: jnp.ndarray,
    x: jnp.ndarray,    # (Nx_pad, d) client embeddings (rows ≥ n_x are padding)
    y: jnp.ndarray,    # (Ny_pad, d) host embeddings (rows ≥ n_y are padding)
    n_x: jnp.ndarray,  # traced true row counts — sampling bounds
    n_y: jnp.ndarray,
    key: jax.Array,
    cfg: PPATConfig,
):
    """Alg. 2 as ONE compiled ``lax.scan`` over all adversarial rounds.

    Per round the traced graph moves exactly the two Alg.-2 tensors between
    the client and host subgraphs — adv = G(X_b) forward, ∂L_G/∂adv backward —
    so the structural privacy boundary of the stepwise driver is preserved;
    the host only sees metrics (and the accountant its clean vote counts)
    once, after the final round.
    """

    def body(carry, k):
        hp, w, vel = carry
        kx, ky, ks = jax.random.split(k, 3)
        idx = jax.random.randint(kx, (cfg.batch,), 0, n_x)
        xb = x[idx]
        adv = xb @ w                                   # client → host
        ridx = jax.random.randint(ky, (cfg.batch,), 0, n_y)
        hp, grad_adv, metrics, (n0, n1) = _host_step_impl(
            hp, ks, adv, y[ridx], cfg
        )
        w, vel = _generator_update(w, vel, xb, grad_adv, cfg)  # host → client
        return (hp, w, vel), (metrics, n0, n1)

    keys = jax.random.split(key, cfg.steps)
    (host_params, w, vel), (metrics, n0s, n1s) = jax.lax.scan(
        body, (host_params, w, vel), keys
    )
    return host_params, w, vel, metrics, n0s, n1s


_ppat_scan = functools.partial(jax.jit, static_argnames=("cfg",))(ppat_scan_graph)


def ppat_entry_graph(
    x: jnp.ndarray,    # (Nx_pad, d) padded client aligned embeddings
    y: jnp.ndarray,    # (Ny_pad, d) padded host aligned embeddings
    n_x: jnp.ndarray,  # traced true row counts
    n_y: jnp.ndarray,
    key: jax.Array,
    cfg: PPATConfig,
):
    """One complete PPAT handshake as a pure graph: discriminator/generator
    init + all adversarial rounds. Key discipline matches ``train_ppat``
    exactly: ``split(key)[0]`` seeds the host discriminators and
    ``split(key)[1]`` the scan. Returns (host_params, w, metrics, n0s,
    n1s) — the trained discriminators, the translation matrix, the
    per-round metric history, and the clean PATE vote counts for the
    moments accountant.

    Shared by the fused ``train_ppat`` path (one entry per program) and the
    federation tick engine (one entry subgraph per pending handshake inside
    a single batched tick program). The per-entry trace is identical in both,
    which is what keeps batched ticks bit-identical to serial ones.
    """
    dim = x.shape[1]
    kh, _ = jax.random.split(key)
    host_params = _init_host_params(kh, dim, cfg)
    w = jnp.eye(dim, dtype=jnp.float32)
    vel = jnp.zeros_like(w)
    _, sub = jax.random.split(key)
    host_params, w, _, metrics, n0s, n1s = ppat_scan_graph(
        host_params, w, vel, x, y, n_x, n_y, sub, cfg
    )
    return host_params, w, metrics, n0s, n1s


_ppat_entry = functools.partial(jax.jit, static_argnames=("cfg",))(ppat_entry_graph)


class PPATHost:
    """g_j side: all discriminators + the moments accountant (§3.2.2)."""

    def __init__(self, key, dim: int, y: jnp.ndarray, cfg: PPATConfig):
        self.cfg = cfg
        self.y = y  # host embeddings of aligned entities/relations — private
        self.params = _init_host_params(key, dim, cfg)
        self.accountant = MomentsAccountant(cfg.lam, cfg.delta)
        self._rng = np.random.default_rng(cfg.seed + 17)

    def step(self, key: jax.Array, adv: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
        """Receive generated samples; return ∂L_G/∂adv + public metrics."""
        idx = self._rng.integers(0, len(self.y), len(adv))
        real = self.y[jnp.asarray(idx)]
        self.params, grad_adv, metrics, (n0, n1) = _host_step(
            self.params, key, adv, real, self.cfg
        )
        self.accountant.update(np.asarray(n0), np.asarray(n1))
        return grad_adv, {k: float(v) for k, v in metrics.items()}


class PPATClient:
    """g_i side: the translation matrix W (= θ_G) and its optimizer."""

    def __init__(self, key, dim: int, x: jnp.ndarray, cfg: PPATConfig):
        self.cfg = cfg
        self.x = x  # client embeddings of aligned entities/relations — private
        self.w = jnp.eye(dim, dtype=jnp.float32)
        self.vel = jnp.zeros_like(self.w)
        self._rng = np.random.default_rng(cfg.seed + 29)

    def sample_batch(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        idx = self._rng.integers(0, len(self.x), self.cfg.batch)
        xb = self.x[jnp.asarray(idx)]
        return xb, self.generate(xb)

    def generate(self, xb: jnp.ndarray) -> jnp.ndarray:
        return xb @ self.w

    def apply_grad(self, xb: jnp.ndarray, grad_adv: jnp.ndarray) -> None:
        """Chain rule through G(X)=XW: ∂L/∂W = Xᵀ·∂L/∂G(X)."""
        self.w, self.vel = _generator_update(
            self.w, self.vel, xb, grad_adv, self.cfg
        )


#: aligned sets are zero-padded up to this row granularity before the fused
#: scan, so handshakes with different alignment sizes reuse the compiled loop
PPAT_BUCKET = 64


def _pad_rows(a: jnp.ndarray, granularity: int) -> jnp.ndarray:
    from repro.kge.engine import bucket  # shared round-up-to-bucket rule

    n_pad = bucket(a.shape[0], granularity)
    if n_pad == a.shape[0]:
        return a
    return jnp.pad(a, ((0, n_pad - a.shape[0]), (0, 0)))


def train_ppat(
    x: jnp.ndarray,
    y: jnp.ndarray,
    cfg: Optional[PPATConfig] = None,
    *,
    key: Optional[jax.Array] = None,
    fused: bool = True,
) -> Tuple[PPATClient, PPATHost, Dict]:
    """Run Alg. 2 between a client embedding set X and host set Y.

    Returns the trained (client, host) pair and a history dict; the caller
    obtains DP-synthesized embeddings via ``client.generate(...)`` and the
    privacy estimate via ``host.accountant.epsilon()``.

    ``fused=True`` (default) runs all ``cfg.steps`` adversarial rounds as one
    compiled device scan: batch sampling moves to ``jax.random``, the host
    syncs metrics exactly once at the end, and the accountant consumes the
    whole clean-vote history in one update. ``fused=False`` keeps the seed
    stepwise driver (one ``float()`` sync per round) — the two are the same
    algorithm with different sampling streams.
    """
    cfg = cfg or PPATConfig()
    if x.shape[0] == 0 or y.shape[0] == 0:
        # the stepwise path fails on the first sample; fused sampling would
        # silently train on padding rows instead — reject up front
        raise ValueError("train_ppat needs non-empty aligned sets "
                         f"(got |X|={x.shape[0]}, |Y|={y.shape[0]})")
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    dim = x.shape[1]
    kh, kc = jax.random.split(key)
    client = PPATClient(kc, dim, x, cfg)
    history = {"gen_loss": [], "student_loss": [], "teacher_loss": []}
    if fused:
        # ONE compiled program for the whole handshake, init included —
        # the same trace the federation tick engine embeds per pending
        # handshake, so serial and batched ticks agree bit-for-bit. The
        # host object is assembled around the program's outputs (an eager
        # PPATHost init would just duplicate the in-graph init).
        host = PPATHost.__new__(PPATHost)
        host.cfg, host.y = cfg, y
        host.accountant = MomentsAccountant(cfg.lam, cfg.delta)
        host._rng = np.random.default_rng(cfg.seed + 17)
        host.params, client.w, metrics, n0s, n1s = _ppat_entry(
            _pad_rows(x, PPAT_BUCKET), _pad_rows(y, PPAT_BUCKET),
            jnp.int32(x.shape[0]), jnp.int32(y.shape[0]), key, cfg,
        )
        # ONE device→host sync for the whole run
        metrics = {k: np.asarray(v) for k, v in metrics.items()}
        for k in history:
            history[k] = [float(v) for v in metrics[k]]
        host.accountant.update(np.asarray(n0s).ravel(), np.asarray(n1s).ravel())
    else:
        host = PPATHost(kh, dim, y, cfg)
        for _ in range(cfg.steps):
            key, sub = jax.random.split(key)
            xb, adv = client.sample_batch()          # client → host: adv only
            grad_adv, metrics = host.step(sub, adv)  # host → client: grads only
            client.apply_grad(xb, grad_adv)
            for k in history:
                history[k].append(metrics[k])
    history["epsilon"] = host.accountant.epsilon()
    history["max_alpha"] = host.accountant.max_alpha()
    return client, host, history


def noisy_vote_labels(
    host_params: dict,
    rows: jnp.ndarray,
    lam: float,
    key: jax.Array,
    *,
    rounds: int = 1,
) -> np.ndarray:
    """The PATE vote channel as an attacker-facing query surface.

    Everything a client ever learns about the host's private ``Y`` flows
    through the noisy teacher votes (§3.2.2) — this helper exposes exactly
    that channel for the measured-leakage harness: query the trained
    teacher ensemble on ``rows`` and return the mean noisy vote label over
    ``rounds`` independent Laplace draws, shape ``(len(rows),)`` in [0, 1].
    Each round spends privacy budget in the real protocol; the harness uses
    multiple rounds to emulate a persistent attacker averaging out noise.
    """
    probs = jax.vmap(lambda tp: _disc_prob(tp, rows))(host_params["teachers"])
    votes = teacher_votes(probs)
    labels = [
        np.asarray(pate_vote(k, votes, lam)[0], np.float64)
        for k in jax.random.split(key, rounds)
    ]
    return np.mean(labels, axis=0)
