"""KGEmb-Update — merging PPAT output back into a KG's embedding tables.

Two pieces (§3.2.1 last paragraph + §4.3 Tab. 7):
  * ``kgemb_update``: replace (or average into) the host's aligned-entity
    embeddings with the DP-synthesized ``G(X)`` — and symmetrically let the
    client adopt the unified embeddings.
  * ``virtual_extension`` (FKGE vs FKGE-simple): the client additionally
    translates the *neighbors* of aligned entities, G(N(X)), which the host
    temporarily adds as virtual entities/relations + their adjacency triples
    for the next local-training round; they are removed afterwards.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: robust-acceptance modes applied to the synthesized aligned rows BEFORE
#: KGEmb aggregation (``FederationScheduler(robust_agg=...)``)
ROBUST_AGG_MODES = ("none", "clip", "median", "trimmed")


def _masked_median(v: jnp.ndarray, mask: jnp.ndarray, n: jnp.ndarray):
    """Median over the first ``n`` rows of ``v`` (axis 0), robust to padded
    tails: masked-out rows sort to +inf past the true rows, and the median
    indices are computed from the traced true count."""
    big = jnp.where(mask, v, jnp.inf)
    s = jnp.sort(big, axis=0)
    lo = jnp.take(s, (n - 1) // 2, axis=0)
    hi = jnp.take(s, n // 2, axis=0)
    return 0.5 * (lo + hi)


def robust_rows_graph(
    cur: jnp.ndarray,
    synth: jnp.ndarray,
    n: jnp.ndarray,
    *,
    mode: str,
    want_cos: bool,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Robust acceptance over the synthesized aligned-entity rows, as a pure
    graph both tick engines trace on identically padded shapes (rows past
    ``n`` pass through untouched — the bit-parity contract holds per bucket).

    Statistics are over the per-row *deltas* (synth − current): a Byzantine
    minority of rows crafted under the norm screen still stands out against
    the honest majority's delta distribution.

      * ``clip``    — per-row delta-norm clipping at 2× the median norm;
      * ``median``  — coordinate-wise clamp to median ± 3·MAD;
      * ``trimmed`` — coordinate-wise clamp to 20%-trimmed mean ± 3× the
                      trimmed absolute deviation;
      * ``none``    — identity (callers skip the call entirely on the
                      defenses-off path, keeping it bit-identical).

    ``want_cos`` additionally returns the mean per-row cosine between the
    host's current rows and the RAW (pre-robustization) synthesized rows —
    the cosine-shift screen the scheduler's accept gate thresholds.
    """
    nrows = synth.shape[0]
    mask = jnp.arange(nrows) < n
    nf = jnp.maximum(n, 1)
    mean_cos = jnp.float32(1.0)
    if want_cos:
        num = jnp.sum(cur * synth, axis=1)
        den = (
            jnp.linalg.norm(cur, axis=1) * jnp.linalg.norm(synth, axis=1)
            + 1e-12
        )
        mean_cos = jnp.sum(jnp.where(mask, num / den, 0.0)) / nf
    if mode == "none":
        return synth, mean_cos
    colmask = mask[:, None]
    delta = synth - cur
    if mode == "clip":
        dn = jnp.linalg.norm(delta, axis=1)
        med = _masked_median(dn, mask, nf)
        cap = 2.0 * med + 1e-6
        robust = delta * jnp.minimum(1.0, cap / jnp.maximum(dn, 1e-12))[:, None]
    elif mode == "median":
        med = _masked_median(delta, colmask, nf)
        mad = _masked_median(jnp.abs(delta - med), colmask, nf)
        robust = jnp.clip(delta, med - 3.0 * mad - 1e-6, med + 3.0 * mad + 1e-6)
    elif mode == "trimmed":
        k = nf // 5  # 20% trimmed each side
        s = jnp.sort(jnp.where(colmask, delta, jnp.inf), axis=0)
        r = jnp.arange(nrows)[:, None]
        keep = (r >= k) & (r < nf - k)
        cnt = jnp.maximum(nf - 2 * k, 1)
        center = jnp.sum(jnp.where(keep, s, 0.0), axis=0) / cnt
        spread = (
            jnp.sum(jnp.where(keep, jnp.abs(s - center), 0.0), axis=0) / cnt
        )
        robust = jnp.clip(
            delta, center - 3.0 * spread - 1e-6, center + 3.0 * spread + 1e-6
        )
    else:
        raise ValueError(f"unknown robust_agg mode {mode!r}")
    return jnp.where(colmask, cur + robust, synth), mean_cos


#: jitted entry point for the serial reference path (the batched engine
#: inlines ``robust_rows_graph`` into its entry programs)
robust_rows = functools.partial(
    jax.jit, static_argnames=("mode", "want_cos")
)(robust_rows_graph)


def kgemb_update(
    trainer,
    aligned_idx: np.ndarray,
    synthesized: jnp.ndarray,
    *,
    mode: str = "average",
) -> None:
    """Write synthesized embeddings for ``aligned_idx`` into ``trainer``.

    mode='replace' → paper's plain replacement; 'average' → FKGE's smoother
    aggregation (Tab. 7 compares aggregation settings).
    """
    if mode == "replace":
        new = synthesized
    elif mode == "average":
        cur = trainer.get_entity_embeddings(aligned_idx)
        new = 0.5 * (cur + synthesized)
    else:
        raise ValueError(f"unknown aggregation mode {mode!r}")
    trainer.set_entity_embeddings(aligned_idx, new)


@dataclass
class VirtualExtension:
    """Bookkeeping to add & later strip virtual rows from a host trainer."""

    n_virtual_ent: int
    n_virtual_rel: int
    extra_triples: np.ndarray  # (M, 3) in the extended id space


def neighbor_structure(
    kg, aligned_local: np.ndarray, *, max_neighbors: int = 2000
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Client side: N(X) — neighbor entities + joining relations of aligned
    entities, and the adjacency triples (neighbor, relation, aligned).

    Returns (neighbor_ids, relation_ids, triples[h_is_neighbor, r, t_aligned])
    with ids local to the client KG."""
    aligned = set(int(i) for i in aligned_local)
    tri = kg.train
    mask_t = np.fromiter((int(t) in aligned for t in tri[:, 2]), bool, len(tri))
    mask_h = np.fromiter((int(h) in aligned for h in tri[:, 0]), bool, len(tri))
    # triples whose tail is aligned: head is the virtual neighbor
    tail_side = tri[mask_t & ~mask_h]
    # triples whose head is aligned: tail is the virtual neighbor (reverse)
    head_side = tri[mask_h & ~mask_t]
    rows = []
    for h, r, t in tail_side:
        rows.append((int(h), int(r), int(t), 0))
    for h, r, t in head_side:
        rows.append((int(t), int(r), int(h), 1))  # store neighbor first
    if not rows:
        return np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros((0, 4), np.int64)
    rows = np.asarray(rows, np.int64)[:max_neighbors]
    neigh = np.unique(rows[:, 0])
    rels = np.unique(rows[:, 1])
    return neigh, rels, rows


def virtual_structure(
    client_kg,
    aligned_client: np.ndarray,
    aligned_host: np.ndarray,
    e0: int,
    r0: int,
    *,
    max_neighbors: int = 2000,
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """The id-space part of a virtual extension: neighbor entity ids, joining
    relation ids (client-local), and the adjacency triples remapped into the
    host id space, where virtual rows occupy ids ``e0..``/``r0..``.

    Deterministic in (client_kg.train, aligned sets, host table sizes), all
    of which are immutable between ticks — so callers (the federation tick
    engine) may compute it once per (client, host) pair and reuse it, while
    ``virtual_extension`` recomputes per handshake.
    """
    neigh, rels, rows = neighbor_structure(
        client_kg, aligned_client, max_neighbors=max_neighbors
    )
    if len(rows) == 0:
        return None
    ent_map = {int(e): e0 + i for i, e in enumerate(neigh)}
    rel_map = {int(r): r0 + i for i, r in enumerate(rels)}
    align_map = {int(c): int(h) for c, h in zip(aligned_client, aligned_host)}

    extra = []
    for n, r, a, direction in rows:
        host_a = align_map[int(a)]
        vn, vr = ent_map[int(n)], rel_map[int(r)]
        if direction == 0:  # (neighbor) -r-> (aligned)
            extra.append((vn, vr, host_a))
        else:  # (aligned) -r-> (neighbor)
            extra.append((host_a, vr, vn))
    return neigh, rels, np.asarray(extra, np.int64)


def virtual_extension(
    host_trainer,
    client_trainer,
    client_kg,
    aligned_client: np.ndarray,
    aligned_host: np.ndarray,
    generate_fn,
) -> Optional[VirtualExtension]:
    """Extend the host KG with DP-translated virtual entities/relations.

    ``generate_fn`` is the client's DP generator (embeddings → host space);
    only G(N(X)) crosses the boundary, never raw client embeddings.
    """
    vs = virtual_structure(
        client_kg, aligned_client, aligned_host,
        host_trainer.model.num_entities, host_trainer.model.num_relations,
    )
    if vs is None:
        return None
    neigh, rels, extra = vs
    # translated (DP) embeddings of the neighbors and joining relations —
    # kept on device (the generator already ran host-side): staging them
    # through host numpy was a device→host→device round trip per handshake
    v_ent = jnp.asarray(generate_fn(client_trainer.get_entity_embeddings(neigh)))
    v_rel = jnp.asarray(generate_fn(client_trainer.get_relation_embeddings(rels)))

    host_trainer.extend_tables(v_ent, v_rel, extra)
    return VirtualExtension(len(neigh), len(rels), extra)
