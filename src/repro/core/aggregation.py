"""KGEmb-Update — merging PPAT output back into a KG's embedding tables.

Two pieces (§3.2.1 last paragraph + §4.3 Tab. 7):
  * ``kgemb_update``: replace (or average into) the host's aligned-entity
    embeddings with the DP-synthesized ``G(X)`` — and symmetrically let the
    client adopt the unified embeddings.
  * ``virtual_extension`` (FKGE vs FKGE-simple): the client additionally
    translates the *neighbors* of aligned entities, G(N(X)), which the host
    temporarily adds as virtual entities/relations + their adjacency triples
    for the next local-training round; they are removed afterwards.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np


def kgemb_update(
    trainer,
    aligned_idx: np.ndarray,
    synthesized: jnp.ndarray,
    *,
    mode: str = "average",
) -> None:
    """Write synthesized embeddings for ``aligned_idx`` into ``trainer``.

    mode='replace' → paper's plain replacement; 'average' → FKGE's smoother
    aggregation (Tab. 7 compares aggregation settings).
    """
    if mode == "replace":
        new = synthesized
    elif mode == "average":
        cur = trainer.get_entity_embeddings(aligned_idx)
        new = 0.5 * (cur + synthesized)
    else:
        raise ValueError(f"unknown aggregation mode {mode!r}")
    trainer.set_entity_embeddings(aligned_idx, new)


@dataclass
class VirtualExtension:
    """Bookkeeping to add & later strip virtual rows from a host trainer."""

    n_virtual_ent: int
    n_virtual_rel: int
    extra_triples: np.ndarray  # (M, 3) in the extended id space


def neighbor_structure(
    kg, aligned_local: np.ndarray, *, max_neighbors: int = 2000
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Client side: N(X) — neighbor entities + joining relations of aligned
    entities, and the adjacency triples (neighbor, relation, aligned).

    Returns (neighbor_ids, relation_ids, triples[h_is_neighbor, r, t_aligned])
    with ids local to the client KG."""
    aligned = set(int(i) for i in aligned_local)
    tri = kg.train
    mask_t = np.fromiter((int(t) in aligned for t in tri[:, 2]), bool, len(tri))
    mask_h = np.fromiter((int(h) in aligned for h in tri[:, 0]), bool, len(tri))
    # triples whose tail is aligned: head is the virtual neighbor
    tail_side = tri[mask_t & ~mask_h]
    # triples whose head is aligned: tail is the virtual neighbor (reverse)
    head_side = tri[mask_h & ~mask_t]
    rows = []
    for h, r, t in tail_side:
        rows.append((int(h), int(r), int(t), 0))
    for h, r, t in head_side:
        rows.append((int(t), int(r), int(h), 1))  # store neighbor first
    if not rows:
        return np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros((0, 4), np.int64)
    rows = np.asarray(rows, np.int64)[:max_neighbors]
    neigh = np.unique(rows[:, 0])
    rels = np.unique(rows[:, 1])
    return neigh, rels, rows


def virtual_structure(
    client_kg,
    aligned_client: np.ndarray,
    aligned_host: np.ndarray,
    e0: int,
    r0: int,
    *,
    max_neighbors: int = 2000,
) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """The id-space part of a virtual extension: neighbor entity ids, joining
    relation ids (client-local), and the adjacency triples remapped into the
    host id space, where virtual rows occupy ids ``e0..``/``r0..``.

    Deterministic in (client_kg.train, aligned sets, host table sizes), all
    of which are immutable between ticks — so callers (the federation tick
    engine) may compute it once per (client, host) pair and reuse it, while
    ``virtual_extension`` recomputes per handshake.
    """
    neigh, rels, rows = neighbor_structure(
        client_kg, aligned_client, max_neighbors=max_neighbors
    )
    if len(rows) == 0:
        return None
    ent_map = {int(e): e0 + i for i, e in enumerate(neigh)}
    rel_map = {int(r): r0 + i for i, r in enumerate(rels)}
    align_map = {int(c): int(h) for c, h in zip(aligned_client, aligned_host)}

    extra = []
    for n, r, a, direction in rows:
        host_a = align_map[int(a)]
        vn, vr = ent_map[int(n)], rel_map[int(r)]
        if direction == 0:  # (neighbor) -r-> (aligned)
            extra.append((vn, vr, host_a))
        else:  # (aligned) -r-> (neighbor)
            extra.append((host_a, vr, vn))
    return neigh, rels, np.asarray(extra, np.int64)


def virtual_extension(
    host_trainer,
    client_trainer,
    client_kg,
    aligned_client: np.ndarray,
    aligned_host: np.ndarray,
    generate_fn,
) -> Optional[VirtualExtension]:
    """Extend the host KG with DP-translated virtual entities/relations.

    ``generate_fn`` is the client's DP generator (embeddings → host space);
    only G(N(X)) crosses the boundary, never raw client embeddings.
    """
    vs = virtual_structure(
        client_kg, aligned_client, aligned_host,
        host_trainer.model.num_entities, host_trainer.model.num_relations,
    )
    if vs is None:
        return None
    neigh, rels, extra = vs
    # translated (DP) embeddings of the neighbors and joining relations —
    # kept on device (the generator already ran host-side): staging them
    # through host numpy was a device→host→device round trip per handshake
    v_ent = jnp.asarray(generate_fn(client_trainer.get_entity_embeddings(neigh)))
    v_rel = jnp.asarray(generate_fn(client_trainer.get_relation_embeddings(rels)))

    host_trainer.extend_tables(v_ent, v_rel, extra)
    return VirtualExtension(len(neigh), len(rels), extra)
