"""PATE mechanism — Eqs. (5)–(6) of the paper.

Teacher discriminators vote {0,1} per sample; i.i.d. Laplace(λ) noise is added
to each class's vote count and the noisy argmax becomes the student's label.
Vectorized over the teacher axis (the paper trains |T| separate nets; we hold
them as one stacked pytree and ``vmap``) and over the sample batch.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def teacher_votes(probs: jnp.ndarray) -> jnp.ndarray:
    """probs: (T, B) teacher sigmoid outputs → hard votes (T, B) in {0,1}."""
    return (probs >= 0.5).astype(jnp.int32)


def pate_vote(
    key: jax.Array, votes: jnp.ndarray, lam: float
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Noisy-argmax aggregation (Eq. 5).

    votes: (T, B) hard {0,1} votes → (labels (B,), n0 (B,), n1 (B,)).
    ``n0``/``n1`` are the *clean* counts — the accountant (Eq. 10) consumes
    them; only the released labels carry the noise.

    λ semantics: the paper's Tab. 1 calls λ the "noise (scale)", but Eqs.
    (9)–(10) are PATE's Theorems 2–3 verbatim, in which the noise is
    Lap(1/γ) with γ≡λ. We follow the equations (noise scale = 1/λ) so the
    accountant and the mechanism are consistent; λ=0 disables noise (the
    Tab. 5 "No noise" column — no DP guarantee). The ambiguity is recorded
    in EXPERIMENTS.md.
    """
    t, b = votes.shape
    n1 = jnp.sum(votes, axis=0)  # (B,)
    n0 = t - n1
    scale = 0.0 if lam <= 0 else 1.0 / lam
    noise = jax.random.laplace(key, (2, b)) * scale
    noisy0 = n0.astype(jnp.float32) + noise[0]
    noisy1 = n1.astype(jnp.float32) + noise[1]
    labels = (noisy1 > noisy0).astype(jnp.float32)
    return labels, n0, n1
