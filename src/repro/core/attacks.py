"""Measured-leakage attacks against the PPAT message surface.

The paper's privacy argument is an (ε, δ) bookkeeping exercise (moments
accountant over the PATE teacher votes). "Quantifying and Defending against
Privacy Threats on Federated KGE" (arXiv 2304.02932) makes the case that ε
alone is not evidence: the released messages must be *attacked* and the
attack's success measured. This module implements the two standard attacks
against the only thing FKGE ever releases — the DP-synthesized embeddings
``G(X)`` of the aligned entity set — as pure numpy scoring (no training,
no jax): the harness in ``benchmarks/attack_eval.py`` sweeps the DP noise
level and reports attack AUC/advantage next to the accounted ε, so the
"more noise ⇒ less leakage" claim is a measured curve, not an assertion.

  * :func:`membership_inference` — does a released embedding set reveal
    whether a specific triple was in the client's TRAINING data? The
    attacker fits per-relation translation offsets from background
    knowledge (triples it already knows are members — the standard shadow
    assumption), then scores candidate triples by TransE plausibility
    under the released rows. AUC 0.5 = no leakage; 1.0 = full membership
    disclosure.
  * :func:`reconstruction_attack` — how much of the client's private
    embedding geometry survives the DP release? The attacker fits the best
    orthogonal map (procrustes — it knows the release is a learned linear
    translation) from released to true rows and reports the residual
    alignment. Cosine ~1 = the release is the private table up to
    rotation; ~0 = geometry destroyed.

Both attacks operate on numpy arrays so they run identically against a live
scheduler's exchanged messages or against arrays replayed from a benchmark
JSON artifact.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def auc(pos: np.ndarray, neg: np.ndarray) -> float:
    """Area under the ROC curve for score samples ``pos`` (should rank
    high) vs ``neg`` — the Mann-Whitney U statistic with tie-averaged
    ranks, exact for small samples (no threshold sweep)."""
    pos = np.asarray(pos, np.float64).ravel()
    neg = np.asarray(neg, np.float64).ravel()
    if pos.size == 0 or neg.size == 0:
        return 0.5
    both = np.concatenate([pos, neg])
    order = np.argsort(both, kind="mergesort")
    ranks = np.empty_like(both)
    ranks[order] = np.arange(1, both.size + 1, dtype=np.float64)
    # tie groups share the average rank — without this, AUC on heavily
    # quantized scores depends on sort order
    sorted_vals = both[order]
    i = 0
    while i < both.size:
        j = i
        while j + 1 < both.size and sorted_vals[j + 1] == sorted_vals[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = 0.5 * (i + j) + 1.0
        i = j + 1
    u = float(np.sum(ranks[: pos.size])) - pos.size * (pos.size + 1) / 2.0
    return u / (pos.size * neg.size)


def advantage(auc_value: float) -> float:
    """Membership advantage |2·AUC − 1| ∈ [0, 1]: the attacker's edge over
    coin-flipping, symmetric in score polarity."""
    return abs(2.0 * float(auc_value) - 1.0)


def _relation_offsets(
    ent: Dict[int, np.ndarray], triples: np.ndarray, dim: int
) -> Dict[int, np.ndarray]:
    """Per-relation translation vectors r̂ = mean(e_t − e_h) over the
    background triples whose endpoints are both released — the attacker's
    shadow model of the client's TransE geometry."""
    sums: Dict[int, np.ndarray] = {}
    counts: Dict[int, int] = {}
    for h, r, t in np.asarray(triples, np.int64):
        eh, et = ent.get(int(h)), ent.get(int(t))
        if eh is None or et is None:
            continue
        r = int(r)
        d = et - eh
        if r in sums:
            sums[r] += d
            counts[r] += 1
        else:
            sums[r] = d.astype(np.float64, copy=True)
            counts[r] = 1
    return {r: s / counts[r] for r, s in sums.items()}


def _score_triples(
    ent: Dict[int, np.ndarray],
    offsets: Dict[int, np.ndarray],
    triples: np.ndarray,
) -> np.ndarray:
    """TransE plausibility −‖e_h + r̂ − e_t‖ of each scoreable triple under
    the released rows (higher = more member-like). Triples whose endpoints
    or relation the attacker cannot resolve are skipped — membership of
    unreleased entities is out of the release's blast radius."""
    out = []
    for h, r, t in np.asarray(triples, np.int64):
        eh, et = ent.get(int(h)), ent.get(int(t))
        off = offsets.get(int(r))
        if eh is None or et is None or off is None:
            continue
        out.append(-float(np.linalg.norm(eh + off - et)))
    return np.asarray(out, np.float64)


def membership_inference(
    released_ent: Dict[int, np.ndarray],
    member_triples: np.ndarray,
    nonmember_triples: np.ndarray,
    background_triples: Optional[np.ndarray] = None,
) -> Dict[str, float]:
    """Membership-inference attack against a DP embedding release.

    ``released_ent`` maps client-local entity id → released (synthesized)
    row; ``member_triples`` are true training triples, ``nonmember_triples``
    held-out triples over the same entities, ``background_triples`` the
    attacker's prior knowledge for fitting relation offsets (defaults to
    the member set itself — the strongest, standard shadow assumption).

    Returns ``auc``, ``advantage``, and the scoreable counts (an attack
    that could score nothing reports AUC 0.5, not a crash).
    """
    if background_triples is None:
        background_triples = member_triples
    dim = next(iter(released_ent.values())).shape[0] if released_ent else 0
    ent = {int(k): np.asarray(v, np.float64) for k, v in released_ent.items()}
    offsets = _relation_offsets(ent, background_triples, dim)
    pos = _score_triples(ent, offsets, member_triples)
    neg = _score_triples(ent, offsets, nonmember_triples)
    a = auc(pos, neg)
    return {
        "auc": a,
        "advantage": advantage(a),
        "n_member": int(pos.size),
        "n_nonmember": int(neg.size),
    }


def reconstruction_attack(
    released: np.ndarray, true: np.ndarray
) -> Dict[str, float]:
    """Embedding-reconstruction attack: fit the best orthogonal map from
    released rows to the client's true private rows (numpy SVD procrustes —
    the attacker knows the release is a learned linear translation of X)
    and measure what survives: mean per-row cosine and MSE after the fit.

    ``cosine`` near 1 means the DP release preserved the private geometry
    up to rotation — reconstruction succeeded; near 0 means the noise
    destroyed it."""
    released = np.asarray(released, np.float64)
    true = np.asarray(true, np.float64)
    if released.shape != true.shape or released.size == 0:
        raise ValueError(
            f"released {released.shape} and true {true.shape} rows must "
            "match and be non-empty"
        )
    u, _, vt = np.linalg.svd(released.T @ true)
    w = u @ vt
    rec = released @ w
    num = np.sum(rec * true, axis=1)
    den = (
        np.linalg.norm(rec, axis=1) * np.linalg.norm(true, axis=1) + 1e-12
    )
    return {
        "cosine": float(np.mean(num / den)),
        "mse": float(np.mean((rec - true) ** 2)),
    }
