"""Byzantine peer model for the federation — seeded, deterministic attacks.

PR 6's fault layer models *random* failure (crash/straggle/drop/corrupt);
this module models *adversarial* peers — the threat the paper's protocol
actually faces when embeddings are exchanged with owners you don't trust.
"Quantifying and Defending against Privacy Threats on Federated KGE"
(arXiv 2304.02932) shows poisoning succeeds against exactly this message
surface, and a NaN screen is no defense against an attacker who crafts
finite rows: every strategy here stays strictly inside the receiver's
``screen_rows`` norm bound, so the undefended path accepts the message and
only the *robust* acceptance layer (``robust_agg`` / ``cos_screen`` /
reputation gating in ``core.federation``) can reject it.

Attack kinds (at most one per handshake entry):

  * ``drift``  — norm-evading targeted drift: the attacked client's shipped
                 rows are blended toward a persistent per-client random
                 direction, row norms capped at ``evade * bound`` so the
                 integrity screen passes. ``frac`` poisons only a seeded
                 subset of rows (a *targeted* poison): the honest majority
                 is what coordinate-wise median/trimmed aggregation needs
                 to reconstruct a usable update.
  * ``sybil``  — colluding drift: like ``drift`` but every sybil peer
                 shares ONE group direction (seeded by the plan alone, not
                 the client), so their poison compounds across peers and
                 ticks instead of averaging out.
  * ``replay`` — stale-view replay: the first view a peer ships per
                 (client, host) pair is cached and re-shipped on later
                 replay draws — a freshness attack, individually harmless
                 rows that are collectively stale.

Determinism: ``AdversaryPlan.draw`` is a pure function of
``(seed, tick, host, client)`` (same contract as ``FaultPlan.draw``), and
``tamper_view`` derives all randomness from the plan seed — so storms
reproduce bit-identically across both tick engines and across checkpoint
resume. The only adversary state is the replay cache, which is serialized
by ``save_scheduler``/``restore_scheduler`` precisely so resumed storms
replay the same stale views. Like the fault layer, the lockstep is
PER-ENTRY, not per-tick: the streamed scheduler (``tick_sync="stream"``)
executes a pass level by level and may tamper the same ``(tick, host,
client)`` twice (a re-offer handshake re-freezes and re-tampers a fresh
view), and because draws and directions are pure in those coordinates —
and the replay cache keys on the (client, host) pair, not the tick — the
storm a streamed pass sees is byte-identical across engines and
scheduling disciplines.

Resolution: ``kernels.dispatch.resolve_tick_adversary`` /
``REPRO_TICK_ADVERSARY`` / ``FederationScheduler(tick_adversary=...)``.
Default off ⇒ the adversary is ``None`` and every hook is an ``is None``
check — the adversary-off tick path stays bit-identical to the pre-attack
engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.faults import DEFAULT_NORM_BOUND, _stable_u32

#: fixed draw order — segment boundaries of the uniform draw; reordering
#: would silently change every seeded storm
ATTACK_KINDS = ("drift", "sybil", "replay")


@dataclass(frozen=True)
class Attack:
    """One drawn attack. ``strength`` is the drift blend factor γ (0 = no-op,
    1 = pure adversarial direction); ``evade`` scales the norm cap relative
    to the receiver's screen bound; ``frac`` is the poisoned-row fraction."""

    kind: str
    strength: float = 0.5
    evade: float = 0.9
    frac: float = 1.0


@dataclass(frozen=True)
class AdversaryPlan:
    """A seeded adversarial-peer schedule: per-entry attack rates plus an
    optional explicit ``table`` of pinned attacks.

    ``peers`` restricts which clients behave adversarially (empty = any
    client may draw an attack) — the sybil group is exactly the adversarial
    peer set. ``until`` bounds the storm window like ``FaultPlan.until``.
    ``draw`` is stateless so plans survive checkpoint/resume and reproduce
    identically under both tick engines.
    """

    drift: float = 0.0
    sybil: float = 0.0
    replay: float = 0.0
    peers: Tuple[str, ...] = ()
    seed: int = 0
    until: Optional[int] = None      # last tick (inclusive) that attacks
    strength: float = 0.5            # drift blend γ
    evade: float = 0.9               # norm cap = evade * screen bound
    frac: float = 1.0                # poisoned-row fraction per attack
    bound: float = DEFAULT_NORM_BOUND
    table: Optional[Dict[Tuple[int, str], Attack]] = field(default=None)

    def __post_init__(self):
        for k in ATTACK_KINDS:
            r = getattr(self, k)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"attack rate {k}={r} outside [0, 1]")
        for k in ("strength", "evade", "frac"):
            v = getattr(self, k)
            if not 0.0 < v <= 1.0 and k != "strength":
                raise ValueError(f"{k}={v} outside (0, 1]")
        if not 0.0 <= self.strength <= 1.0:
            raise ValueError(f"strength={self.strength} outside [0, 1]")

    # ------------------------------------------------------------- drawing
    def draw(self, tick: int, host: str, client: Optional[str]
             ) -> Optional[Attack]:
        """The attack (if any) this peer mounts against this tick entry — a
        pure function of ``(seed, tick, host, client)``. Attacks live on the
        handshake message surface, so self-train entries (``client=None``)
        and clients outside the adversarial ``peers`` set never attack."""
        if client is None:
            return None
        if self.peers and client not in self.peers:
            return None
        if self.table is not None:
            hit = self.table.get((tick, host))
            if hit is not None:
                return hit
        if self.until is not None and tick > self.until:
            return None
        # a distinct stream from FaultPlan's (offset first element), so an
        # adversary layered over a fault storm with the same seed draws
        # independently
        rng = np.random.default_rng(
            (self.seed + 0xAD7E, tick, _stable_u32(host),
             _stable_u32(client or ""))
        )
        u = float(rng.random())
        lo = 0.0
        for kind in ATTACK_KINDS:
            hi = lo + getattr(self, kind)
            if lo <= u < hi:
                return Attack(
                    kind, strength=self.strength, evade=self.evade,
                    frac=self.frac,
                )
            lo = hi
        return None

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str) -> "AdversaryPlan":
        """Build a plan from the ``REPRO_TICK_ADVERSARY`` /
        ``tick_adversary=`` string grammar: comma-separated ``key=value``
        pairs, e.g. ``"drift=0.6,peers=K1+K2,seed=7,until=10,strength=0.8"``
        (``peers`` is ``+``-separated). Bare ``"on"`` arms the layer with
        zero rates (hooks active, nothing injected)."""
        kw: Dict[str, object] = {}
        spec = spec.strip()
        if spec.lower() == "on":
            return cls()
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"bad tick_adversary clause {part!r} (key=value)"
                )
            k, v = (s.strip() for s in part.split("=", 1))
            if k in ATTACK_KINDS + ("strength", "evade", "frac", "bound"):
                kw[k] = float(v)
            elif k in ("seed", "until"):
                kw[k] = int(v)
            elif k == "peers":
                kw[k] = tuple(p for p in v.split("+") if p)
            else:
                raise ValueError(f"unknown tick_adversary key {k!r}")
        return cls(**kw)  # type: ignore[arg-type]


class Adversary:
    """Per-scheduler wrapper around an :class:`AdversaryPlan`: draws
    attacks, tampers client views, keeps per-kind counts (pure telemetry)
    and the replay cache of first-shipped views (serialized on checkpoint —
    the ONLY adversary state that feeds back into behavior)."""

    def __init__(self, plan: AdversaryPlan):
        self.plan = plan
        self.counts: Dict[str, int] = {}
        #: (client, host) → the first params view that pair ever shipped
        #: (numpy copies; replayed verbatim on later ``replay`` draws)
        self._stale: Dict[Tuple[str, str], Dict[str, np.ndarray]] = {}

    def draw(self, tick: int, host: str, client: Optional[str] = None
             ) -> Optional[Attack]:
        a = self.plan.draw(tick, host, client)
        if a is not None:
            self.counts[a.kind] = self.counts.get(a.kind, 0) + 1
        return a

    # ----------------------------------------------------------- tampering
    def _direction(self, client: str, dim: int, kind: str) -> np.ndarray:
        """The drift target direction: a persistent unit vector. ``drift``
        seeds it per client; ``sybil`` seeds it from the plan alone, so all
        colluding peers push the same way every tick — their poison
        compounds instead of averaging out."""
        if kind == "sybil":
            key: Tuple[int, ...] = (self.plan.seed + 0x5B11,)
        else:
            key = (self.plan.seed + 0xD21F7, _stable_u32(client))
        rng = np.random.default_rng(key)
        d = rng.standard_normal(dim).astype(np.float32)
        return d / max(float(np.linalg.norm(d)), 1e-12)

    def tamper_view(
        self,
        view: Dict,
        attack: Attack,
        tick: int,
        host: str,
        client: str,
        *,
        rows: np.ndarray,
    ) -> Dict:
        """Apply one drawn attack to a client-view params snapshot, touching
        exactly the rows the host will read (aligned set + virtual
        neighbors). Pure given (plan, attack, tick, host, client, view) —
        both tick engines and a resumed run tamper bit-identically.

        Every produced row is finite with norm ≤ ``evade * bound``: the
        receiver's ``screen_rows`` integrity check passes by construction —
        these messages can only be stopped by the robust acceptance layer.
        """
        if attack.kind == "replay":
            key = (client, host)
            cached = self._stale.get(key)
            if cached is None:
                # first fire: record what this pair ships today; the attack
                # itself is a no-op this tick
                self._stale[key] = {
                    k: np.array(v, dtype=np.float32, copy=True)
                    for k, v in view.items()
                }
                return view
            import jax.numpy as jnp

            return {k: jnp.asarray(v) for k, v in cached.items()}

        ent = np.array(view["ent"], dtype=np.float32, copy=True)
        rows = np.unique(np.asarray(rows, np.int64))
        rows = rows[(rows >= 0) & (rows < ent.shape[0])]
        if rows.size == 0:
            return view
        if attack.frac < 1.0:
            # targeted subset, seeded per entry — deterministic, and the
            # honest remainder is what robust aggregation leans on
            rng = np.random.default_rng(
                (self.plan.seed + 0xF2AC, tick, _stable_u32(host),
                 _stable_u32(client))
            )
            k = max(1, int(np.ceil(attack.frac * rows.size)))
            rows = np.sort(rng.choice(rows, size=k, replace=False))
        d = self._direction(client, ent.shape[1], attack.kind)
        sel = ent[rows]
        norms = np.linalg.norm(sel, axis=1, keepdims=True)
        target = norms * d[None, :]
        new = (1.0 - attack.strength) * sel + attack.strength * target
        # norm-evading cap: just under the receiver's screen bound
        cap = attack.evade * self.plan.bound
        nn = np.linalg.norm(new, axis=1, keepdims=True)
        new = new * np.minimum(1.0, cap / np.maximum(nn, 1e-12))
        ent[rows] = new.astype(np.float32)
        import jax.numpy as jnp

        out = dict(view)
        out["ent"] = jnp.asarray(ent)
        return out

    # -------------------------------------------------- checkpoint surface
    def stale_arrays(self) -> Dict[str, Dict[str, np.ndarray]]:
        """The replay cache as a checkpointable tree:
        ``{"client::host": {leaf: array}}`` (see ``save_scheduler``)."""
        return {
            f"{c}::{h}": dict(v) for (c, h), v in sorted(self._stale.items())
        }

    def load_stale(self, tree: Dict[str, Dict]) -> None:
        self._stale = {
            tuple(key.split("::", 1)): {
                k: np.asarray(a, np.float32) for k, a in leaves.items()
            }
            for key, leaves in tree.items()
        }


def resolve_adversary(src) -> Optional[Adversary]:
    """Normalize a resolved ``tick_adversary`` source (spec string /
    ``AdversaryPlan`` / ``Adversary``) to an :class:`Adversary`."""
    if src is None:
        return None
    if isinstance(src, Adversary):
        return src
    plan = src if isinstance(src, AdversaryPlan) else AdversaryPlan.parse(src)
    return Adversary(plan)
