# The paper's primary contribution: PPAT (privacy-preserving adversarial
# translation), PATE differential privacy, and the federated orchestrator.
from repro.core.pate import pate_vote, teacher_votes  # noqa: F401
from repro.core.privacy import MomentsAccountant  # noqa: F401
from repro.core.ppat import PPATConfig, PPATHost, PPATClient, train_ppat  # noqa: F401
from repro.core.alignment import csls, AlignmentRegistry  # noqa: F401
from repro.core.aggregation import kgemb_update, virtual_extension  # noqa: F401
from repro.core.federation import FederationScheduler, NodeState  # noqa: F401
