"""Moments accountant for the PPAT network — Eqs. (8)–(10), Alg. 2 ll. 18–20.

Tracks α(l) for a range of moments l; each PATE query (one noisy vote batch)
adds the per-query moment bound

    α(l) += min{ 2λ²l(l+1),
                 log((1−q)·((1−q)/(1−e^{2λ}q))^l + q·e^{2λl}) }        (Eq. 9)
    q    = (2 + λ|n0−n1|) / (4·exp(λ|n0−n1|))                          (Eq. 10)

and the privacy estimate is ε̂ = min_l (α(l) + log(1/δ)) / l (Eq. 8). The
data-dependent log-term is only a valid bound when q < 1/(1+e^{2λ}) (PATE
Thms. 2–3); outside that regime we fall back to the data-independent
2λ²l(l+1) term, which the ``min`` does automatically once the log-term is
guarded against producing NaN/negative values.
"""
from __future__ import annotations

import numpy as np


class MomentsAccountant:
    def __init__(self, lam: float, delta: float, max_moment: int = 32):
        self.lam = float(lam)
        self.delta = float(delta)
        self.ls = np.arange(1, max_moment + 1, dtype=np.float64)
        self.alpha = np.zeros_like(self.ls)
        self.queries = 0

    def update(self, n0, n1) -> None:
        """Account one PATE query (or a batch: n0/n1 arrays)."""
        n0 = np.atleast_1d(np.asarray(n0, dtype=np.float64))
        n1 = np.atleast_1d(np.asarray(n1, dtype=np.float64))
        lam, ls = self.lam, self.ls
        for a, b in zip(n0, n1):
            gap = abs(a - b)
            q = (2.0 + lam * gap) / (4.0 * np.exp(lam * gap))  # Eq. 10
            data_indep = 2.0 * lam**2 * ls * (ls + 1.0)
            denom = 1.0 - np.exp(2.0 * lam) * q
            if q < 1.0 / (1.0 + np.exp(2.0 * lam)) and denom > 0:
                with np.errstate(over="ignore"):
                    term = (1.0 - q) * ((1.0 - q) / denom) ** ls + q * np.exp(
                        2.0 * lam * ls
                    )
                data_dep = np.log(np.maximum(term, 1e-300))
                bound = np.minimum(data_indep, np.maximum(data_dep, 0.0))
            else:
                bound = data_indep
            self.alpha += bound
            self.queries += 1

    def epsilon(self) -> float:
        """ε̂ = min_l (α(l) + log(1/δ)) / l — Eq. 8."""
        return float(np.min((self.alpha + np.log(1.0 / self.delta)) / self.ls))

    def best_moment(self) -> int:
        return int(self.ls[np.argmin((self.alpha + np.log(1.0 / self.delta)) / self.ls)])

    def max_alpha(self) -> float:
        return float(np.max(self.alpha))
