"""Moments accountant for the PPAT network — Eqs. (8)–(10), Alg. 2 ll. 18–20.

Tracks α(l) for a range of moments l; each PATE query (one noisy vote batch)
adds the per-query moment bound

    α(l) += min{ 2λ²l(l+1),
                 log((1−q)·((1−q)/(1−e^{2λ}q))^l + q·e^{2λl}) }        (Eq. 9)
    q    = (2 + λ|n0−n1|) / (4·exp(λ|n0−n1|))                          (Eq. 10)

and the privacy estimate is ε̂ = min_l (α(l) + log(1/δ)) / l (Eq. 8). The
data-dependent log-term is only a valid bound when q < 1/(1+e^{2λ}) (PATE
Thms. 2–3); outside that regime we fall back to the data-independent
2λ²l(l+1) term, which the ``min`` does automatically once the log-term is
guarded against producing NaN/negative values.
"""
from __future__ import annotations

import numpy as np


class MomentsAccountant:
    def __init__(self, lam: float, delta: float, max_moment: int = 32):
        self.lam = float(lam)
        self.delta = float(delta)
        self.ls = np.arange(1, max_moment + 1, dtype=np.float64)
        self.alpha = np.zeros_like(self.ls)
        self.queries = 0

    def update(self, n0, n1) -> None:
        """Account one PATE query (or a batch: n0/n1 arrays).

        Vectorized over the query batch: one (Q, L) broadcast instead of a
        Python loop — a federation tick accounts steps × batch ≈ 2k queries
        per handshake, and the per-query loop was a measurable host-side
        serial cost in an otherwise device-resident tick. Per-query math is
        Eqs. 9–10 exactly as before; the moment accumulators gain only the
        usual pairwise-vs-sequential float summation reordering (both tick
        engines share this accountant, so their ε parity is unaffected)."""
        n0 = np.atleast_1d(np.asarray(n0, dtype=np.float64)).ravel()
        n1 = np.atleast_1d(np.asarray(n1, dtype=np.float64)).ravel()
        if n0.size == 0:
            return
        lam, ls = self.lam, self.ls
        gap = np.abs(n0 - n1)                                   # (Q,)
        q = (2.0 + lam * gap) / (4.0 * np.exp(lam * gap))       # Eq. 10
        data_indep = 2.0 * lam**2 * ls * (ls + 1.0)             # (L,)
        denom = 1.0 - np.exp(2.0 * lam) * q                     # (Q,)
        ok = (q < 1.0 / (1.0 + np.exp(2.0 * lam))) & (denom > 0)
        with np.errstate(over="ignore", divide="ignore", invalid="ignore"):
            ratio = (1.0 - q) / np.where(ok, denom, 1.0)        # (Q,)
            term = (
                (1.0 - q)[:, None] * ratio[:, None] ** ls[None, :]
                + q[:, None] * np.exp(2.0 * lam * ls)[None, :]
            )                                                   # (Q, L)
            data_dep = np.log(np.maximum(term, 1e-300))
        bound = np.where(
            ok[:, None],
            np.minimum(data_indep[None, :], np.maximum(data_dep, 0.0)),
            data_indep[None, :],
        )
        self.alpha += bound.sum(axis=0)
        self.queries += int(gap.size)

    def merge(self, other: "MomentsAccountant") -> None:
        """Fold another accountant's spend into this one. Moment bounds are
        additive across queries (Eq. 9 accumulates per query), so merging a
        per-handshake accountant into a federation-lifetime one yields the
        composed bound bit-for-bit — the scheduler uses this to keep a
        cumulative ε across every handshake it ever executed."""
        if (self.lam, self.delta) != (other.lam, other.delta) or \
                self.ls.shape != other.ls.shape:
            raise ValueError("cannot merge accountants with different "
                             "(lam, delta, max_moment)")
        self.alpha += other.alpha
        self.queries += other.queries

    def state_dict(self) -> dict:
        """JSON-serializable snapshot for crash-consistent scheduler resume
        (``checkpoint.save_scheduler``). Floats round-trip exactly through
        ``repr`` — the restored accountant reports bit-identical ε."""
        return {
            "lam": self.lam,
            "delta": self.delta,
            "alpha": [float(a) for a in self.alpha],
            "queries": int(self.queries),
        }

    def load_state_dict(self, state: dict) -> None:
        if (float(state["lam"]), float(state["delta"])) != (self.lam, self.delta):
            raise ValueError("checkpointed accountant (lam, delta) mismatch")
        alpha = np.asarray(state["alpha"], dtype=np.float64)
        if alpha.shape != self.alpha.shape:
            raise ValueError("checkpointed accountant moment range mismatch")
        self.alpha = alpha
        self.queries = int(state["queries"])

    def epsilon(self) -> float:
        """ε̂ = min_l (α(l) + log(1/δ)) / l — Eq. 8."""
        return float(np.min((self.alpha + np.log(1.0 / self.delta)) / self.ls))

    def best_moment(self) -> int:
        return int(self.ls[np.argmin((self.alpha + np.log(1.0 / self.delta)) / self.ls)])

    def max_alpha(self) -> float:
        return float(np.max(self.alpha))
