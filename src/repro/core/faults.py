"""Deterministic fault-injection harness for the federation stack.

The paper sells FKGE as a decentralized, asynchronous, peer-to-peer
framework, but a scheduler that assumes every peer always succeeds cannot
claim any of those words: real federations (FedE, arXiv 2010.12882; FedR,
arXiv 2203.09553) treat client dropout, stragglers, and partial
participation as the normal case. This module is the chaos side of the
fault-tolerance layer: a seeded, fully deterministic plan of injected
failures that both tick engines honor, so the failure semantics in
``core.federation`` / ``core.tick_engine`` can be *proved* by tests instead
of asserted in prose.

Fault kinds (one per tick entry at most):

  * ``crash``    — the host owner dies mid-entry: the entry raises before
                   any PPAT key is consumed; the scheduler isolates it,
                   restores the host snapshot, and re-queues the handshake
                   with exponential backoff.
  * ``straggle`` — the entry completes but late: an injected delay is added
                   to the entry's measured wall-clock, and a configured
                   ``tick_deadline`` marks it a straggler — its result is
                   discarded and the handshake deferred, without stalling
                   the rest of the tick. (The delay is *simulated* — added
                   to the measurement, never slept — so chaos soaks stay
                   fast and deterministic.)
  * ``drop``     — the client's PPAT message is lost in transit: same
                   re-queue path as ``crash`` but attributed to the network,
                   so neither peer accrues quarantine blame.
  * ``corrupt``  — the client's exchanged embeddings arrive damaged
                   (NaN or norm-bound-violating garbage rows). Detection is
                   the receiver's job: the non-finite / norm screens on
                   ``_ClientView`` gathers reject the handshake through the
                   existing backtrack-restore path and blame the client.

Determinism: every draw is a pure function of ``(seed, tick, host, client)``
— no injector state feeds back into the draw — so a scheduler resumed from a
mid-run checkpoint sees exactly the faults the uninterrupted run would have
seen, and two engines driving the same plan inject identically. The same
purity is what makes the key-stream lockstep PER-ENTRY rather than per-tick:
the streamed scheduler (``tick_sync="stream"``) executes a pass's entries
level by level in a different order than the barrier loop, and a re-offered
handshake executes twice in one pass — both re-draw the identical fault for
their ``(tick, host, client)``, so storms are byte-identical across
engines, scheduling disciplines, and resume points.

Resolution: ``kernels.dispatch.resolve_tick_faults`` /
``REPRO_TICK_FAULTS`` / ``FederationScheduler(tick_faults=...)``. Default
off ⇒ the injector is ``None`` and every hook is an ``is None`` check — the
faults-off tick path stays bit-identical to the pre-fault engine.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

#: fixed draw order — segment boundaries of the uniform draw; reordering
#: would silently change every seeded plan
FAULT_KINDS = ("crash", "straggle", "drop", "corrupt")

#: row-norm screen default: entity tables are renormalized toward unit norm
#: every epoch, so anything beyond this is not an embedding
DEFAULT_NORM_BOUND = 1e3


class FaultError(RuntimeError):
    """An injected (or detected) fault for one tick entry."""

    def __init__(self, kind: str, host: str, client: Optional[str] = None):
        super().__init__(f"fault[{kind}] host={host} client={client}")
        self.kind = kind
        self.host = host
        self.client = client


class CorruptEmbeddingError(FaultError):
    """Incoming client embeddings failed the non-finite / norm-bound screen."""

    def __init__(self, host: str, client: Optional[str], detail: str):
        super().__init__("corrupt", host, client)
        self.detail = detail


@dataclass(frozen=True)
class Fault:
    """One injected fault. ``delay`` is the straggle's simulated seconds;
    ``rows`` / ``mode`` shape the corruption (NaN vs out-of-norm garbage)."""

    kind: str
    delay: float = 0.0
    rows: int = 4
    mode: str = "nan"  # "nan" | "garbage"


def _stable_u32(s: str) -> int:
    """Process- and platform-stable string hash (Python's ``hash`` is salted
    per process, which would break cross-process fault determinism)."""
    return zlib.crc32(s.encode("utf-8")) & 0xFFFFFFFF


@dataclass(frozen=True)
class FaultPlan:
    """A seeded chaos schedule: per-entry fault rates plus an optional
    explicit ``table`` of pinned faults.

    ``draw`` is stateless — ``(seed, tick, host, client)`` fully determines
    the outcome — so plans survive checkpoint/resume and are identical under
    both tick engines. ``until`` bounds the chaos window (ticks > ``until``
    inject nothing), which is how soak tests let the federation heal and
    converge after the storm.
    """

    crash: float = 0.0
    straggle: float = 0.0
    drop: float = 0.0
    corrupt: float = 0.0
    seed: int = 0
    until: Optional[int] = None   # last tick (inclusive) that injects
    delay: float = 1.0            # straggle: simulated seconds
    rows: int = 4                 # corrupt: damaged row count
    mode: str = "nan"             # corrupt: "nan" | "garbage"
    norm_bound: float = DEFAULT_NORM_BOUND
    table: Optional[Dict[Tuple[int, str], Fault]] = field(default=None)

    def __post_init__(self):
        for k in FAULT_KINDS:
            r = getattr(self, k)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"fault rate {k}={r} outside [0, 1]")
        if self.mode not in ("nan", "garbage"):
            raise ValueError(f"corrupt mode {self.mode!r} (nan|garbage)")

    # ------------------------------------------------------------- drawing
    def draw(self, tick: int, host: str, client: Optional[str]) -> Optional[Fault]:
        """The fault (if any) for this tick entry — a pure function of
        ``(seed, tick, host, client)``. ``drop``/``corrupt`` only apply to
        handshake entries (there is no message to lose on a self-train)."""
        if self.table is not None:
            hit = self.table.get((tick, host))
            if hit is not None:
                if client is None and hit.kind in ("drop", "corrupt"):
                    return None
                return hit
        if self.until is not None and tick > self.until:
            return None
        rng = np.random.default_rng(
            (self.seed, tick, _stable_u32(host), _stable_u32(client or ""))
        )
        u = float(rng.random())
        lo = 0.0
        for kind in FAULT_KINDS:
            hi = lo + getattr(self, kind)
            if lo <= u < hi:
                if client is None and kind in ("drop", "corrupt"):
                    return None
                return Fault(
                    kind, delay=self.delay, rows=self.rows, mode=self.mode
                )
            lo = hi
        return None

    # ------------------------------------------------------------ builders
    @classmethod
    def slow_owner(
        cls, host: str, *, delay: float, ticks: int, first_tick: int = 1,
    ) -> "FaultPlan":
        """The straggler-storm scenario: one pinned slow owner. ``host``
        draws a simulated-``delay`` straggle every time it hosts an entry
        in ticks ``first_tick .. first_tick + ticks - 1``; every other
        owner runs clean. With no ``tick_deadline`` configured the slow
        results are still accepted — the owner is merely late, which is
        exactly the case the streamed scheduler must not let stall the
        mesh (and the barrier scheduler, by construction, does)."""
        table = {
            (t, host): Fault("straggle", delay=float(delay))
            for t in range(first_tick, first_tick + ticks)
        }
        return cls(table=table)

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from the ``REPRO_TICK_FAULTS`` / ``tick_faults=``
        string grammar: comma-separated ``key=value`` pairs, e.g.
        ``"crash=0.2,straggle=0.1,corrupt=0.1,seed=7,until=6,delay=0.5"``.
        Bare ``"on"`` enables the layer (screens + hooks) with no injection.
        """
        kw: Dict[str, object] = {}
        spec = spec.strip()
        if spec.lower() in ("on", "screen"):
            return cls()
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad tick_faults clause {part!r} (key=value)")
            k, v = (s.strip() for s in part.split("=", 1))
            if k in FAULT_KINDS + ("delay", "norm_bound"):
                kw[k] = float(v)
            elif k in ("seed", "until", "rows"):
                kw[k] = int(v)
            elif k == "mode":
                kw[k] = v
            else:
                raise ValueError(f"unknown tick_faults key {k!r}")
        return cls(**kw)  # type: ignore[arg-type]


#: fixed draw order for the serving-side plan — same contract as
#: FAULT_KINDS: reordering silently changes every seeded storm
SERVE_FAULT_KINDS = ("crash", "straggle", "poison")


class ServeFaultError(RuntimeError):
    """An injected (or detected) fault for one dispatched serving batch."""

    def __init__(self, kind: str, batch: int, replica: int):
        super().__init__(f"serve fault[{kind}] batch={batch} replica={replica}")
        self.kind = kind
        self.batch = batch
        self.replica = replica


@dataclass(frozen=True)
class ServeFault:
    """One injected serving fault. ``delay`` is the straggle's simulated
    seconds of suppressed readiness; ``rows`` is how many output rows the
    poison damages."""

    kind: str
    delay: float = 0.0
    rows: int = 1


@dataclass(frozen=True)
class ServeFaultPlan:
    """A seeded chaos schedule for the query path — the serving twin of
    :class:`FaultPlan`. Fault kinds (at most one per dispatched batch):

      * ``crash``    — the replica dies under the batch: collection raises,
                       the tier isolates the failure to this batch and
                       re-dispatches it once to a different replica instead
                       of failing its requests.
      * ``straggle`` — the replica is slow: the batch's device results exist
                       but report not-ready until ``delay`` simulated
                       seconds after dispatch, exercising the hedging path
                       (the delay gates readiness polling, it is never added
                       to the device work — storms stay fast).
      * ``poison``   — the replica returns damaged output: ``rows`` result
                       rows are corrupted after collection, and the tier's
                       armed output screen must catch them (negative rank
                       counts / non-finite top-k scores) and route the batch
                       through the same retry path as a crash.

    ``draw`` is a pure function of ``(seed, batch, replica)`` — ``batch``
    is the tier's monotone launch sequence number, so retries and hedges
    (which consume fresh sequence numbers) re-draw independently, and the
    same plan replays byte-identically across runs. ``until`` bounds the
    storm to launch sequence numbers ``<= until`` so soaks can assert the
    tier heals (breaker re-admission) on the clean tail. An explicit
    ``table`` of ``(batch, replica) -> ServeFault`` pins faults for
    deterministic scenario tests, exactly like ``FaultPlan.table``.
    """

    crash: float = 0.0
    straggle: float = 0.0
    poison: float = 0.0
    seed: int = 0
    until: Optional[int] = None   # last launch seq (inclusive) that injects
    delay: float = 0.05           # straggle: simulated seconds
    rows: int = 1                 # poison: damaged output rows
    table: Optional[Dict[Tuple[int, int], ServeFault]] = field(default=None)

    def __post_init__(self):
        for k in SERVE_FAULT_KINDS:
            r = getattr(self, k)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"serve fault rate {k}={r} outside [0, 1]")

    # ------------------------------------------------------------- drawing
    def draw(self, batch: int, replica: int) -> Optional[ServeFault]:
        """The fault (if any) for one dispatched batch — a pure function of
        ``(seed, batch, replica)``."""
        if self.table is not None:
            hit = self.table.get((batch, replica))
            if hit is not None:
                return hit
        if self.until is not None and batch > self.until:
            return None
        if not (self.crash or self.straggle or self.poison):
            return None
        rng = np.random.default_rng((self.seed, 0x5E57E, batch, replica))
        u = float(rng.random())
        lo = 0.0
        for kind in SERVE_FAULT_KINDS:
            hi = lo + getattr(self, kind)
            if lo <= u < hi:
                return ServeFault(kind, delay=self.delay, rows=self.rows)
            lo = hi
        return None

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str) -> "ServeFaultPlan":
        """Build a plan from the ``REPRO_SERVE_FAULTS`` / ``serve_faults=``
        string grammar: comma-separated ``key=value`` pairs, e.g.
        ``"crash=0.2,straggle=0.1,poison=0.1,seed=7,until=40,delay=0.05"``.
        Bare ``"on"`` arms the layer (output screens + draws) with no
        injection."""
        kw: Dict[str, object] = {}
        spec = spec.strip()
        if spec.lower() in ("on", "screen"):
            return cls()
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"bad serve_faults clause {part!r} (key=value)"
                )
            k, v = (s.strip() for s in part.split("=", 1))
            if k in SERVE_FAULT_KINDS + ("delay",):
                kw[k] = float(v)
            elif k in ("seed", "until", "rows"):
                kw[k] = int(v)
            else:
                raise ValueError(f"unknown serve_faults key {k!r}")
        return cls(**kw)  # type: ignore[arg-type]


class FaultInjector:
    """Per-scheduler wrapper around a :class:`FaultPlan`: draws faults,
    applies embedding corruption, and keeps per-kind injection counts (pure
    telemetry — counts never feed back into draws, so they need no
    checkpointing)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.counts: Dict[str, int] = {}

    @property
    def norm_bound(self) -> float:
        return self.plan.norm_bound

    def draw(self, tick: int, host: str, client: Optional[str] = None
             ) -> Optional[Fault]:
        f = self.plan.draw(tick, host, client)
        if f is not None:
            self.counts[f.kind] = self.counts.get(f.kind, 0) + 1
        return f

    def corrupt_view(self, params: Dict, fault: Fault, tick: int, host: str
                     ) -> Dict:
        """Damage a client-view params snapshot the way a broken peer would:
        ``rows`` entity rows become NaN (``mode="nan"``) or garbage far past
        the norm bound (``mode="garbage"``). Row choice is seeded by
        ``(seed, tick, host)`` — deterministic, like every draw."""
        import jax.numpy as jnp

        rng = np.random.default_rng(
            (self.plan.seed + 0x5EED, tick, _stable_u32(host))
        )
        ent = np.array(params["ent"], dtype=np.float32, copy=True)
        n = min(max(1, fault.rows), ent.shape[0])
        idx = rng.choice(ent.shape[0], size=n, replace=False)
        if fault.mode == "nan":
            ent[idx] = np.nan
        else:
            ent[idx] = rng.standard_normal((n, ent.shape[1])).astype(
                np.float32
            ) * (10.0 * self.plan.norm_bound)
        out = dict(params)
        out["ent"] = jnp.asarray(ent)
        return out


def screen_rows(rows, *, bound: float, host: str, client: Optional[str],
                what: str = "embeddings") -> None:
    """Receiver-side integrity screen on exchanged embedding rows: reject
    non-finite values and row norms beyond ``bound``. Raises
    :class:`CorruptEmbeddingError` (a :class:`FaultError`, so the scheduler
    routes it through the backtrack-restore failure path and blames the
    sender). Costs one host sync per gather — only wired in when a fault
    injector is active, keeping the faults-off path untouched."""
    a = np.asarray(rows)
    if a.size == 0:
        return
    if not np.isfinite(a).all():
        raise CorruptEmbeddingError(
            host, client, f"non-finite values in incoming {what}"
        )
    worst = float(np.max(np.linalg.norm(a.reshape(a.shape[0], -1), axis=1)))
    if worst > bound:
        raise CorruptEmbeddingError(
            host, client,
            f"incoming {what} row norm {worst:.3g} exceeds bound {bound:.3g}",
        )
