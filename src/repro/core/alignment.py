"""Aligned-entity registry + CSLS (cross-domain similarity local scaling).

The paper assumes aligned entities/relations are given (matched via secure
hash of canonical URIs — footnote 4). ``AlignmentRegistry`` plays that role:
it stores, per KG pair, index arrays into each side's embedding tables.

CSLS (MUSE, used by the student discriminator's input metric §3.2.1) scales
cosine similarity by mean similarity to each point's k nearest neighbors,
mitigating hubness. We use it as the translation-quality metric and expose a
Pallas-accelerated path (kernels/csls) for large alignment sets.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


def cosine_sim(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    an = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-9)
    bn = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-9)
    return an @ bn.T


def csls(a: jnp.ndarray, b: jnp.ndarray, k: int = 10) -> jnp.ndarray:
    """CSLS(a_i, b_j) = 2·cos(a_i, b_j) − r_B(a_i) − r_A(b_j)."""
    sim = cosine_sim(a, b)  # (n, m)
    kk = min(k, sim.shape[1])
    kk2 = min(k, sim.shape[0])
    r_a = jnp.mean(jnp.sort(sim, axis=1)[:, -kk:], axis=1)  # (n,)
    r_b = jnp.mean(jnp.sort(sim, axis=0)[-kk2:, :], axis=0)  # (m,)
    return 2 * sim - r_a[:, None] - r_b[None, :]


def csls_retrieval_acc(a: jnp.ndarray, b: jnp.ndarray, k: int = 10) -> float:
    """Fraction of rows whose CSLS-argmax is the correct (diagonal) match."""
    s = csls(a, b, k)
    return float(jnp.mean(jnp.argmax(s, axis=1) == jnp.arange(s.shape[0])))


def procrustes(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Orthogonal R minimizing ||a·R − b||_F (MUSE refinement step).

    Used HOST-LOCALLY on (DP-released G(X), host's own Y): post-processing a
    differentially-private output together with data the processor already
    owns, so it does not change the (ε, δ) guarantee of the release.
    """
    m = a.T @ b
    u, _, vt = jnp.linalg.svd(m, full_matrices=False)
    return u @ vt


class AlignmentRegistry:
    """Pairwise aligned entity/relation local-index maps between KGs."""

    def __init__(self):
        self._ent: Dict[Tuple[str, str], Tuple[np.ndarray, np.ndarray]] = {}
        self._rel: Dict[Tuple[str, str], Tuple[np.ndarray, np.ndarray]] = {}

    @staticmethod
    def from_kgs(kgs: Dict[str, "object"]) -> "AlignmentRegistry":
        reg = AlignmentRegistry()
        names = list(kgs)
        for i, a in enumerate(names):
            for b in names[i + 1 :]:
                ia, ib = kgs[a].aligned_with(kgs[b])
                if len(ia):
                    reg.add_entities(a, b, ia, ib)
        return reg

    def add_entities(self, a: str, b: str, idx_a, idx_b):
        self._ent[(a, b)] = (np.asarray(idx_a), np.asarray(idx_b))
        self._ent[(b, a)] = (np.asarray(idx_b), np.asarray(idx_a))

    def add_relations(self, a: str, b: str, idx_a, idx_b):
        self._rel[(a, b)] = (np.asarray(idx_a), np.asarray(idx_b))
        self._rel[(b, a)] = (np.asarray(idx_b), np.asarray(idx_a))

    def entities(self, a: str, b: str):
        return self._ent.get((a, b))

    def relations(self, a: str, b: str):
        return self._rel.get((a, b))

    def partners(self, a: str) -> List[str]:
        return sorted({b for (x, b) in self._ent if x == a})

    def num_aligned(self, a: str, b: str) -> int:
        ent = self._ent.get((a, b))
        rel = self._rel.get((a, b))
        return (len(ent[0]) if ent else 0) + (len(rel[0]) if rel else 0)
