"""Sharding-aware npz checkpointing (no external deps).

Pytrees are flattened to path-keyed arrays; on restore the tree is rebuilt
and (optionally) device_put with the caller's shardings. Metadata (step,
config hash) rides along as a JSON sidecar entry.

``save_scheduler`` / ``restore_scheduler`` extend this to crash-consistent
federation resume: everything the scheduler's decisions depend on — queues,
node states, the tick counter, best scores, every RNG stream (the
scheduler's PPAT key, each trainer's engine key and numpy generator), the
moments accountant, retry/backoff/quarantine bookkeeping, sticky owner
placement, the streaming scheduler's per-owner clocks and view-version
vector, and the accepted embedding tables — round-trips exactly, so a
process killed between ticks (or between streamed passes — passes complete
atomically, so the streaming frontier is empty at every save point)
resumes with bit-identical decisions. Device
residency is deliberately NOT persisted: restored tables land on the
default device and the per-device resident caches repopulate lazily on the
first post-resume tick (visible as ``TickEngine.resident_transfers``
growth).
"""
from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.tree_util import DictKey, SequenceKey


def _key_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(getattr(k, "name", k)))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, *, metadata: Optional[Dict] = None) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(p): np.asarray(v) for p, v in flat}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __metadata__=json.dumps(metadata or {}), **arrays)
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any, *, shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` optionally device_puts each leaf."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__metadata__"]))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, ref in paths:
            key = _key_str(p)
            if key not in z:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = z[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != expected {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta


# ---------------------------------------------------------------------------
# crash-consistent federation scheduler resume
# ---------------------------------------------------------------------------
def _scheduler_tree(sched) -> Dict:
    """The scheduler's array-valued state. One embedding copy per owner: at
    a tick boundary ``trainer.params`` and ``best_snapshot`` are the same
    arrays by construction (accept aliases snapshot=params, reject restores
    params=snapshot), so the accepted snapshot is the canonical table.

    ``adversary`` carries the replay-attack stale-view cache (the only
    adversary state that feeds back into behavior) — empty when no
    adversary is armed, so pre-adversary checkpoints stay byte-compatible."""
    adv = sched._adversary
    return {
        "key": sched._key,
        "trainers": {
            n: {
                "params": dict(sched.best_snapshot[n]),
                "key": sched.trainers[n]._key,
            }
            for n in sched.trainers
        },
        "adversary": adv.stale_arrays() if adv is not None else {},
    }


def save_scheduler(path: str, sched, *, metadata: Optional[Dict] = None) -> None:
    """Checkpoint a ``FederationScheduler`` between ticks (atomic
    tmp+rename, like ``save_checkpoint``). Must be called at a tick
    boundary — mid-tick state (BUSY owners) is not a consistent cut and is
    rejected. All scalar protocol state rides in the JSON sidecar (floats
    round-trip exactly through ``repr``); arrays go path-keyed in the npz."""
    from repro.core.federation import NodeState

    if any(s is NodeState.BUSY for s in sched.state.values()):
        raise ValueError(
            "save_scheduler called mid-tick (BUSY owners); checkpoint only "
            "at tick boundaries"
        )
    if set(sched.best_snapshot) != set(sched.trainers):
        raise ValueError(
            "save_scheduler before initial_training: no accepted snapshots"
        )
    meta = dict(metadata or {})
    meta["scheduler"] = {
        "tick": sched._tick,
        "owners": list(sched.trainers),
        "state": {n: s.value for n, s in sched.state.items()},
        "queue": {n: list(q) for n, q in sched.queue.items()},
        "best_score": {n: float(v) for n, v in sched.best_score.items()},
        "epsilons": [float(e) for e in sched.epsilons],
        "accountant": sched.accountant.state_dict(),
        "retries": [[h, c, a] for (h, c), a in sched._retries.items()],
        "peer_failures": dict(sched._peer_failures),
        "deferred": [[r, h, c] for r, h, c in sched._deferred],
        "quarantine_until": dict(sched._quarantine_until),
        "reputation": {n: float(v) for n, v in sched._reputation.items()},
        "adversary_stale": {
            key: {leaf: list(a.shape) for leaf, a in leaves.items()}
            for key, leaves in (
                sched._adversary.stale_arrays() if sched._adversary is not None
                else {}
            ).items()
        },
        "placement": sched._tick_engine.placement.assignments(),
        "rng": {
            n: tr.rng.bit_generator.state for n, tr in sched.trainers.items()
        },
        # streaming-scheduler state: per-owner logical clocks, the
        # view-version vector the bounded-staleness gate compares against,
        # and the simulated-time accounting (floats round-trip exactly
        # through JSON repr). The streaming frontier itself is ALWAYS empty
        # at a save point — passes complete atomically and the BUSY guard
        # above forbids mid-pass cuts — so cross-pass re-offers live in the
        # ordinary queue/deferred state already serialized.
        "stream": {
            "owner_clock": {
                n: int(v) for n, v in sched._owner_clock.items()
            },
            "view_version": {
                n: int(v) for n, v in sched._view_version.items()
            },
            "owner_free": {
                n: float(v) for n, v in sched._owner_free.items()
            },
            "publish_sim": {
                n: float(v) for n, v in sched._publish_sim.items()
            },
        },
    }
    save_checkpoint(path, _scheduler_tree(sched), metadata=meta)


def restore_scheduler(path: str, sched) -> Dict:
    """Restore a ``FederationScheduler`` (built over the same universe with
    the same configuration) to a checkpointed tick boundary; returns the
    user metadata. The resumed scheduler makes bit-identical decisions to
    the uninterrupted run: every queue/state/score/RNG/accountant stream is
    reloaded exactly. Device caches are rebuilt lazily — restored tables
    land on the default device and migrate to their owners' sticky homes on
    the first post-resume tick."""
    from collections import deque

    from repro.core.federation import NodeState

    like = {
        "key": sched._key,
        "trainers": {
            n: {"params": dict(tr.params), "key": tr._key}
            for n, tr in sched.trainers.items()
        },
    }
    # peek the sidecar first: the stale-view subtree's shapes are data-
    # dependent (old checkpoints predate the key entirely)
    with np.load(path, allow_pickle=False) as z:
        sd0 = json.loads(str(z["__metadata__"])).get("scheduler", {})
    stale_shapes = sd0.get("adversary_stale", {})
    if stale_shapes:
        like["adversary"] = {
            key: {
                leaf: jax.ShapeDtypeStruct(tuple(shape), jnp.float32)
                for leaf, shape in leaves.items()
            }
            for key, leaves in stale_shapes.items()
        }
    tree, meta = load_checkpoint(path, like)
    sd = meta.get("scheduler")
    if sd is None:
        raise ValueError(f"{path!r} is not a scheduler checkpoint")
    if set(sd["owners"]) != set(sched.trainers):
        raise ValueError(
            f"checkpoint owners {sorted(sd['owners'])} != scheduler owners "
            f"{sorted(sched.trainers)}"
        )
    tree = jax.tree.map(jnp.asarray, tree)
    sched._key = tree["key"]
    for n, tr in sched.trainers.items():
        t = tree["trainers"][n]
        tr.params = dict(t["params"])
        sched.best_snapshot[n] = dict(t["params"])  # alias, like a live accept
        tr._key = t["key"]
        tr.rng.bit_generator.state = sd["rng"][n]
        tr._tri_cache = None  # device-resident store rebuilds lazily
    sched._tick = int(sd["tick"])
    sched.state = {n: NodeState(v) for n, v in sd["state"].items()}
    sched.queue = {n: deque(v) for n, v in sd["queue"].items()}
    sched._queued = {n: set(v) for n, v in sd["queue"].items()}
    sched.best_score = {n: float(v) for n, v in sd["best_score"].items()}
    sched.epsilons = [float(e) for e in sd["epsilons"]]
    sched.accountant.load_state_dict(sd["accountant"])
    sched._retries = {(h, c): int(a) for h, c, a in sd["retries"]}
    sched._peer_failures = {k: int(v) for k, v in sd["peer_failures"].items()}
    sched._deferred = [(int(r), h, c) for r, h, c in sd["deferred"]]
    sched._quarantine_until = {
        k: int(v) for k, v in sd["quarantine_until"].items()
    }
    # continuous reputation (absent in pre-defense checkpoints → pristine)
    sched._reputation = {
        k: float(v) for k, v in sd.get("reputation", {}).items()
    }
    # streaming-scheduler state (absent in pre-stream checkpoints → fresh
    # clocks, which matches those checkpoints' barrier-only history)
    st = sd.get("stream", {})
    sched._owner_clock = {
        k: int(v) for k, v in st.get("owner_clock", {}).items()
    }
    sched._view_version = {
        k: int(v) for k, v in st.get("view_version", {}).items()
    }
    sched._owner_free = {
        k: float(v) for k, v in st.get("owner_free", {}).items()
    }
    sched._publish_sim = {
        k: float(v) for k, v in st.get("publish_sim", {}).items()
    }
    for owner, version in sched._view_version.items():
        sched._tick_engine.placement.note_version(owner, version)
    # replay-attack stale-view cache: resumed storms must re-ship the SAME
    # stale views the interrupted run cached
    if stale_shapes:
        adv = sched._adversary_for(None)
        if adv is None:
            raise ValueError(
                "checkpoint carries adversary replay state but no "
                "tick_adversary is configured on the restoring scheduler"
            )
        adv.load_stale(tree["adversary"])
    sched._tick_engine.placement.restore_assignments(sd["placement"])
    return {k: v for k, v in meta.items() if k != "scheduler"}
