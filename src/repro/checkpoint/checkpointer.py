"""Sharding-aware npz checkpointing (no external deps).

Pytrees are flattened to path-keyed arrays; on restore the tree is rebuilt
and (optionally) device_put with the caller's shardings. Metadata (step,
config hash) rides along as a JSON sidecar entry.
"""
from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.tree_util import DictKey, SequenceKey


def _key_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(getattr(k, "name", k)))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, *, metadata: Optional[Dict] = None) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_key_str(p): np.asarray(v) for p, v in flat}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __metadata__=json.dumps(metadata or {}), **arrays)
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any, *, shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` optionally device_puts each leaf."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__metadata__"]))
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for p, ref in paths:
            key = _key_str(p)
            if key not in z:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = z[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"{key}: shape {arr.shape} != expected {ref.shape}")
            leaves.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta
