from repro.checkpoint.checkpointer import (  # noqa: F401
    load_checkpoint,
    restore_scheduler,
    save_checkpoint,
    save_scheduler,
)
