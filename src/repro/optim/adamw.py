"""AdamW with fp32 moments over (possibly bf16) parameters.

Plain-pytree implementation — no optax dependency. Moments live in fp32 and
shard identically to their parameters (the dry-run relies on this: optimizer
state dominates per-device bytes for the large cards).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: dict
    nu: dict


def adamw_init(params, *, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype=jnp.bfloat16`` halves optimizer HBM (§Perf iteration on
    the 1T-param card); fp32 is the default for exactness."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 0.0,
):
    step = state.step + 1
    if grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        mdt = m.dtype
        g = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1**step.astype(jnp.float32))
        vhat = v / (1 - b2**step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m.astype(mdt), v.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
