"""Evaluation: triple classification and (filtered) link prediction.

Triple classification (§4.1.3): corrupt each valid/test triple 1:1; learn a
global score threshold on the valid set; report accuracy on test.

Link prediction: for each test triple rank the true tail (and head) against
all entities, removing other true triples in Filter mode; report Mean Rank and
Hit@1/3/10 — the metrics of Tab. 4 / Tab. 6.

The default path is the **streaming fused-rank engine**: known-true entities
are packed once into padded CSR-style index tensors, queries are decomposed
into (query vector, entity table, mode) via ``lp_query_*``, and per-query
filtered rank counts come back from ``kernels.triple_score.fused_ranks`` —
tile-accumulated on device, so the (B, E) score matrix never materializes on
host and there is no per-triple Python ranking loop. Families without a
query/table decomposition stream through ``score_triples`` one entity block
at a time (same memory bound, generic math). ``engine="reference"`` keeps the
seed implementation for parity testing.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kge.data import corrupt_triples
from repro.kge.models import (
    KGEModel,
    lp_gold_scores,
    lp_query_heads,
    lp_query_tails,
    score_all_heads,
    score_all_tails,
    score_triples,
)


def best_threshold_accuracy(
    pos: np.ndarray, neg: np.ndarray, *, max_candidates: int = 512
) -> Tuple[float, float]:
    """(threshold, accuracy) maximizing ((pos ≥ thr) + (neg < thr)) / 2 over
    candidate thresholds — one broadcasted (C, N) comparison, no Python loop."""
    cand = np.unique(np.concatenate([pos, neg]))
    if len(cand) > max_candidates:
        cand = cand[:: len(cand) // max_candidates]
    acc = (
        (pos[None, :] >= cand[:, None]).mean(axis=1)
        + (neg[None, :] < cand[:, None]).mean(axis=1)
    ) / 2.0
    best = int(np.argmax(acc))
    return float(cand[best]), float(acc[best])


def triple_classification_accuracy(
    params, model: KGEModel, kg, *, seed: int = 0
) -> float:
    rng = np.random.default_rng(seed)
    va, te = kg.valid, kg.test
    va_neg = corrupt_triples(rng, va, kg.num_entities)
    te_neg = corrupt_triples(rng, te, kg.num_entities)

    def scores(t):
        t = jnp.asarray(t)
        return np.asarray(score_triples(params, model, t[:, 0], t[:, 1], t[:, 2]))

    sv_pos, sv_neg = scores(va), scores(va_neg)
    thr, _ = best_threshold_accuracy(sv_pos, sv_neg)
    st_pos, st_neg = scores(te), scores(te_neg)
    return float(((st_pos >= thr).mean() + (st_neg < thr).mean()) / 2.0)


# ---------------------------------------------------------------------------
# filter construction: padded CSR-style known-true index tensors
# ---------------------------------------------------------------------------
def _filter_mask(all_triples: np.ndarray, num_entities: int):
    """Dicts mapping (h, r) → {t} and (r, t) → {h} for Filter mode."""
    hr_t: Dict[Tuple[int, int], set] = {}
    rt_h: Dict[Tuple[int, int], set] = {}
    for h, r, t in all_triples:
        hr_t.setdefault((int(h), int(r)), set()).add(int(t))
        rt_h.setdefault((int(r), int(t)), set()).add(int(h))
    return hr_t, rt_h


def pack_padded_filters(rows, *, width: Optional[int] = None) -> np.ndarray:
    """Pack variable-length known-true id lists into one padded (N, W) int32
    array (pad −1, W ≥ 1). ``width`` pins W (e.g. a pow-2 bucket so downstream
    jits see a fixed filter shape); rows longer than ``width`` are an error
    rather than a silent truncation — a dropped filter id would silently
    stop excluding a known-true entity."""
    rows = [np.asarray(x, np.int64).reshape(-1) for x in rows]
    w = max(1, max((len(x) for x in rows), default=1))
    if width is not None:
        if w > width:
            raise ValueError(f"filter row of {w} ids exceeds width {width}")
        w = max(1, width)
    out = np.full((len(rows), w), -1, np.int32)
    for i, x in enumerate(rows):
        out[i, : len(x)] = x
    return out


def build_filter_arrays(
    test: np.ndarray, all_triples: Optional[np.ndarray], *, filtered: bool
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack per-query known-true entity ids into padded (B, F) int32 arrays
    (pad −1), computed ONCE per evaluation — the engine applies them in-kernel.

    The gold entity is always row member #0 (also in raw mode): excluding it
    from the count is a no-op on exact scores (gold is never > itself) and
    makes the rank invariant to gather-vs-tile fp noise on the gold score.
    """
    b = len(test)
    if not filtered:
        filt_t = np.full((b, 1), -1, np.int64)
        filt_h = np.full((b, 1), -1, np.int64)
        filt_t[:, 0] = test[:, 2]
        filt_h[:, 0] = test[:, 0]
        return filt_t.astype(np.int32), filt_h.astype(np.int32)

    hr_t, rt_h = _filter_mask(all_triples, 0)
    tails = [sorted(hr_t[(int(h), int(r))]) for h, r, _ in test]
    heads = [sorted(rt_h[(int(r), int(t))]) for _, r, t in test]
    return pack_padded_filters(tails), pack_padded_filters(heads)


def build_score_inputs(
    kg, *, split: str = "test", max_test: int = 2000, filtered: bool = True
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(test, filt_t, filt_h) for ``link_prediction(..., precomputed=...)``.

    ``kg.train/valid/test`` are immutable, so these arrays are too — build
    them once per (kg, split, max_test) and reuse across evaluations. The
    federation scheduler caches them per owner: rebuilding the CSR filters is
    a Python pass over every triple, and letting the filter width float per
    call also retraced the rank kernels every tick.
    """
    test = np.asarray(getattr(kg, split))[:max_test]
    all_triples = (
        np.concatenate([kg.train, kg.valid, kg.test]) if filtered else None
    )
    filt_t, filt_h = build_filter_arrays(test, all_triples, filtered=filtered)
    return test, filt_t, filt_h


# ---------------------------------------------------------------------------
# streaming rank engine
# ---------------------------------------------------------------------------
def generic_counts_graph(
    params, model: KGEModel, fixed_a, fixed_b, gold, filt, *, side: str, block_e: int
):
    """Rank counts via blockwise ``score_triples`` for non-decomposable
    families: streams entity blocks (never materializes (B, E)); ``side`` is
    "tail" (fixed h, r) or "head" (fixed r, t)."""
    b = fixed_a.shape[0]
    e = model.num_entities
    be = min(block_e, e)
    n_blocks = -(-e // be)
    cols = jnp.arange(n_blocks * be, dtype=jnp.int32).reshape(n_blocks, be)
    gold = gold.astype(jnp.float32)[:, None]

    def step(acc, cb):
        ids = jnp.clip(cb, 0, e - 1)  # (Be,)
        aa = jnp.repeat(fixed_a[:, None], be, axis=1).reshape(-1)
        bb = jnp.repeat(fixed_b[:, None], be, axis=1).reshape(-1)
        cc = jnp.tile(ids[None], (b, 1)).reshape(-1)
        if side == "tail":
            s = score_triples(params, model, aa, bb, cc)
        else:
            s = score_triples(params, model, cc, aa, bb)
        s = s.reshape(b, be)
        excl = jnp.any(filt[:, :, None] == cb[None, None, :], axis=1)
        beats = (s > gold) & (cb < e)[None, :] & jnp.logical_not(excl)
        return acc + jnp.sum(beats.astype(jnp.int32), axis=1), None

    counts, _ = jax.lax.scan(step, jnp.zeros((b,), jnp.int32), cols)
    return counts


def side_counts_graph(
    params,
    model: KGEModel,
    h: jnp.ndarray,
    r: jnp.ndarray,
    t: jnp.ndarray,
    filt: jnp.ndarray,
    *,
    side: str,
    block_e: int = 512,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    """``streaming_side_counts`` as a pure graph (device in, device out, no
    jit boundary, no host sync) — the exact per-side count math, for callers
    that embed scoring inside a larger compiled program (the federation tick
    engine batches every owner's backtrack scoring into one tick dispatch
    through this)."""
    from repro.kernels.triple_score import fused_ranks_graph

    qd = (
        lp_query_tails(params, model, h, r)
        if side == "tail"
        else lp_query_heads(params, model, r, t)
    )
    if qd is not None:
        q, table, mode = qd
        gold = lp_gold_scores(q, table, t if side == "tail" else h, mode)
        return fused_ranks_graph(q, table, gold, filt, mode=mode,
                                 block_e=block_e, impl=impl)
    gold = score_triples(params, model, h, r, t)
    fixed = (h, r) if side == "tail" else (r, t)
    return generic_counts_graph(
        params, model, *fixed, gold, filt, side=side, block_e=block_e
    )


_side_counts_jit = functools.partial(
    jax.jit, static_argnames=("model", "side", "block_e", "impl")
)(side_counts_graph)


def streaming_side_counts(
    params,
    model: KGEModel,
    chunk: np.ndarray,   # (B, 3) test triples
    filt: np.ndarray,    # (B, F) known-true ids for this side (pad −1)
    *,
    side: str,           # "tail" | "head"
    block_e: int = 512,
    impl: Optional[str] = None,
) -> np.ndarray:
    """Filtered rank counts for ONE corruption side — the engine core.

    One jitted call of the SAME ``side_counts_graph`` the federation tick
    engine embeds in its tick programs: one copy of the decomposition /
    gold-score / fallback selection, and no eager query-building dispatches.
    The implementation is resolved here (host-side) so the env overrides
    keep taking effect per call.

    ``chunk``/``filt`` may be host numpy (uploaded once per call — one
    transfer for the whole chunk, not one per column) or device arrays
    (e.g. the federation scheduler's owner-resident scoring caches — zero
    per-call uploads); with params committed to an owner's home device the
    whole rank computation runs there.
    """
    from repro.kernels.dispatch import resolve_rank_impl

    tri = jnp.asarray(chunk)
    counts = _side_counts_jit(
        params, model,
        tri[:, 0], tri[:, 1], tri[:, 2], jnp.asarray(filt),
        side=side, block_e=block_e, impl=resolve_rank_impl(impl),
    )
    return np.asarray(counts)


def side_counts_dispatch(
    params,
    model: KGEModel,
    h: jnp.ndarray,
    r: jnp.ndarray,
    t: jnp.ndarray,
    filt: jnp.ndarray,
    *,
    side: str,
    block_e: int = 512,
    impl: Optional[str] = None,
) -> jnp.ndarray:
    """One ASYNC jitted dispatch of the side-count engine: device arrays in,
    device array out, no host sync — the serving tier's batch call. Identical
    math to ``streaming_side_counts`` (same jit, same impl resolution); the
    caller materializes the result when it chooses (``jax.Array.is_ready``
    polling lets batches complete out of band while new ones dispatch)."""
    from repro.kernels.dispatch import resolve_rank_impl

    return _side_counts_jit(
        params, model, h, r, t, filt,
        side=side, block_e=block_e, impl=resolve_rank_impl(impl),
    )


def streaming_rank_counts(
    params,
    model: KGEModel,
    chunk: np.ndarray,      # (B, 3) test triples
    filt_t: np.ndarray,     # (B, Ft) known-true tails (pad −1)
    filt_h: np.ndarray,     # (B, Fh) known-true heads (pad −1)
    *,
    block_e: int = 512,
    impl: Optional[str] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Filtered rank counts (tail, head) for one chunk."""
    kw = dict(block_e=block_e, impl=impl)
    return (
        streaming_side_counts(params, model, chunk, filt_t, side="tail", **kw),
        streaming_side_counts(params, model, chunk, filt_h, side="head", **kw),
    )


def _metrics(ranks: np.ndarray) -> Dict[str, float]:
    ranks = ranks.astype(np.float64)
    return {
        "mean_rank": float(ranks.mean()),
        "hit@1": float((ranks <= 1).mean()),
        "hit@3": float((ranks <= 3).mean()),
        "hit@10": float((ranks <= 10).mean()),
    }


def link_prediction(
    params,
    model: KGEModel,
    kg,
    *,
    filtered: bool = True,
    max_test: int = 2000,
    batch: int = 128,
    split: str = "test",
    engine: str = "auto",
    block_e: int = 512,
    impl: Optional[str] = None,
    precomputed: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None,
) -> Dict[str, float]:
    """Filtered/raw link prediction. ``engine``: "auto" | "fused" | "reference".

    "fused"/"auto" run the streaming rank engine (device-side accumulation, no
    (B, E) on host); "reference" is the seed per-triple numpy path, kept as
    the parity oracle. ``precomputed`` takes a cached
    ``build_score_inputs(...)`` triple and skips the per-call test-slice and
    filter construction (the split arrays are immutable, so callers that
    evaluate repeatedly — the federation backtrack — build them once).
    """
    if engine not in ("auto", "fused", "reference"):
        raise ValueError(f"unknown engine {engine!r} (auto|fused|reference)")
    if precomputed is not None and engine != "reference":
        test, filt_t, filt_h = precomputed
    else:
        test = np.asarray(getattr(kg, split))[:max_test]
        all_triples = (
            np.concatenate([kg.train, kg.valid, kg.test]) if filtered else None
        )
        if engine == "reference":
            return _link_prediction_reference(
                params, model, kg, test, all_triples,
                filtered=filtered, batch=batch,
            )
        filt_t, filt_h = build_filter_arrays(test, all_triples, filtered=filtered)
    ranks = np.empty(2 * len(test), dtype=np.int64)
    for i in range(0, len(test), batch):
        chunk = test[i : i + batch]
        c_tail, c_head = streaming_rank_counts(
            params, model, chunk, filt_t[i : i + batch], filt_h[i : i + batch],
            block_e=block_e, impl=impl,
        )
        # same interleaving as the seed loop: tail rank, then head rank
        ranks[2 * i : 2 * (i + len(chunk)) : 2] = c_tail + 1
        ranks[2 * i + 1 : 2 * (i + len(chunk)) : 2] = c_head + 1
    return _metrics(ranks)


def _link_prediction_reference(
    params, model: KGEModel, kg, test, all_triples, *, filtered: bool, batch: int
) -> Dict[str, float]:
    """Seed implementation: host-side (B, E) matrices + per-triple ranking."""
    hr_t, rt_h = _filter_mask(all_triples, kg.num_entities) if filtered else ({}, {})

    ranks = []
    for i in range(0, len(test), batch):
        chunk = test[i : i + batch]
        h = jnp.asarray(chunk[:, 0])
        r = jnp.asarray(chunk[:, 1])
        t = jnp.asarray(chunk[:, 2])
        s_tail = np.asarray(
            score_all_tails(params, model, h, r, via_kernel=False)
        )  # (B, E)
        s_head = np.asarray(score_all_heads(params, model, r, t, via_kernel=False))
        for j, (hh, rr, tt) in enumerate(chunk):
            row = s_tail[j].copy()
            if filtered:
                for other_t in hr_t.get((int(hh), int(rr)), ()):
                    if other_t != int(tt):
                        row[other_t] = -np.inf
            ranks.append(1 + int((row > row[int(tt)]).sum()))
            row = s_head[j].copy()
            if filtered:
                for other_h in rt_h.get((int(rr), int(tt)), ()):
                    if other_h != int(hh):
                        row[other_h] = -np.inf
            ranks.append(1 + int((row > row[int(hh)]).sum()))
    return _metrics(np.array(ranks))
