"""Evaluation: triple classification and (filtered) link prediction.

Triple classification (§4.1.3): corrupt each valid/test triple 1:1; learn a
global score threshold on the valid set; report accuracy on test.

Link prediction: for each test triple rank the true tail (and head) against
all entities, removing other true triples in Filter mode; report Mean Rank and
Hit@1/3/10 — the metrics of Tab. 4 / Tab. 6.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kge.data import corrupt_triples
from repro.kge.models import (
    KGEModel,
    score_all_heads,
    score_all_tails,
    score_triples,
)


def triple_classification_accuracy(
    params, model: KGEModel, kg, *, seed: int = 0
) -> float:
    rng = np.random.default_rng(seed)
    va, te = kg.valid, kg.test
    va_neg = corrupt_triples(rng, va, kg.num_entities)
    te_neg = corrupt_triples(rng, te, kg.num_entities)

    def scores(t):
        t = jnp.asarray(t)
        return np.asarray(score_triples(params, model, t[:, 0], t[:, 1], t[:, 2]))

    sv_pos, sv_neg = scores(va), scores(va_neg)
    # threshold maximizing valid accuracy (scan candidate thresholds)
    cand = np.unique(np.concatenate([sv_pos, sv_neg]))
    if len(cand) > 512:
        cand = cand[:: len(cand) // 512]
    acc = [
        ((sv_pos >= c).mean() + (sv_neg < c).mean()) / 2.0 for c in cand
    ]
    thr = cand[int(np.argmax(acc))]
    st_pos, st_neg = scores(te), scores(te_neg)
    return float(((st_pos >= thr).mean() + (st_neg < thr).mean()) / 2.0)


def _filter_mask(all_triples: np.ndarray, num_entities: int):
    """Dicts mapping (h, r) → {t} and (r, t) → {h} for Filter mode."""
    hr_t: Dict[Tuple[int, int], set] = {}
    rt_h: Dict[Tuple[int, int], set] = {}
    for h, r, t in all_triples:
        hr_t.setdefault((int(h), int(r)), set()).add(int(t))
        rt_h.setdefault((int(r), int(t)), set()).add(int(h))
    return hr_t, rt_h


def link_prediction(
    params,
    model: KGEModel,
    kg,
    *,
    filtered: bool = True,
    max_test: int = 2000,
    batch: int = 128,
) -> Dict[str, float]:
    test = kg.test[:max_test]
    all_triples = np.concatenate([kg.train, kg.valid, kg.test])
    hr_t, rt_h = _filter_mask(all_triples, kg.num_entities) if filtered else ({}, {})

    ranks = []
    for i in range(0, len(test), batch):
        chunk = test[i : i + batch]
        h = jnp.asarray(chunk[:, 0])
        r = jnp.asarray(chunk[:, 1])
        t = jnp.asarray(chunk[:, 2])
        s_tail = np.asarray(score_all_tails(params, model, h, r))  # (B, E)
        s_head = np.asarray(score_all_heads(params, model, r, t))
        for j, (hh, rr, tt) in enumerate(chunk):
            row = s_tail[j].copy()
            if filtered:
                for other_t in hr_t.get((int(hh), int(rr)), ()):
                    if other_t != int(tt):
                        row[other_t] = -np.inf
            ranks.append(1 + int((row > row[int(tt)]).sum()))
            row = s_head[j].copy()
            if filtered:
                for other_h in rt_h.get((int(rr), int(tt)), ()):
                    if other_h != int(hh):
                        row[other_h] = -np.inf
            ranks.append(1 + int((row > row[int(hh)]).sum()))
    ranks = np.array(ranks, dtype=np.float64)
    return {
        "mean_rank": float(ranks.mean()),
        "hit@1": float((ranks <= 1).mean()),
        "hit@3": float((ranks <= 3).mean()),
        "hit@10": float((ranks <= 10).mean()),
    }
