"""Device-resident federated training engine.

The seed trainer round-trips to host every epoch (numpy permutation + numpy
negative sampling + one ``_epoch`` dispatch), and every minibatch applies a
dense ``p − lr·g`` update to the full (E, d) entity table: O(E·d) work where
O(B·d) is needed. This engine compiles ONE ``lax.scan`` over all
epochs × minibatches:

  * **on-device sampling** — per-epoch permutation and 1:1 head/tail
    corruption via ``jax.random``; no per-epoch H2D transfer, and the
    corruption bound is a *traced* scalar, so virtual entities are sampled as
    negatives without retracing;
  * **sparse updates** — each step gathers the ≤3B touched entity rows (and
    ≤B relation rows), differentiates w.r.t. the gathered slice only, and
    scatter-adds the update back. Duplicate rows within a batch compose
    exactly once via the unique-index inverse (the gather backward IS the
    segment-sum over occurrences), which makes the step bit-identical to the
    dense reference;
  * **bucket padding** — embedding tables round up to
    ``ENT_BUCKET``/``REL_BUCKET`` multiples and triple stores to a
    power-of-two minibatch count, so consecutive
    ``federate_once`` handshakes with different virtual-extension sizes reuse
    the compiled scan instead of retracing. Triples are padded by *cycling*
    real triples (every padded row is a valid triple); table padding rows are
    zeros, are never referenced by any triple, and are never sampled as
    negatives (the corruption bound is the true count).

Step implementations (``kernels.dispatch.resolve_train_impl``):
``pallas`` — the fused gather→score→scatter ``sparse_update`` kernel
(TransE/DistMult); ``xla`` — the autodiff sparse step (all families);
``reference`` — the seed dense host-loop path in ``trainer._epoch``.

Device residency: every entry point accepts committed (owner-resident)
tables — after owner-sticky federation ticks a trainer's params live on its
home device, the jitted scan follows them there, and ``pad_tables`` /
``strip_tables`` / ``pad_triples`` preserve the commitment (the trainer
additionally co-locates its padded-triple cache, see
``KGETrainer._padded_triples``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kge.models import (
    KGEModel,
    margin_loss,
    normalize_entities,
    score_triples,
)

#: bucket granularities for the retrace-free shapes
ENT_BUCKET = 256
REL_BUCKET = 64

#: param keys indexed by entity id; everything else is relation-indexed
ENT_KEYS = ("ent", "ent_p", "ent_im")


def bucket(n: int, granularity: int) -> int:
    """Round ``n`` up to the next multiple of ``granularity`` (min 1 bucket)."""
    return max(granularity, -(-n // granularity) * granularity)


def shape_spec(model: KGEModel) -> KGEModel:
    """A hashable, count-free model key for jit static args: the same spec is
    shared by every bucket-padded table size, so handshakes that grow the
    entity/relation counts do not retrace on the model argument."""
    return dataclasses.replace(model, num_entities=0, num_relations=0)


# ---------------------------------------------------------------------------
# sparse SGD step (xla impl) — autodiff w.r.t. the gathered slice only
# ---------------------------------------------------------------------------
def sparse_sgd_step(
    params: Dict[str, jnp.ndarray],
    spec: KGEModel,
    pos: jnp.ndarray,  # (B, 3) int32
    neg: jnp.ndarray,  # (B, 3) int32
    lr,
    *,
    unique_e: int | None = None,
    unique_r: int | None = None,
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """One margin-SGD step touching only the rows named by the minibatch.

    ``unique_e``/``unique_r`` cap the unique-row sets (static, for jit):
    3B/B when ``neg`` is a 1:1 corruption of ``pos`` (the scan path), 4B/2B
    for arbitrary batches. Bit-identical to the dense reference step: the
    forward gathers the same row values, the gather backward segment-sums
    per-occurrence cotangents exactly as the dense scatter does, and
    ``row.at[].add(−lr·g)`` matches ``row − lr·g`` in IEEE arithmetic.
    """
    b = pos.shape[0]
    unique_e = 4 * b if unique_e is None else unique_e
    unique_r = 2 * b if unique_r is None else unique_r
    e_occ = jnp.concatenate([pos[:, 0], pos[:, 2], neg[:, 0], neg[:, 2]])
    r_occ = jnp.concatenate([pos[:, 1], neg[:, 1]])
    # fill slots index one row past the table: gathers clamp (harmless, no
    # occurrence maps to them) and scatters drop (no write at all).
    ue, inv_e = jnp.unique(
        e_occ, return_inverse=True, size=unique_e,
        fill_value=params["ent"].shape[0],
    )
    ur, inv_r = jnp.unique(
        r_occ, return_inverse=True, size=unique_r,
        fill_value=params["rel"].shape[0],
    )
    local = {k: params[k][ue if k in ENT_KEYS else ur] for k in params}
    lh, lt = inv_e[:b], inv_e[b : 2 * b]
    lnh, lnt = inv_e[2 * b : 3 * b], inv_e[3 * b :]
    lrel, lnrel = inv_r[:b], inv_r[b:]

    def loss_fn(lp):
        sp = score_triples(lp, spec, lh, lrel, lt)
        sn = score_triples(lp, spec, lnh, lnrel, lnt)
        return margin_loss(sp, sn, spec.margin)

    loss, g = jax.value_and_grad(loss_fn)(local)
    new = {
        k: params[k].at[ue if k in ENT_KEYS else ur].add(-lr * g[k], mode="drop")
        for k in params
    }
    return new, loss


@functools.partial(jax.jit, static_argnames=("spec",))
def sparse_epoch(params, spec: KGEModel, pos, neg, lr):
    """One epoch of sparse steps over pre-built (nb, B, 3) batches — the
    drop-in parity twin of the dense ``trainer._epoch`` (same scan structure,
    same per-epoch normalization, bit-identical trajectory)."""

    def step(p, sl):
        bp, bn = sl
        return sparse_sgd_step(p, spec, bp, bn, lr)

    params, losses = jax.lax.scan(step, params, (pos, neg))
    return normalize_entities(params), jnp.mean(losses)


def _pallas_step(params, spec, pos, neg, lr, *, interpret):
    """Fused-kernel step for the {ent, rel}-only families."""
    from repro.kernels.sparse_update import fused_sparse_step

    mode = "dot" if spec.family == "distmult" else (
        "l2" if spec.norm_ord == 2 else "l1"
    )
    b = pos.shape[0]
    ent, rel, loss = fused_sparse_step(
        params["ent"], params["rel"], pos, neg, lr,
        mode=mode, margin=spec.margin, interpret=interpret,
        unique_e=3 * b, unique_r=b,  # 1:1 corruption shares side + relation
    )
    return {"ent": ent, "rel": rel}, loss


# ---------------------------------------------------------------------------
# the multi-epoch device scan
# ---------------------------------------------------------------------------
def _renorm_rows(
    params: Dict[str, jnp.ndarray], ids: jnp.ndarray, skip: jnp.ndarray
) -> Dict[str, jnp.ndarray]:
    """Project only the entity rows named by ``ids`` onto the unit ball —
    the sparse twin of ``normalize_entities`` (which maps every row).
    Duplicate ids scatter the same value, so the write is deterministic;
    ``skip`` (traced bool) selects the identity instead (epoch 0 must read
    raw rows, exactly like the dense schedule)."""
    rows = params["ent"][ids]
    n = jnp.linalg.norm(rows, axis=-1, keepdims=True)
    projected = rows / jnp.maximum(n, 1.0)
    new_rows = jnp.where(skip, rows, projected)
    out = dict(params)
    out["ent"] = params["ent"].at[ids].set(new_rows)
    return out


def train_scan_graph(
    params: Dict[str, jnp.ndarray],
    triples: jnp.ndarray,       # (N_pad, 3) int32, N_pad % batch == 0, cycled
    key: jax.Array,
    lr: jnp.ndarray,
    num_entities: jnp.ndarray,  # traced scalar: true (extended) entity count
    *,
    spec: KGEModel,
    epochs: int,
    batch: int,
    impl: str,
    interpret: bool,
    renorm: str = "dense",
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """All epochs × minibatches as one traceable scan → (params, losses).

    This is the pure graph shared by the jitted ``_train_scan`` wrapper and
    the federation tick engine (which embeds one copy per owner inside a
    single batched tick program — the per-owner subgraph is this exact trace,
    which is what keeps batched ticks bit-identical to serial ones).

    ``renorm`` picks the entity-norm projection schedule:

      * ``dense`` — the seed schedule: ``normalize_entities`` over the FULL
        table after every epoch, O(E·d) per epoch.
      * ``sparse`` — project only the rows an epoch is about to gather
        (start-of-epoch, from that epoch's pos/neg ids — they are known
        before the minibatch scan because sampling derives from the epoch
        key), plus ONE full projection after the last epoch, O(4·N_pad·d)
        per epoch + O(E·d) once. A row read at most one epoch after its
        last touch sees exactly the value the dense schedule would show
        it, and the final table is fully projected. The deviation: the
        dense schedule re-projects already-projected rows every epoch and
        x/‖x‖ is not a bit-level fixpoint, so a row untouched for k ≥ 2
        epochs accumulates up to k−1 extra 1-ulp projections under dense
        that the single sparse projection skips. With epochs=1, or when
        every entity is touched every epoch, the two schedules are
        bit-identical (pinned in tests); in general they agree to fp
        tolerance.
    """
    n_pad = triples.shape[0]
    nb = n_pad // batch

    def step(p, sl):
        bp, bn = sl
        if impl == "pallas":
            return _pallas_step(p, spec, bp, bn, lr, interpret=interpret)
        return sparse_sgd_step(
            p, spec, bp, bn, lr, unique_e=3 * batch, unique_r=batch
        )

    def epoch_body(p, einp):
        eidx, ekey = einp
        kp, kc, ks = jax.random.split(ekey, 3)
        perm = jax.random.permutation(kp, n_pad)
        pos = triples[perm].reshape(nb, batch, 3)
        # 1:1 corruption against the TRUE entity count (virtual rows included,
        # bucket-padding rows excluded) — a traced bound, so no retrace.
        corrupt_head = jax.random.bernoulli(kc, 0.5, (nb, batch))
        rand_ent = jax.random.randint(
            ks, (nb, batch), 0, num_entities, dtype=jnp.int32
        )
        neg = jnp.stack(
            [
                jnp.where(corrupt_head, rand_ent, pos[..., 0]),
                pos[..., 1],
                jnp.where(corrupt_head, pos[..., 2], rand_ent),
            ],
            axis=-1,
        )
        if renorm == "sparse":
            touched = jnp.concatenate(
                [pos[..., 0], pos[..., 2], neg[..., 0], neg[..., 2]]
            ).reshape(-1)
            p = _renorm_rows(p, touched, eidx == 0)
        p, losses = jax.lax.scan(step, p, (pos, neg))
        if renorm == "dense":
            p = normalize_entities(p)
        return p, jnp.mean(losses)

    params, losses = jax.lax.scan(
        epoch_body, params,
        (jnp.arange(epochs), jax.random.split(key, epochs)),
    )
    if renorm == "sparse":
        params = normalize_entities(params)
    return params, losses


_train_scan = functools.partial(
    jax.jit,
    static_argnames=("spec", "epochs", "batch", "impl", "interpret", "renorm"),
)(train_scan_graph)


def resolve_renorm(tri_pad: int, ent_rows: int) -> str:
    """Pick the entity-norm projection schedule from static shapes: the
    sparse schedule gathers 4·N_pad rows per epoch, so it only wins when
    that is cheaper than the dense full-table pass."""
    return "sparse" if 4 * tri_pad < ent_rows else "dense"


def pad_tables(
    params: Dict[str, jnp.ndarray], model: KGEModel
) -> Tuple[Dict[str, jnp.ndarray], int, int]:
    """Zero-pad entity/relation tables up to bucket multiples.

    Returns (padded params, e_pad, r_pad). Padding rows are inert: no triple
    references them and the corruption bound keeps them out of negatives;
    ``normalize_entities`` maps zero rows to zero rows.
    """
    e, r = model.num_entities, model.num_relations
    e_pad, r_pad = bucket(e, ENT_BUCKET), bucket(r, REL_BUCKET)
    out = {}
    for k, v in params.items():
        n = e_pad if k in ENT_KEYS else r_pad
        if v.shape[0] < n:
            v = jnp.pad(v, ((0, n - v.shape[0]),) + ((0, 0),) * (v.ndim - 1))
        out[k] = v
    return out, e_pad, r_pad


def strip_tables(
    params: Dict[str, jnp.ndarray], model: KGEModel
) -> Dict[str, jnp.ndarray]:
    """Drop bucket-padding rows, restoring the logical table shapes."""
    e, r = model.num_entities, model.num_relations
    return {k: v[: e if k in ENT_KEYS else r] for k, v in params.items()}


def pad_triples(triples: jnp.ndarray, batch: int) -> jnp.ndarray:
    """Cycle-pad the triple store so the minibatch count is a power of two:
    every padded row is a real triple, so padded epochs train on a slightly
    (< 2×) oversampled store instead of on masked garbage, and the pow2
    batch-count buckets keep consecutive handshakes — whose virtual triples
    shift the store size by a few hundred rows — on the same traced shape."""
    n = triples.shape[0]
    nb = max(1, -(-n // batch))
    n_pad = (1 << (nb - 1).bit_length()) * batch
    if n_pad == n:
        return triples
    reps = jnp.arange(n_pad - n) % n
    return jnp.concatenate([triples, triples[reps]])


def train_epochs_device(
    params: Dict[str, jnp.ndarray],
    model: KGEModel,
    triples,                    # (N, 3) host or device int32
    key: jax.Array,
    *,
    epochs: int,
    batch_size: int,
    lr: float,
    impl: str,
    interpret: bool,
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """Bucket-pad, run the compiled multi-epoch scan, strip padding.

    Returns (new params with logical shapes, per-epoch mean losses).
    """
    tri = jnp.asarray(triples, jnp.int32)
    b = min(batch_size, tri.shape[0])
    tri = pad_triples(tri, b)
    padded, e_pad, _ = pad_tables(params, model)
    padded, losses = _train_scan(
        padded, tri, key, jnp.float32(lr),
        jnp.int32(model.num_entities),
        spec=shape_spec(model), epochs=epochs, batch=b,
        impl=impl, interpret=interpret,
        renorm=resolve_renorm(tri.shape[0], e_pad),
    )
    return strip_tables(padded, model), losses


def train_scan_cache_size() -> int:
    """Number of compiled specializations of the multi-epoch scan — the
    retrace-free federation invariant is asserted against this counter."""
    return _train_scan._cache_size()
