"""Translation-family KGE models: TransE, TransH, TransR, TransD.

Exactly the four base models the paper plugs into FKGE (§4.1.3), plus
DistMult/ComplEx/RotatE as beyond-paper extras. A model is a (params, score)
pair; FKGE only ever touches ``params["ent"]`` / ``params["rel"]`` — that is
what makes it a meta-algorithm.

Score convention: **higher is better** (we negate distances).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

MODEL_FAMILIES = ("transe", "transh", "transr", "transd", "distmult", "complex", "rotate")


@dataclass(frozen=True)
class KGEModel:
    family: str
    num_entities: int
    num_relations: int
    dim: int
    margin: float = 4.0
    norm_ord: int = 1  # L1 per OpenKE default for TransE-family


def _uniform(key, shape, dim):
    bound = 6.0 / math.sqrt(dim)
    return jax.random.uniform(key, shape, jnp.float32, -bound, bound)


def init_kge(key, m: KGEModel) -> Dict[str, jnp.ndarray]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, r, d = m.num_entities, m.num_relations, m.dim
    p = {"ent": _uniform(k1, (e, d), d), "rel": _uniform(k2, (r, d), d)}
    if m.family == "transh":
        w = _uniform(k3, (r, d), d)
        p["norm_vec"] = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-9)
    elif m.family == "transr":
        eye = jnp.eye(d, dtype=jnp.float32)
        p["proj"] = jnp.tile(eye[None], (r, 1, 1)) + 0.01 * _uniform(k3, (r, d, d), d)
    elif m.family == "transd":
        p["ent_p"] = _uniform(k3, (e, d), d)
        p["rel_p"] = _uniform(k4, (r, d), d)
    elif m.family == "complex":
        p["ent_im"] = _uniform(k3, (e, d), d)
        p["rel_im"] = _uniform(k4, (r, d), d)
    elif m.family == "rotate":
        p["rel"] = jax.random.uniform(k2, (r, d // 2), jnp.float32, -math.pi, math.pi)
    return p


def _norm(x, ord_):  # noqa: A002
    if ord_ == 1:
        return jnp.sum(jnp.abs(x), axis=-1)
    return jnp.sqrt(jnp.sum(jnp.square(x), axis=-1) + 1e-12)


def score_triples(
    params: Dict[str, jnp.ndarray],
    m: KGEModel,
    h: jnp.ndarray,
    r: jnp.ndarray,
    t: jnp.ndarray,
    *,
    h_emb=None,
    t_emb=None,
) -> jnp.ndarray:
    """Score a batch of (h, r, t) index triples; higher = more plausible.

    ``h_emb``/``t_emb`` optionally override the gathered entity embeddings —
    used by the PPAT pipeline to score with refined/translated embeddings.
    """
    ent, rel = params["ent"], params["rel"]
    he = ent[h] if h_emb is None else h_emb
    te = ent[t] if t_emb is None else t_emb

    if m.family == "transe":
        re = rel[r]
        return -_norm(he + re - te, m.norm_ord)
    if m.family == "transh":
        re, w = rel[r], params["norm_vec"][r]
        w = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-9)
        hp = he - jnp.sum(w * he, -1, keepdims=True) * w
        tp = te - jnp.sum(w * te, -1, keepdims=True) * w
        return -_norm(hp + re - tp, m.norm_ord)
    if m.family == "transr":
        re, mat = rel[r], params["proj"][r]  # (B,d), (B,d,d)
        hp = jnp.einsum("bd,bde->be", he, mat)
        tp = jnp.einsum("bd,bde->be", te, mat)
        return -_norm(hp + re - tp, m.norm_ord)
    if m.family == "transd":
        re = rel[r]
        hpv, tpv = params["ent_p"][h], params["ent_p"][t]
        rpv = params["rel_p"][r]
        hp = he + jnp.sum(hpv * he, -1, keepdims=True) * rpv
        tp = te + jnp.sum(tpv * te, -1, keepdims=True) * rpv
        return -_norm(hp + re - tp, m.norm_ord)
    if m.family == "distmult":
        return jnp.sum(he * rel[r] * te, axis=-1)
    if m.family == "complex":
        hre, him = he, params["ent_im"][h]
        tre, tim = te, params["ent_im"][t]
        rre, rim = rel[r], params["rel_im"][r]
        return jnp.sum(
            hre * rre * tre + him * rre * tim + hre * rim * tim - him * rim * tre,
            axis=-1,
        )
    if m.family == "rotate":
        d2 = he.shape[-1] // 2
        hr, hi = he[..., :d2], he[..., d2:]
        tr, ti = te[..., :d2], te[..., d2:]
        ph = params["rel"][r]
        cr, ci = jnp.cos(ph), jnp.sin(ph)
        rr = hr * cr - hi * ci
        ri = hr * ci + hi * cr
        return -jnp.sum(
            jnp.sqrt(jnp.square(rr - tr) + jnp.square(ri - ti) + 1e-12), axis=-1
        )
    raise ValueError(f"unknown family {m.family!r}")


def margin_loss(pos_scores: jnp.ndarray, neg_scores: jnp.ndarray, margin: float):
    """Margin ranking loss (paper's base objective via OpenKE defaults)."""
    return jnp.mean(jax.nn.relu(margin - pos_scores + neg_scores))


def virtual_pad_rows(
    params: Dict[str, jnp.ndarray], dim: int, n_ent: int, n_rel: int
) -> Dict[str, jnp.ndarray]:
    """Inert rows appended to the family-specific tables when ``n_ent``
    virtual entities / ``n_rel`` virtual relations extend ``ent``/``rel``:
    zero projections for TransD, unit normals for TransH, identity maps for
    TransR. The ONE definition of these rules — shared by
    ``KGETrainer.extend_tables`` and the tick engine's in-graph extension,
    so the two cannot drift apart per family."""
    pads: Dict[str, jnp.ndarray] = {}
    if "ent_p" in params:
        pads["ent_p"] = jnp.zeros((n_ent, dim), jnp.float32)
        pads["rel_p"] = jnp.zeros((n_rel, dim), jnp.float32)
    if "norm_vec" in params:
        padr = jnp.ones((n_rel, dim), jnp.float32)
        pads["norm_vec"] = padr / jnp.sqrt(jnp.float32(dim))
    if "proj" in params:
        pads["proj"] = jnp.tile(jnp.eye(dim)[None], (n_rel, 1, 1))
    return pads


def normalize_entities(params: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Project entity embeddings onto the unit ball (TransE constraint)."""
    out = dict(params)
    n = jnp.linalg.norm(params["ent"], axis=-1, keepdims=True)
    out["ent"] = params["ent"] / jnp.maximum(n, 1.0)
    return out


# ---------------------------------------------------------------------------
# link-prediction query decomposition — the streaming-rank-engine surface
# ---------------------------------------------------------------------------
# A family is "decomposable" when score(q, e) factors into a per-query vector
# against a query-independent entity table: score = −‖q − ent[e]‖ (l1/l2),
# q · ent[e] (dot), or the per-component complex modulus distance (cl1, the
# RotatE metric over [re | im] halves). That is exactly the contract of the
# Pallas triple_score kernels; TransH/R/D project the *entity* table per
# relation, so a mixed-relation batch has no shared table and falls back to
# index expansion.
#
# ComplEx factors through the real (E, 2d) table [ent | ent_im]:
#   tail: s = Σ tre·(hre·rre − him·rim) + tim·(him·rre + hre·rim)
#   head: s = Σ hre·(rre·tre + rim·tim) + him·(rre·tim − rim·tre)
# RotatE rotates the query side (rotations are per-component isometries, so
# ranking heads uses the inverse rotation t∘r̄):
#   tail: s = −Σ_k |h_k·r_k − t_k|      → q = h∘r,  mode cl1
#   head: s = −Σ_k |h_k·r_k − t_k|
#          = −Σ_k |h_k − t_k·r̄_k|       → q = t∘r̄,  mode cl1


def _complex_table(params) -> jnp.ndarray:
    return jnp.concatenate([params["ent"], params["ent_im"]], axis=1)


def lp_query_tails(params, m: KGEModel, h: jnp.ndarray, r: jnp.ndarray):
    """(query (B,d), entity table (E,d), mode) for tail ranking, or None."""
    if m.family == "transe":
        q = params["ent"][h] + params["rel"][r]
        return q, params["ent"], ("l2" if m.norm_ord == 2 else "l1")
    if m.family == "distmult":
        return params["ent"][h] * params["rel"][r], params["ent"], "dot"
    if m.family == "complex":
        hre, him = params["ent"][h], params["ent_im"][h]
        rre, rim = params["rel"][r], params["rel_im"][r]
        q = jnp.concatenate([hre * rre - him * rim, him * rre + hre * rim], 1)
        return q, _complex_table(params), "dot"
    if m.family == "rotate":
        he = params["ent"][h]
        d2 = he.shape[-1] // 2
        hr, hi = he[..., :d2], he[..., d2:]
        ph = params["rel"][r]
        cr, ci = jnp.cos(ph), jnp.sin(ph)
        q = jnp.concatenate([hr * cr - hi * ci, hr * ci + hi * cr], 1)
        return q, params["ent"], "cl1"
    return None


def lp_query_heads(params, m: KGEModel, r: jnp.ndarray, t: jnp.ndarray):
    """(query (B,d), entity table (E,d), mode) for head ranking, or None."""
    if m.family == "transe":
        q = params["ent"][t] - params["rel"][r]
        return q, params["ent"], ("l2" if m.norm_ord == 2 else "l1")
    if m.family == "distmult":
        return params["rel"][r] * params["ent"][t], params["ent"], "dot"
    if m.family == "complex":
        tre, tim = params["ent"][t], params["ent_im"][t]
        rre, rim = params["rel"][r], params["rel_im"][r]
        q = jnp.concatenate([rre * tre + rim * tim, rre * tim - rim * tre], 1)
        return q, _complex_table(params), "dot"
    if m.family == "rotate":
        te = params["ent"][t]
        d2 = te.shape[-1] // 2
        tr, ti = te[..., :d2], te[..., d2:]
        ph = params["rel"][r]
        cr, ci = jnp.cos(ph), jnp.sin(ph)  # conj rotation: t ∘ r̄
        q = jnp.concatenate([tr * cr + ti * ci, ti * cr - tr * ci], 1)
        return q, params["ent"], "cl1"
    return None


def lp_gold_scores(q: jnp.ndarray, ent: jnp.ndarray, idx: jnp.ndarray, mode: str):
    """Gather gold scores with the SAME expansion the tile kernel uses, so the
    gold entity's in-tile score differs from its gathered score only by fp
    noise (and the engine excludes gold via the filter row anyway)."""
    e = ent[idx].astype(jnp.float32)
    q = q.astype(jnp.float32)
    if mode == "dot":
        return jnp.sum(q * e, axis=-1)
    if mode == "l2":
        d2 = jnp.sum(q * q, -1) - 2.0 * jnp.sum(q * e, -1) + jnp.sum(e * e, -1)
        return -jnp.sqrt(jnp.maximum(d2, 0.0) + 1e-12)
    if mode == "cl1":
        half = q.shape[-1] // 2
        dr, di = q[:, :half] - e[:, :half], q[:, half:] - e[:, half:]
        return -jnp.sum(jnp.sqrt(dr * dr + di * di + 1e-12), axis=-1)
    return -jnp.sum(jnp.abs(q - e), axis=-1)


def _use_score_kernel(via_kernel: bool | None) -> bool:
    if via_kernel is not None:
        return via_kernel
    from repro.kernels.dispatch import COMPILED_BACKENDS

    # compiled Pallas backends route through the tiled kernel (write-once
    # tiles — safe on TPU and GPU); CPU CI keeps the numerically-identical
    # jnp broadcast (interpret mode would be slower)
    return jax.default_backend() in COMPILED_BACKENDS


def _decomposed_scores(q, table, mode: str, m: KGEModel, via_kernel):
    """(B, d) query × (E, d) table → (B, E) through the tile kernel on
    compiled backends, or the numerically-identical jnp broadcast on CPU."""
    if _use_score_kernel(via_kernel):
        from repro.kernels.triple_score import pairwise_scores

        return pairwise_scores(q, table, mode=mode)
    if mode == "dot":
        return q @ table.T
    if mode == "cl1":
        half = q.shape[-1] // 2
        dr = q[:, None, :half] - table[None, :, :half]
        di = q[:, None, half:] - table[None, :, half:]
        return -jnp.sum(jnp.sqrt(dr * dr + di * di + 1e-12), axis=-1)
    return -_norm(q[:, None, :] - table[None], m.norm_ord)


def score_all_tails(
    params, m: KGEModel, h: jnp.ndarray, r: jnp.ndarray,
    *, via_kernel: bool | None = None,
) -> jnp.ndarray:
    """Score (h, r, ·) against every entity → (B, E). Used by link prediction."""
    e = m.num_entities
    ent = params["ent"]

    qd = lp_query_tails(params, m, h, r)
    if qd is not None:
        q, table, mode = qd
        return _decomposed_scores(q, table, mode, m, via_kernel)
    # generic fallback: score against every entity by index expansion
    b = h.shape[0]
    t_all = jnp.arange(e)
    hh = jnp.repeat(h[:, None], e, axis=1).reshape(-1)
    rr = jnp.repeat(r[:, None], e, axis=1).reshape(-1)
    tt = jnp.tile(t_all[None], (b, 1)).reshape(-1)
    return score_triples(params, m, hh, rr, tt).reshape(b, e)


def score_all_heads(
    params, m: KGEModel, r: jnp.ndarray, t: jnp.ndarray,
    *, via_kernel: bool | None = None,
) -> jnp.ndarray:
    qd = lp_query_heads(params, m, r, t)
    if qd is not None:
        q, table, mode = qd
        return _decomposed_scores(q, table, mode, m, via_kernel)
    b = t.shape[0]
    e = m.num_entities
    h_all = jnp.arange(e)
    hh = jnp.tile(h_all[None], (b, 1)).reshape(-1)
    rr = jnp.repeat(r[:, None], e, axis=1).reshape(-1)
    tt = jnp.repeat(t[:, None], e, axis=1).reshape(-1)
    return score_triples(params, m, hh, rr, tt).reshape(b, e)
