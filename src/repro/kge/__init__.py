from repro.kge.models import KGEModel, init_kge, score_triples, MODEL_FAMILIES  # noqa: F401
from repro.kge.data import KG, synthesize_universe, PAPER_KG_STATS  # noqa: F401
from repro.kge.trainer import KGETrainer  # noqa: F401
from repro.kge.eval import triple_classification_accuracy, link_prediction  # noqa: F401
