"""KG triple stores and the synthetic LOD-like universe generator.

Raw LOD dumps (Dbpedia, Geonames, …) are not available offline, so we generate
a *universe* of latent entities with translational relational structure
(h + r ≈ t in latent space) and carve per-owner KGs out of it. Entities shared
between two KGs are exactly the paper's "aligned entities" (Tab. 3) — because
they are literally the same latent object, cross-KG signal exists and
federation *can* help, which is the property the paper's experiments rely on.

``PAPER_KG_STATS`` mirrors Tab. 2 (entity/relation/triple counts); the default
``scale`` shrinks it for CPU runs while preserving relative sizes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# (name, #relations, #entities, #triples) — Tab. 2 of the paper.
PAPER_KG_STATS = [
    ("Dbpedia", 14085, 491078, 1373644),
    ("Geonames", 6, 300000, 1163878),
    ("Yago", 37, 286389, 1824322),
    ("Geospecies", 38, 41943, 782120),
    ("Pokepedia", 28, 238008, 548883),
    ("Sandrart", 20, 14765, 18243),
    ("Hellenic", 4, 11145, 33296),
    ("Lexvo", 6, 9810, 147211),
    ("Tharawat", 12, 4693, 31130),
    ("Whisky", 11, 642, 1339),
    ("WorldLift", 10, 357, 1192),
]

# (kg_a, kg_b, #aligned entities) — Tab. 3.
PAPER_ALIGNMENTS = [
    ("Geonames", "Dbpedia", 118939),
    ("Yago", "Dbpedia", 123853),
    ("Yago", "Geonames", 53553),
    ("Sandrart", "Dbpedia", 379),
    ("Dbpedia", "Lexvo", 507),
    ("Dbpedia", "Tharawat", 403),
    ("Dbpedia", "Whisky", 70),
    ("Dbpedia", "WorldLift", 25),
    ("Lexvo", "Yago", 77),
    ("Whisky", "Yago", 49),
    ("Dbpedia", "Pokepedia", 27),
    ("Dbpedia", "Geospecies", 133),
    ("Geonames", "Geospecies", 89),
    ("Dbpedia", "Hellenic", 41),
    ("Geonames", "Lexvo", 245),
    ("Geonames", "Tharawat", 90),
    ("Geonames", "Whisky", 39),
    ("Yago", "WorldLift", 18),
    ("Yago", "Tharawat", 266),
]


@dataclass
class KG:
    """One owner's knowledge graph with train/valid/test splits (90:5:5)."""

    name: str
    num_entities: int
    num_relations: int
    triples: np.ndarray  # (N, 3) int32 [h, r, t] — local ids
    universe_ids: np.ndarray  # (num_entities,) global entity ids
    train: np.ndarray = field(default=None)
    valid: np.ndarray = field(default=None)
    test: np.ndarray = field(default=None)

    def split(self, rng: np.random.Generator):
        n = len(self.triples)
        order = rng.permutation(n)
        tr, va = int(0.9 * n), int(0.95 * n)
        self.train = self.triples[order[:tr]]
        self.valid = self.triples[order[tr:va]]
        self.test = self.triples[order[va:]]

    def aligned_with(self, other: "KG") -> Tuple[np.ndarray, np.ndarray]:
        """Local ids (this, other) of shared universe entities."""
        common, idx_self, idx_other = np.intersect1d(
            self.universe_ids, other.universe_ids, return_indices=True
        )
        return idx_self.astype(np.int32), idx_other.astype(np.int32)


def synthesize_universe(
    *,
    seed: int = 0,
    scale: float = 1 / 400,
    latent_dim: int = 12,
    kg_stats: Optional[List[Tuple[str, int, int, int]]] = None,
    alignments: Optional[List[Tuple[str, str, int]]] = None,
    noise: float = 0.05,
    density_boost: float = 8.0,
) -> Dict[str, KG]:
    """Build the 11-KG universe mirroring Tab. 2 / Tab. 3 at ``scale``.

    ``density_boost`` multiplies triple counts relative to the scaled entity
    counts: at 1/400 scale the paper's raw triples-per-entity (~3) is too
    sparse for any KGE model to generalize (loss→0, test accuracy ~chance —
    pure memorization), so scaled KGs keep the paper's *relative* sizes but
    are denser. Recorded as a deviation in EXPERIMENTS.md.
    """
    rng = np.random.default_rng(seed)
    kg_stats = kg_stats or PAPER_KG_STATS
    alignments = alignments if alignments is not None else PAPER_ALIGNMENTS

    def sc(x, lo):
        return max(lo, int(round(x * scale)))

    # small relation vocabularies are kept verbatim; only large ones scale
    sizes = {
        n: (r if r <= 50 else sc(r, 8), sc(e, 150), sc(t * density_boost, 1500))
        for n, r, e, t in kg_stats
    }

    total_universe = int(sum(e for _, e, _ in sizes.values()) * 0.8)
    z = rng.normal(0, 1.0, (total_universe, latent_dim)).astype(np.float32)

    # global relation pool with translational latents
    total_rel = sum(r for r, _, _ in sizes.values())
    rel_z = rng.normal(0, 0.6, (total_rel, latent_dim)).astype(np.float32)

    # assign entity subsets: overlapping pairs first (aligned entities are
    # shared universe ids), then fill up with private ids.
    assigned: Dict[str, set] = {n: set() for n in sizes}
    pool = rng.permutation(total_universe)
    cursor = 0

    def take(k):
        nonlocal cursor
        out = pool[cursor : cursor + k]
        cursor += k
        if len(out) < k:  # wrap (overlap is fine — extra incidental alignment)
            out = np.concatenate([out, rng.choice(total_universe, k - len(out))])
        return out

    for a, b, n_al in alignments:
        n_al = sc(n_al, 2)
        cap = min(sizes[a][1], sizes[b][1])
        n_al = min(n_al, int(0.6 * cap))
        shared = take(n_al)
        assigned[a].update(shared.tolist())
        assigned[b].update(shared.tolist())

    rel_cursor = 0
    kgs: Dict[str, KG] = {}
    for name, (n_rel, n_ent, n_tri) in sizes.items():
        ids = list(assigned[name])
        if len(ids) < n_ent:
            ids.extend(take(n_ent - len(ids)).tolist())
        ids = np.array(sorted(set(ids)), dtype=np.int64)[:n_ent]
        n_ent = len(ids)

        rel_ids = np.arange(rel_cursor, rel_cursor + n_rel)
        rel_cursor += n_rel

        # triples: sample (h, r), tail = exact nearest entity to z_h + z_r
        # (+ noise) → genuinely translational structure a TransX model can fit,
        # consistent across KGs because aligned entities share latents.
        h_idx = rng.integers(0, n_ent, n_tri)
        r_idx = rng.integers(0, n_rel, n_tri)
        target = z[ids[h_idx]] + rel_z[rel_ids[r_idx]]
        target += rng.normal(0, noise, target.shape).astype(np.float32)
        ent_z = z[ids]  # (E, L)
        t_idx = np.empty(n_tri, dtype=np.int64)
        step = max(1, 2_000_000 // max(1, n_ent))
        for s in range(0, n_tri, step):
            blk = target[s : s + step]
            d = (
                np.sum(blk**2, axis=1)[:, None]
                - 2 * blk @ ent_z.T
                + np.sum(ent_z**2, axis=1)[None]
            )
            d[np.arange(len(blk)), h_idx[s : s + step]] = np.inf  # no self-loop
            t_idx[s : s + step] = np.argmin(d, axis=1)
        triples = np.stack([h_idx, r_idx, t_idx], axis=1).astype(np.int32)
        triples = np.unique(triples, axis=0)

        kg = KG(
            name=name,
            num_entities=n_ent,
            num_relations=n_rel,
            triples=triples,
            universe_ids=ids,
        )
        kg.split(rng)
        kgs[name] = kg
    return kgs


def equal_shape_universe(
    n_owners: int = 8,
    *,
    entities: int = 160,
    relations: int = 8,
    triples: int = 1300,
    shared: int = 40,
    seed: int = 0,
) -> Dict[str, KG]:
    """N structurally IDENTICAL KG owners: every owner has the same entity /
    relation / triple-store / split extents, and every pair shares the same
    ``shared`` aligned entities (universe ids 0..shared-1, occupying the same
    local slots in every owner).

    ``synthesize_universe`` deduplicates generated triples, so even owners
    built from identical stats end up a few triples apart — enough to change
    padded store shapes. This builder pins shapes exactly: it is the
    deployment the paper scales to (N symmetric KG processes) and the shape
    the tick engine's trace-time program dedup targets — all N owners share
    ONE compiled tick-entry program per tick kind, and with owner-sticky
    placement each owner's chunk position in the shard_map group equals its
    home device. Owner counts that don't match the mesh (5 owners on 3 or 8
    devices — the pow-2 chunk-extent tests) are exactly as cheap: partial
    chunks pad with dummy entries instead of compiling new extents.
    """
    kgs: Dict[str, KG] = {}
    private = entities - shared
    if private < 0:
        raise ValueError("shared aligned block exceeds the entity count")
    for i in range(n_owners):
        rng = np.random.default_rng(seed + 7919 * i)
        h = rng.integers(0, entities, triples)
        r = rng.integers(0, relations, triples)
        t = (h + 1 + rng.integers(0, entities - 1, triples)) % entities
        tri = np.stack([h, r, t], axis=1).astype(np.int32)
        ids = np.concatenate(
            [np.arange(shared), shared + i * private + np.arange(private)]
        ).astype(np.int64)
        kg = KG(
            name=f"K{i}",
            num_entities=entities,
            num_relations=relations,
            triples=tri,
            universe_ids=ids,
        )
        kg.split(rng)
        kgs[kg.name] = kg
    return kgs


def corrupt_triples(
    rng: np.random.Generator, triples: np.ndarray, num_entities: int
) -> np.ndarray:
    """Negative sampling: corrupt head or tail uniformly (ratio 1:1, §4.1.1)."""
    neg = triples.copy()
    n = len(neg)
    corrupt_head = rng.random(n) < 0.5
    rand_ent = rng.integers(0, num_entities, n)
    neg[corrupt_head, 0] = rand_ent[corrupt_head]
    neg[~corrupt_head, 2] = rand_ent[~corrupt_head]
    return neg
