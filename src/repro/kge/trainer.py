"""Local KGE training — the "Train" step of Fig. 2 / Alg. 1 line 2.

SGD on margin ranking loss with 1:1 negative sampling. Matches OpenKE
defaults used by the paper (§4.1.1): lr=0.5 (SGD), batch 100, margin-based
TransX.

The default path is the **device-resident training engine**
(``kge.engine``): one compiled ``lax.scan`` over all epochs × minibatches
with on-device sampling and sparse (touched-rows-only) updates, bucket-padded
so federation handshakes reuse the compiled step. ``impl="reference"`` keeps
the seed path — a host loop of dense ``_epoch`` calls with numpy negative
sampling — as the parity oracle.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import resolve_interpret, resolve_train_impl
from repro.kge.models import (
    KGEModel,
    init_kge,
    margin_loss,
    normalize_entities,
    score_triples,
)


@functools.partial(jax.jit, static_argnames=("model",))
def _epoch(params, model: KGEModel, pos, neg, lr):
    """Seed dense epoch (``impl="reference"``): pos/neg (num_batches, B, 3)."""

    def step(p, batch):
        bp, bn = batch

        def loss_fn(pp):
            sp = score_triples(pp, model, bp[:, 0], bp[:, 1], bp[:, 2])
            sn = score_triples(pp, model, bn[:, 0], bn[:, 1], bn[:, 2])
            return margin_loss(sp, sn, model.margin)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p = jax.tree.map(lambda x, g: x - lr * g, p, grads)
        return p, loss

    params, losses = jax.lax.scan(step, params, (pos, neg))
    params = normalize_entities(params)
    return params, jnp.mean(losses)


class KGETrainer:
    """Owns one KG's embedding training state (one 'process' of the paper)."""

    def __init__(self, kg, family: str = "transe", dim: int = 100, *,
                 lr: float = 0.5, batch_size: int = 100, margin: float = 4.0,
                 seed: int = 0):
        self.kg = kg
        self.model = KGEModel(
            family=family,
            num_entities=kg.num_entities,
            num_relations=kg.num_relations,
            dim=dim,
            margin=margin,
        )
        self.lr = lr
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.params = init_kge(jax.random.PRNGKey(seed), self.model)
        self._virtual: Tuple[int, int] = (0, 0)  # extra (ent, rel) rows
        self._extra_triples: np.ndarray | None = None
        self._key = jax.random.PRNGKey(seed + 7919)  # engine sampling stream
        # device-resident padded triple store: kg.train is immutable and the
        # extended store only changes at extend/strip boundaries, so the O(N)
        # H2D upload + cycle-pad is paid once per (store size, batch) instead
        # of on every train_epochs call
        self._tri_cache: Tuple[tuple, jnp.ndarray] | None = None

    # ---- virtual entities/relations (core.aggregation) -----------------
    def extend_tables(self, v_ent, v_rel, extra_triples: np.ndarray) -> None:
        """Temporarily append DP-translated virtual rows + their triples."""
        import dataclasses

        from repro.kge.models import virtual_pad_rows

        assert self._virtual == (0, 0), "virtual extension already active"
        self.params = dict(self.params)
        self.params["ent"] = jnp.concatenate([self.params["ent"], v_ent])
        self.params["rel"] = jnp.concatenate([self.params["rel"], v_rel])
        pads = virtual_pad_rows(
            self.params, self.model.dim, len(v_ent), len(v_rel)
        )
        for k, pad in pads.items():
            self.params[k] = jnp.concatenate([self.params[k], pad])
        self._virtual = (len(v_ent), len(v_rel))
        self._extra_triples = np.asarray(extra_triples, np.int32)
        self._tri_cache = None  # store contents changed, not just its length
        self.model = dataclasses.replace(
            self.model,
            num_entities=self.model.num_entities + len(v_ent),
            num_relations=self.model.num_relations + len(v_rel),
        )

    def strip_virtual(self) -> None:
        """Remove virtual rows before responding to other hosts (§3.2.1)."""
        import dataclasses

        ne, nr = self._virtual
        if ne == 0 and nr == 0:
            return
        self.params = dict(self.params)
        for k in ("ent", "ent_p"):
            if k in self.params:
                self.params[k] = self.params[k][: len(self.params[k]) - ne]
        for k in ("rel", "rel_p", "norm_vec", "proj"):
            if k in self.params:
                self.params[k] = self.params[k][: len(self.params[k]) - nr]
        self.model = dataclasses.replace(
            self.model,
            num_entities=self.model.num_entities - ne,
            num_relations=self.model.num_relations - nr,
        )
        self._virtual = (0, 0)
        self._extra_triples = None
        self._tri_cache = None

    def consume_engine_key(self) -> jax.Array:
        """Advance the engine sampling stream and return the subkey the next
        device-resident ``train_epochs`` call would use. The federation tick
        engine draws from this SAME stream when it retrains an owner inside a
        batched tick program, so serial and batched ticks sample identically.
        """
        self._key, sub = jax.random.split(self._key)
        return sub

    def train_epochs(
        self, epochs: int = 1, *, impl: Optional[str] = None
    ) -> float:
        """Train ``epochs`` epochs; returns the last epoch's mean loss.

        ``impl``: ``pallas`` | ``xla`` | ``reference`` (default resolved by
        ``kernels.dispatch.resolve_train_impl`` / ``REPRO_TRAIN_IMPL``).
        """
        impl = resolve_train_impl(impl, self.model.family)
        tr = self.kg.train
        if self._extra_triples is not None and len(self._extra_triples):
            tr = np.concatenate([tr, self._extra_triples])
        if impl == "reference":
            return self._train_epochs_reference(tr, epochs)
        from repro.kge.engine import train_epochs_device

        sub = self.consume_engine_key()
        self.params, losses = train_epochs_device(
            self.params, self.model, self._padded_triples(tr), sub,
            epochs=epochs, batch_size=self.batch_size, lr=self.lr,
            impl=impl, interpret=resolve_interpret(None),
        )
        return float(losses[-1])

    def _padded_triples(self, tr: np.ndarray) -> jnp.ndarray:
        from repro.core.distributed import committed_device
        from repro.kge.engine import pad_triples

        b = min(self.batch_size, len(tr))
        # co-locate with the params: after owner-sticky federation ticks the
        # tables live committed on this owner's home device, and the padded
        # store should be uploaded there ONCE, not implicitly re-staged on
        # every train_epochs dispatch
        dev = committed_device(self.params)
        key = (len(tr), b, dev)
        if self._tri_cache is None or self._tri_cache[0] != key:
            padded = pad_triples(jnp.asarray(tr, jnp.int32), b)
            if dev is not None:
                padded = jax.device_put(padded, dev)
            self._tri_cache = (key, padded)
        return self._tri_cache[1]

    def _train_epochs_reference(self, tr: np.ndarray, epochs: int) -> float:
        """Seed path: host loop, numpy sampling, dense ``_epoch`` updates."""
        from repro.kge.data import corrupt_triples

        b = min(self.batch_size, len(tr))
        loss = 0.0
        for _ in range(epochs):
            order = self.rng.permutation(len(tr))
            nb = len(tr) // b
            pos = tr[order[: nb * b]].reshape(nb, b, 3)
            # corrupt against the EXTENDED entity count so virtual rows are
            # sampled as negatives while a virtual extension is active
            neg = corrupt_triples(self.rng, pos.reshape(-1, 3), self.model.num_entities)
            neg = neg.reshape(nb, b, 3)
            self.params, l = _epoch(
                self.params, self.model, jnp.asarray(pos), jnp.asarray(neg),
                jnp.float32(self.lr),
            )
            loss = float(l)
        return loss

    # ---- embedding table access (the FKGE surface) --------------------
    def get_entity_embeddings(self, idx: np.ndarray) -> jnp.ndarray:
        return self.params["ent"][jnp.asarray(idx)]

    def get_relation_embeddings(self, idx: np.ndarray) -> jnp.ndarray:
        return self.params["rel"][jnp.asarray(idx)]

    def set_entity_embeddings(self, idx: np.ndarray, emb: jnp.ndarray):
        self.params = dict(self.params)
        self.params["ent"] = self.params["ent"].at[jnp.asarray(idx)].set(emb)

    def set_relation_embeddings(self, idx: np.ndarray, emb: jnp.ndarray):
        self.params = dict(self.params)
        self.params["rel"] = self.params["rel"].at[jnp.asarray(idx)].set(emb)

    def snapshot(self) -> Dict[str, jnp.ndarray]:
        return {k: v for k, v in self.params.items()}

    def restore(self, snap: Dict[str, jnp.ndarray]):
        self.params = dict(snap)
