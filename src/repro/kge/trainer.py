"""Local KGE training — the "Train" step of Fig. 2 / Alg. 1 line 2.

SGD on margin ranking loss with 1:1 negative sampling, batched and jitted;
an epoch is one ``lax.scan`` over minibatches. Matches OpenKE defaults used
by the paper (§4.1.1): lr=0.5 (SGD), batch 100, margin-based TransX.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kge.models import (
    KGEModel,
    init_kge,
    margin_loss,
    normalize_entities,
    score_triples,
)


@functools.partial(jax.jit, static_argnames=("model",))
def _epoch(params, model: KGEModel, pos, neg, lr):
    """pos/neg: (num_batches, B, 3) int32."""

    def step(p, batch):
        bp, bn = batch

        def loss_fn(pp):
            sp = score_triples(pp, model, bp[:, 0], bp[:, 1], bp[:, 2])
            sn = score_triples(pp, model, bn[:, 0], bn[:, 1], bn[:, 2])
            return margin_loss(sp, sn, model.margin)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p = jax.tree.map(lambda x, g: x - lr * g, p, grads)
        return p, loss

    params, losses = jax.lax.scan(step, params, (pos, neg))
    params = normalize_entities(params)
    return params, jnp.mean(losses)


class KGETrainer:
    """Owns one KG's embedding training state (one 'process' of the paper)."""

    def __init__(self, kg, family: str = "transe", dim: int = 100, *,
                 lr: float = 0.5, batch_size: int = 100, margin: float = 4.0,
                 seed: int = 0):
        self.kg = kg
        self.model = KGEModel(
            family=family,
            num_entities=kg.num_entities,
            num_relations=kg.num_relations,
            dim=dim,
            margin=margin,
        )
        self.lr = lr
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.params = init_kge(jax.random.PRNGKey(seed), self.model)
        self._virtual: Tuple[int, int] = (0, 0)  # extra (ent, rel) rows
        self._extra_triples: np.ndarray | None = None

    # ---- virtual entities/relations (core.aggregation) -----------------
    def extend_tables(self, v_ent, v_rel, extra_triples: np.ndarray) -> None:
        """Temporarily append DP-translated virtual rows + their triples."""
        import dataclasses

        assert self._virtual == (0, 0), "virtual extension already active"
        self.params = dict(self.params)
        self.params["ent"] = jnp.concatenate([self.params["ent"], v_ent])
        self.params["rel"] = jnp.concatenate([self.params["rel"], v_rel])
        if "ent_p" in self.params:  # transd per-entity projections
            pad = jnp.zeros((len(v_ent), self.model.dim), jnp.float32)
            self.params["ent_p"] = jnp.concatenate([self.params["ent_p"], pad])
            padr = jnp.zeros((len(v_rel), self.model.dim), jnp.float32)
            self.params["rel_p"] = jnp.concatenate([self.params["rel_p"], padr])
        if "norm_vec" in self.params:
            padr = jnp.ones((len(v_rel), self.model.dim), jnp.float32)
            padr = padr / jnp.sqrt(jnp.float32(self.model.dim))
            self.params["norm_vec"] = jnp.concatenate([self.params["norm_vec"], padr])
        if "proj" in self.params:
            eye = jnp.tile(jnp.eye(self.model.dim)[None], (len(v_rel), 1, 1))
            self.params["proj"] = jnp.concatenate([self.params["proj"], eye])
        self._virtual = (len(v_ent), len(v_rel))
        self._extra_triples = np.asarray(extra_triples, np.int32)
        self.model = dataclasses.replace(
            self.model,
            num_entities=self.model.num_entities + len(v_ent),
            num_relations=self.model.num_relations + len(v_rel),
        )

    def strip_virtual(self) -> None:
        """Remove virtual rows before responding to other hosts (§3.2.1)."""
        import dataclasses

        ne, nr = self._virtual
        if ne == 0 and nr == 0:
            return
        self.params = dict(self.params)
        for k in ("ent", "ent_p"):
            if k in self.params:
                self.params[k] = self.params[k][: len(self.params[k]) - ne]
        for k in ("rel", "rel_p", "norm_vec", "proj"):
            if k in self.params:
                self.params[k] = self.params[k][: len(self.params[k]) - nr]
        self.model = dataclasses.replace(
            self.model,
            num_entities=self.model.num_entities - ne,
            num_relations=self.model.num_relations - nr,
        )
        self._virtual = (0, 0)
        self._extra_triples = None

    def train_epochs(self, epochs: int = 1) -> float:
        from repro.kge.data import corrupt_triples

        tr = self.kg.train
        if self._extra_triples is not None and len(self._extra_triples):
            tr = np.concatenate([tr, self._extra_triples])
        b = min(self.batch_size, len(tr))
        loss = 0.0
        for _ in range(epochs):
            order = self.rng.permutation(len(tr))
            nb = len(tr) // b
            pos = tr[order[: nb * b]].reshape(nb, b, 3)
            # corrupt against the EXTENDED entity count so virtual rows are
            # sampled as negatives while a virtual extension is active
            neg = corrupt_triples(self.rng, pos.reshape(-1, 3), self.model.num_entities)
            neg = neg.reshape(nb, b, 3)
            self.params, l = _epoch(
                self.params, self.model, jnp.asarray(pos), jnp.asarray(neg),
                jnp.float32(self.lr),
            )
            loss = float(l)
        return loss

    # ---- embedding table access (the FKGE surface) --------------------
    def get_entity_embeddings(self, idx: np.ndarray) -> jnp.ndarray:
        return self.params["ent"][jnp.asarray(idx)]

    def get_relation_embeddings(self, idx: np.ndarray) -> jnp.ndarray:
        return self.params["rel"][jnp.asarray(idx)]

    def set_entity_embeddings(self, idx: np.ndarray, emb: jnp.ndarray):
        self.params = dict(self.params)
        self.params["ent"] = self.params["ent"].at[jnp.asarray(idx)].set(emb)

    def set_relation_embeddings(self, idx: np.ndarray, emb: jnp.ndarray):
        self.params = dict(self.params)
        self.params["rel"] = self.params["rel"].at[jnp.asarray(idx)].set(emb)

    def snapshot(self) -> Dict[str, jnp.ndarray]:
        return {k: v for k, v in self.params.items()}

    def restore(self, snap: Dict[str, jnp.ndarray]):
        self.params = dict(snap)
