"""Production meshes (TPU v5e).

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only the
dry-run entrypoint sets the 512-device host-platform flag).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.sharding.context import auto_axis_types_kw


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips ('data', 'model').
    Multi-pod: (2, 16, 16) = 512 chips ('pod', 'data', 'model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types_kw(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever local devices exist (tests, examples)."""
    return jax.make_mesh(
        (data, model), ("data", "model"), **auto_axis_types_kw(2)
    )
