"""Workload construction for the dry-run and launchers.

``make_workload(cfg, shape, mesh, multi_pod)`` returns the jittable step
function, abstract input ShapeDtypeStructs (``input_specs`` — no allocation),
and in/out shardings for every (architecture × input shape) pair.

Shape semantics:
  train_4k    → one optimizer step (grad-accumulated microbatches)
  prefill_32k → full-sequence prefill populating a KV cache
  decode_32k  → ONE new token against a seq_len KV cache
  long_500k   → ONE new token against a 524288-token context; requires
                sub-quadratic attention → SSM / hybrid / SWA archs only.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPE_BY_NAME, InputShape, ModelConfig, TrainConfig
from repro.models.model import init_cache, init_params
from repro.sharding.specs import batch_pspec, cache_pspecs, param_pspecs, state_pspecs
from repro.train.step import (
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

# long_500k is only valid for sub-quadratic attention (DESIGN.md §4):
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "jamba-1.5-large-398b", "mixtral-8x22b"}


def supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        return False, "full quadratic attention at 524k context (see DESIGN.md §4)"
    return True, ""


def default_train_config(
    cfg: ModelConfig, shape: InputShape, *, multi_pod: bool = False
) -> TrainConfig:
    # multi-pod: 8 microbatches so each microbatch's 32 sequences still
    # divide the 32-way ('pod','data') batch sharding (needed by the
    # shard_map MoE path).
    return TrainConfig(
        global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        microbatches=8 if multi_pod else 16,
        ce_chunk=1024,  # sequence positions per CE chunk (see train/loss.py)
    )


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _abstract_state(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    mdt = jnp.dtype(tcfg.moment_dtype) if tcfg else jnp.float32
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, moment_dtype=mdt), key
    )


def _abstract_params(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(k, cfg), key)


def _abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))


def make_workload(
    cfg: ModelConfig,
    shape_name: str,
    mesh: Mesh,
    *,
    multi_pod: bool = False,
    tcfg: Optional[TrainConfig] = None,
    layout: str = "tp",
) -> Dict[str, Any]:
    """→ {fn, args (abstract), in_shardings, out_shardings, kind}.

    layout: "tp" (tensor/expert parallel — default production rules) or
    "dp" (fully data-parallel, small-card training; §Perf iteration 4)."""
    shape = INPUT_SHAPE_BY_NAME[shape_name]
    ok, why = supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape_name} unsupported: {why}")
    from repro.sharding import context as shard_ctx

    shard_ctx.set_mesh(mesh)  # layers with manual collectives (MoE a2a) read it
    bspec = batch_pspec(multi_pod, layout=layout)
    dt = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        tcfg = tcfg or default_train_config(cfg, shape, multi_pod=multi_pod)
        state = _abstract_state(cfg, tcfg)
        b, s = shape.global_batch, shape.seq_len
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        batch_specs = {"tokens": bspec, "labels": bspec}
        if cfg.encoder_layers:
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt)
            batch_specs["frames"] = P(bspec[0], None, None)
        if cfg.num_patches:
            batch["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), dt)
            batch_specs["patches"] = P(bspec[0], None, None)
        sspec = state_pspecs(state, layout=layout)
        fn = make_train_step(cfg, tcfg)
        return {
            "fn": fn,
            "args": (state, batch),
            "in_shardings": (_ns(mesh, sspec), _ns(mesh, batch_specs)),
            "out_shardings": (_ns(mesh, sspec), None),
            "kind": "train",
        }

    params = _abstract_params(cfg)
    pspec = param_pspecs(params)

    if shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
        # VLM: the cache also holds the visual-prefix positions
        cache = _abstract_cache(cfg, b, s + cfg.num_patches)
        cspec = cache_pspecs(cache, cfg, b, multi_pod=multi_pod)
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        base = make_prefill_step(cfg)
        extra_args: Tuple = ()
        extra_specs: Tuple = ()
        if cfg.encoder_layers:
            extra_args = (jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt),)
            extra_specs = (P(bspec[0], None, None),)
            fn = lambda p, t, c, f: base(p, t, c, frames=f)
        elif cfg.num_patches:
            extra_args = (jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), dt),)
            extra_specs = (P(bspec[0], None, None),)
            fn = lambda p, t, c, pa: base(p, t, c, patches=pa)
        else:
            fn = lambda p, t, c: base(p, t, c)
        return {
            "fn": fn,
            "args": (params, tokens, cache) + extra_args,
            "in_shardings": (_ns(mesh, pspec), NamedSharding(mesh, bspec), _ns(mesh, cspec))
            + tuple(NamedSharding(mesh, s) for s in extra_specs),
            "out_shardings": (None, _ns(mesh, cspec)),
            "kind": "prefill",
        }

    # decode: ONE token against a cache of shape.seq_len
    b, t = shape.global_batch, shape.seq_len
    cache = _abstract_cache(cfg, b, t)
    cspec = cache_pspecs(cache, cfg, b, multi_pod=multi_pod)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    base = make_decode_step(cfg)
    fn = lambda p, tok, c, cp: base(p, tok, c, cp)
    tok_spec = NamedSharding(mesh, bspec if b > 1 else P(None, None))
    return {
        "fn": fn,
        "args": (params, token, cache, pos),
        "in_shardings": (
            _ns(mesh, pspec),
            tok_spec,
            _ns(mesh, cspec),
            NamedSharding(mesh, P()),
        ),
        "out_shardings": (None, _ns(mesh, cspec)),
        "kind": "decode",
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Public ShapeDtypeStruct stand-ins for every model input (no mesh)."""
    shape = INPUT_SHAPE_BY_NAME[shape_name]
    dt = jnp.dtype(cfg.dtype)
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        out["token"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        out["cache_pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.encoder_layers:
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.num_patches and shape.kind != "decode":
        out["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), dt)
    return out
